#!/usr/bin/env python
"""Render / validate / diff flight-recorder traces.  Run from anywhere:

    python scripts/obs_report.py t.jsonl            # timeline + bottlenecks
    python scripts/obs_report.py --check t.jsonl    # schema gate (CI)
    python scripts/obs_report.py a.jsonl b.jsonl    # diff two runs

Traces are written by ``train.py --trace-out t.jsonl`` (see
``repro.obs.events`` for the schema).  Exit 1 iff --check finds schema
problems.
"""

import argparse
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs.report import (  # noqa: E402
    check_trace,
    diff_traces,
    load_trace,
    render_report,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report",
        description="Timeline, bottleneck attribution and diffs over "
                    "repro.obs flight-recorder traces.")
    ap.add_argument("trace", help="JSONL trace (train.py --trace-out)")
    ap.add_argument("other", nargs="?", default=None,
                    help="second trace: print a diff instead of a report")
    ap.add_argument("--check", action="store_true",
                    help="validate the trace(s) against the schema and "
                         "exit nonzero on any problem")
    args = ap.parse_args(argv)

    if args.check:
        ok = True
        for path in filter(None, (args.trace, args.other)):
            good, lines = check_trace(path)
            print("\n".join(lines))
            ok = ok and good
        return 0 if ok else 1

    if args.other:
        print(diff_traces(load_trace(args.trace), load_trace(args.other)))
        return 0

    print(render_report(load_trace(args.trace)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
