#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite must pass.
# Usage: scripts/ci.sh [--fast] [extra pytest args]
#
#   --fast   deselect tests marked `slow` (Monte-Carlo schedule sweeps,
#            subprocess train acceptance runs) — the minutes-scale lane
#            for inner-loop development.  The DEFAULT (no flag) runs the
#            full suite including slow tests: that is the tier-1 gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
FAST_ARGS=()
FAST=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST_ARGS=(-m "not slow")
  FAST=1
  shift
fi
# Property tests silently degrade to deterministic compat-shim sweeps when
# hypothesis is missing (tests/_hypothesis_compat.py) — make sure CI runs
# the real thing.  Offline/airgapped runs fall back to the shim with a
# visible warning instead of failing before any test runs.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  python -m pip install -q -r requirements-dev.txt ||
    echo "WARN: could not install requirements-dev.txt;" \
         "property tests will use the compat-shim sweeps" >&2
fi
# Lint gate: project-invariant static checks (trace safety, RNG
# discipline, NEG_INF sentinel, dtype discipline, engine contracts,
# protocol typestate) against the committed baseline.  The fast lane
# checks only files changed vs the git merge base (the whole tree is
# still parsed for cross-file facts); the full lane lints everything
# and must finish inside its 30 s wall-clock budget — if it doesn't,
# the lint layer has regressed and the budget assert fails the run.
echo "== repro-lint =="
LINT_START=$SECONDS
if [[ "$FAST" == 1 ]]; then
  python scripts/lint_repro.py --changed
else
  python scripts/lint_repro.py
  LINT_TOOK=$((SECONDS - LINT_START))
  if (( LINT_TOOK >= 30 )); then
    echo "repro-lint: full lint took ${LINT_TOOK}s (budget: 30s)" >&2
    exit 1
  fi
fi
# Docs gate first: the README quickstart must run as-is and docs/ must
# not reference dead file paths (tests/test_readme_quickstart.py).
echo "== docs gate =="
python -m pytest -x -q tests/test_readme_quickstart.py
echo "== tier-1 =="
# --ignore: the docs gate already ran that file; don't run it twice
# ${arr[@]+...} guard: empty-array expansion under `set -u` aborts on
# bash < 4.4 (e.g. macOS system bash)
python -m pytest -x -q --ignore=tests/test_readme_quickstart.py \
  ${FAST_ARGS[@]+"${FAST_ARGS[@]}"} "$@"
echo "== pallas kernel smoke =="
# The Pallas segment-max kernel must stay bit-identical to
# jax.ops.segment_max (interpret mode on CPU; the compiled-TPU path is
# the same kernel body).  A one-liner so kernel drift fails loudly even
# when the kernel test file is deselected.
python - <<'PY'
import numpy as np, jax, jax.numpy as jnp
from repro.kernels.segment_max import edge_segment_max_pallas
rng = np.random.default_rng(0)
vals = rng.standard_normal((4, 96)).astype(np.float32)
vals[rng.random((4, 96)) < 0.2] = -np.inf
ids = rng.integers(-1, 33, size=(4, 96)).astype(np.int32)
got = edge_segment_max_pallas(vals, ids, 32, interpret=True)
ref = jax.vmap(lambda v, i: jax.ops.segment_max(v, i, num_segments=32))(
    jnp.asarray(vals), jnp.asarray(ids))
np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
print("pallas segment-max == jax.ops.segment_max (bitwise)")
PY
echo "== bench smoke =="
# Seconds-scale pass over the smoke-capable benchmarks (tiny grids, perf
# asserts off, correctness asserts on) so bench code cannot silently rot.
python -m benchmarks.run --smoke
if [[ "$FAST" == 0 ]]; then
  # Obs trace smoke (full lane only — a subprocess train run is minutes):
  # the closed-loop linkfail scenario must produce a schema-valid flight
  # recording that obs_report can both validate and render.  This is the
  # end-to-end contract for the observability layer: recorder wiring in
  # train.py, controller decision records, and the report toolchain.
  # --objective time_to_eps makes every re-design price (tau, rho)
  # co-design, so the trace also carries the mixing-rate audit fields.
  echo "== obs trace smoke =="
  TRACE=$(mktemp /tmp/obs_trace.XXXXXX.jsonl)
  trap 'rm -f "$TRACE"' EXIT
  python -m repro.launch.train --arch internlm2-1.8b --reduced --dynamic \
    --underlay gaia --scenario linkfail --steps 60 \
    --objective time_to_eps \
    --trace-out "$TRACE" --metrics-interval 5 >/dev/null
  python scripts/obs_report.py --check "$TRACE"
  # Render the full report to /dev/null: a crash here means the trace
  # has records the report code can't handle.  (No `| head`: pipefail
  # turns the reader's SIGPIPE into a spurious CI failure.)
  python scripts/obs_report.py "$TRACE" >/dev/null
fi
