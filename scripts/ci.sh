#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite must pass.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
