#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite must pass.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Property tests silently degrade to deterministic compat-shim sweeps when
# hypothesis is missing (tests/_hypothesis_compat.py) — make sure CI runs
# the real thing.  Offline/airgapped runs fall back to the shim with a
# visible warning instead of failing before any test runs.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  python -m pip install -q -r requirements-dev.txt ||
    echo "WARN: could not install requirements-dev.txt;" \
         "property tests will use the compat-shim sweeps" >&2
fi
python -m pytest -x -q "$@"
