#!/usr/bin/env python
"""CLI for repro-lint.  Run from anywhere:

    python scripts/lint_repro.py                 # lint src/ + tests/
    python scripts/lint_repro.py --update-baseline
    python scripts/lint_repro.py src/repro/core  # lint a subtree

Exit 1 iff violations not covered by scripts/lint_baseline.txt exist.
"""

import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
