"""Serving-path correctness: prefill+decode must agree with the
full-sequence forward pass (cache semantics, ring buffers, MLA latents,
recurrent states)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, transformer as T

ARCHS = [
    "internlm2-1.8b",       # dense GQA
    "h2o-danube-1.8b",      # sliding window
    "deepseek-v2-lite-16b", # MLA absorbed decode
    "xlstm-350m",           # recurrent
    "hymba-1.5b",           # hybrid
]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward_last_logits(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, T.model_specs(cfg))
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens)
    last_logits, _ = T.prefill(params, cfg, tokens, max_len=64,
                               cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(full[:, -1]), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_continuation_matches_forward(arch):
    """prefill(t[:n]) then decode t[n], t[n+1]... == forward(t) logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, T.model_specs(cfg))
    B, S, n = 2, 20, 14
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens)
    _, cache = T.prefill(params, cfg, tokens[:, :n], max_len=64,
                         cache_dtype=jnp.float32)
    for pos in range(n, S):
        logits, cache = T.decode_step(params, cfg, tokens[:, pos], cache,
                                      jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, pos]),
            atol=5e-3, rtol=5e-3,
        )


def test_sliding_window_ring_buffer_wraps_correctly():
    """Decode far past the window: the ring buffer must forget old
    positions exactly like a windowed full forward."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window == 32
    key = jax.random.PRNGKey(2)
    params = init_params(key, T.model_specs(cfg))
    B, S = 1, 72  # > 2x window
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens)
    n = 8
    _, cache = T.prefill(params, cfg, tokens[:, :n], max_len=S,
                         cache_dtype=jnp.float32)
    logits = None
    for pos in range(n, S):
        logits, cache = T.decode_step(params, cfg, tokens[:, pos], cache,
                                      jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=5e-3, rtol=5e-3)


def test_whisper_encdec_decode_consistency():
    cfg = get_config("whisper-large-v3").reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, T.model_specs(cfg))
    B, S = 2, 12
    frames = jax.random.normal(key, (B, cfg.encoder.seq_len, 128)) * 0.1
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens, enc_frames=frames)
    _, cache = T.prefill(params, cfg, tokens[:, :8], max_len=32,
                         cache_dtype=jnp.float32, enc_frames=frames)
    logits = None
    for pos in range(8, S):
        logits, cache = T.decode_step(params, cfg, tokens[:, pos], cache,
                                      jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=5e-3, rtol=5e-3)
