"""Elastic silo membership: the fed-layer contracts.

* :class:`MembershipSlot` — versioned active-set swaps (validation,
  no-op on unchanged sets, callbacks);
* :func:`migrate_silo_state` — gather → re-stack → re-shard invariants:
  survivors bit-identical, leavers dropped, joiners at the survivors'
  consensus average, shared leaves untouched;
* :func:`masked_consensus` — renormalizing a consensus matrix over the
  active silos (the traced-mask path of the ``consensus_arg`` step);
* resizable :class:`PlanSlot`/:class:`ScheduleSlot` swaps;
* :func:`save_silo_checkpoint` round-trip of a leaver's shard;
* :class:`FederatedBatcher` stacking a silo-label subset.
"""

import numpy as np
import pytest

import repro.core as C
from repro.core.delays import TrainingParams
from repro.fed.dpasgd import masked_consensus, migrate_silo_state
from repro.fed.gossip import GossipPlan, MembershipSlot, PlanSlot, ScheduleSlot
from repro.fed.topology_runtime import plan_from_overlay


# ---------------------------------------------------------------------------
# MembershipSlot


def test_membership_slot_swap_contract():
    slot = MembershipSlot(range(5), 5)
    assert slot.active == (0, 1, 2, 3, 4)
    assert slot.n_active == 5 and slot.n_universe == 5
    seen = []
    slot.on_swap(lambda active, version: seen.append((active, version)))
    v = slot.swap((0, 1, 3, 4), label="silo 2 left")
    assert v == 1 and slot.active == (0, 1, 3, 4)
    assert seen == [((0, 1, 3, 4), 1)]
    assert slot.history[-1] == (1, "silo 2 left")
    # unchanged set (any order) is a no-op: version does not move
    assert slot.swap((4, 3, 1, 0)) == 1 and slot.version == 1
    v = slot.swap(range(5), label="silo 2 rejoined")
    assert v == 2 and slot.active == (0, 1, 2, 3, 4)


def test_membership_slot_rejects_bad_sets():
    slot = MembershipSlot(range(4), 4)
    with pytest.raises(ValueError):
        slot.swap(())  # empty
    with pytest.raises(ValueError):
        slot.swap((0, 0, 1))  # duplicate
    with pytest.raises(ValueError):
        slot.swap((0, 4))  # outside the universe
    with pytest.raises(ValueError):
        MembershipSlot((-1, 0), 4)
    assert slot.version == 0  # failed swaps leave the slot untouched


# ---------------------------------------------------------------------------
# State migration


def _stacked_state(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": rng.standard_normal((n, 3, 2)).astype(np.float32),
            "b": rng.standard_normal((n, 4)).astype(np.float32),
        },
        "opt_state": {"m": rng.standard_normal((n, 3, 2)).astype(np.float32)},
        "step": np.asarray(7, np.int32),
    }


def test_migrate_drops_leaver_and_keeps_survivors_bit_identical():
    state = _stacked_state(4)
    new, joined, left = migrate_silo_state(state, (0, 1, 2, 3), (0, 1, 3))
    assert joined == () and left == (2,)
    assert new["params"]["w"].shape == (3, 3, 2)
    for key in ("w", "b"):
        old = state["params"][key]
        assert np.array_equal(new["params"][key], old[[0, 1, 3]])
    assert np.array_equal(new["opt_state"]["m"], state["opt_state"]["m"][[0, 1, 3]])
    assert new["step"] == 7  # shared leaf passes through


def test_migrate_initializes_joiner_at_survivors_consensus_average():
    state = _stacked_state(4)
    # silo 2 left earlier; now silo 4's label joins a 3-silo universe
    shrunk, _, _ = migrate_silo_state(state, (0, 1, 2, 3), (0, 1, 3))
    grown, joined, left = migrate_silo_state(shrunk, (0, 1, 3), (0, 1, 2, 3))
    assert joined == (2,) and left == ()
    for key in ("w", "b"):
        old = shrunk["params"][key]
        expect = old.mean(axis=0, dtype=np.float64).astype(old.dtype)
        assert np.array_equal(grown["params"][key][2], expect)
        # survivors stay bit-identical through the round trip
        assert np.array_equal(grown["params"][key][[0, 1, 3]], old)


def test_slice_silo_row_picks_mesh_position_of_label():
    from repro.fed.dpasgd import slice_silo_row

    state = _stacked_state(4)
    row = slice_silo_row(state, (0, 2, 5, 7), 5)  # label 5 = mesh row 2
    assert np.array_equal(row["params"]["w"], state["params"]["w"][2])
    assert np.array_equal(row["opt_state"]["m"], state["opt_state"]["m"][2])
    assert row["step"] == 7  # shared leaf passes through
    with pytest.raises(ValueError):
        slice_silo_row(state, (0, 2, 5, 7), 9)  # not an active label


def test_migrate_requires_a_surviving_silo():
    state = _stacked_state(3)
    with pytest.raises(ValueError):
        migrate_silo_state(state, (0, 1, 2), (3, 4))


# ---------------------------------------------------------------------------
# Masked consensus renormalization


def ring_A(n):
    from repro.core.consensus import ring_matrix

    return ring_matrix(n, list(range(n)))


def test_masked_consensus_full_mask_is_identity_transform():
    A = ring_A(5)
    out = np.asarray(masked_consensus(A, np.ones(5)))
    np.testing.assert_allclose(out, A, atol=1e-7)


def test_masked_consensus_renormalizes_over_survivors():
    A = ring_A(4)  # silo i receives from i-1 and itself, weights 1/2 each
    mask = np.array([1.0, 1.0, 0.0, 1.0])
    out = np.asarray(masked_consensus(A, mask))
    # every row is stochastic
    np.testing.assert_allclose(out.sum(axis=1), np.ones(4), atol=1e-6)
    # the inactive silo's row froze to identity (params untouched)
    np.testing.assert_allclose(out[2], np.eye(4)[2], atol=1e-7)
    # nothing mixes *from* the inactive silo
    assert np.all(out[[0, 1, 3], 2] == 0.0)
    # silo 3 received from the departed silo 2: that weight returns to
    # its surviving in-neighbour set (here: itself), renormalized
    np.testing.assert_allclose(out[3], np.eye(4)[3], atol=1e-7)
    # silo 1 keeps its intact in-neighbourhood {0, 1} untouched
    np.testing.assert_allclose(out[1], A[1], atol=1e-7)


def test_masked_consensus_matches_submatrix_renormalization():
    rng = np.random.default_rng(3)
    for _ in range(10):
        n = int(rng.integers(3, 8))
        A = rng.random((n, n)) + 0.1
        A = A / A.sum(axis=1, keepdims=True)  # row-stochastic
        keep = np.sort(rng.choice(n, size=int(rng.integers(2, n + 1)),
                                  replace=False))
        mask = np.zeros(n)
        mask[keep] = 1.0
        out = np.asarray(masked_consensus(A, mask))
        sub = A[np.ix_(keep, keep)]
        sub = sub / sub.sum(axis=1, keepdims=True)
        np.testing.assert_allclose(out[np.ix_(keep, keep)], sub, atol=1e-6)


# ---------------------------------------------------------------------------
# Resizable slots


def gaia_overlays():
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    from repro.dynamics import active_subgraph

    full = C.design_overlay("ring", gc, tp)
    active = tuple(v for v in gc.silos if v != 5)
    sub = C.design_overlay("ring", active_subgraph(gc, active), tp)
    return gc, full, sub, active


def test_plan_slot_resize_requires_opt_in():
    gc, full, sub, active = gaia_overlays()
    slot = PlanSlot(plan_from_overlay(full, gc.num_silos))
    small = plan_from_overlay(sub, len(active), silos=active)
    with pytest.raises(ValueError):  # silent resize still rejected
        slot.swap(small)
    v = slot.swap(small, label="churn", allow_resize=True)
    assert v == 1 and slot.plan.n_silos == len(active)
    # and back up once the silo rejoins
    slot.swap(plan_from_overlay(full, gc.num_silos), allow_resize=True)
    assert slot.plan.n_silos == gc.num_silos


def test_schedule_slot_resize_repins_silo_order():
    gc, full, sub, active = gaia_overlays()
    slot = ScheduleSlot(C.FixedSchedule(full), gc.num_silos, silos=gc.silos)
    assert slot.plan.n_silos == gc.num_silos
    v = slot.swap_schedule(C.FixedSchedule(sub), label="churn", silos=active)
    assert v == 1 and slot.plan.n_silos == len(active)
    A = slot.matrix_for_round(0)
    assert A.shape == (len(active), len(active))
    np.testing.assert_allclose(A.sum(axis=1), np.ones(len(active)), atol=1e-8)
    with pytest.raises(ValueError):  # without silos= the resize is rejected
        slot.swap_schedule(C.FixedSchedule(full))
    # ... and the failed swap left the slot untouched and usable
    assert slot.version == v and slot.plan.n_silos == len(active)
    np.testing.assert_array_equal(slot.matrix_for_round(0), A)


def test_schedule_slot_rolls_back_when_a_callback_raises():
    gc, full, sub, active = gaia_overlays()
    slot = ScheduleSlot(C.FixedSchedule(full), gc.num_silos, silos=gc.silos)
    v0, plan0, hist0 = slot.version, slot.plan, list(slot.history)
    A0 = slot.matrix_for_round(0)

    @slot.on_swap
    def boom(plan, version):
        raise RuntimeError("consumer re-lower failed")

    with pytest.raises(RuntimeError):
        slot.swap_schedule(C.FixedSchedule(sub), silos=active)
    # fully rolled back: plan, version, history AND the silo universe
    assert slot.version == v0 and slot.plan is plan0
    assert slot.history == hist0
    np.testing.assert_array_equal(slot.matrix_for_round(0), A0)


# ---------------------------------------------------------------------------
# Leaver checkpoint + elastic batching


def test_save_silo_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_silo_checkpoint

    row = {"w": np.arange(6, dtype=np.float32).reshape(3, 2)}
    path = save_silo_checkpoint(str(tmp_path), 7, row, step=42)
    assert path.endswith("silo7_step42.msgpack")
    back = load_checkpoint(path, {"w": np.zeros((3, 2), np.float32)})
    np.testing.assert_array_equal(np.asarray(back["w"]), row["w"])


def test_federated_batcher_stacks_silo_subset():
    from repro.data import FederatedBatcher, SyntheticLMStream

    stream = SyntheticLMStream(64, 8, n_silos=5)
    batcher = FederatedBatcher(stream, local_steps=2, batch_per_silo=3)
    full = batcher.batch(4)
    sub = batcher.batch(4, silos=(3, 0))
    assert sub["tokens"].shape == (2, 2, 3, 8)
    # row k of the subset batch is silo label silos[k]'s own stream
    np.testing.assert_array_equal(sub["tokens"][0], full["tokens"][3])
    np.testing.assert_array_equal(sub["tokens"][1], full["tokens"][0])
    with pytest.raises(ValueError):
        batcher.batch(0, silos=(5,))
