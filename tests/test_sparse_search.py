"""Device-side topology search (`search_overlays_jit`) and its wiring
into the re-design pool of the dynamics controller."""

import numpy as np
import pytest

import repro.core as C
from repro.core.delays import overlay_delay_matrix
from repro.core.maxplus_vec import batched_is_strongly_connected
from repro.core.topologies import search_overlays_jit

pytest.importorskip("jax")


def _gaia_problem():
    u = C.make_underlay("gaia")
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    return u.connectivity_graph(comp_time_ms=Tc), tp


def test_search_returns_valid_overlay_with_constraints():
    gc, tp = _gaia_problem()
    delta = 3
    ov = search_overlays_jit(
        gc, tp, n_restarts=8, n_steps=24, delta_max=delta, seed=0
    )
    assert ov.name == "sparse_rewire"
    W = overlay_delay_matrix(gc, tp, ov.edges)
    assert bool(batched_is_strongly_connected(W))
    for v in gc.silos:
        assert ov.out_degree(v) <= delta
        assert ov.in_degree(v) <= delta
    assert np.isfinite(ov.cycle_time_ms) and ov.cycle_time_ms > 0


def test_search_never_worse_than_christofides_ring():
    """The climb is seeded with the Christofides ring and only accepts
    improvements, so it can never return something worse."""
    gc, tp = _gaia_problem()
    ring = C.design_overlay("ring", gc, tp)
    ov = search_overlays_jit(gc, tp, n_restarts=8, n_steps=24, seed=0)
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-6


def test_search_beats_ring_search_on_gaia():
    """Acceptance: tau(search_overlays_jit) <= tau(256-candidate ring
    search) on the Gaia underlay (the wall-clock-budget comparison lives
    in benchmarks/sparse_search_bench.py)."""
    from repro.dynamics import search_ring_candidates

    gc, tp = _gaia_problem()
    ring = search_ring_candidates(gc, tp, 256, np.random.default_rng(0))
    ov = search_overlays_jit(gc, tp, n_restarts=8, n_steps=48, seed=0)
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-6


def test_search_improves_incumbent_on_sparse_underlay():
    """On a non-complete connectivity graph the climb must stay within
    routed pairs and still match/improve an incumbent ring."""
    u = C.make_underlay("geant")  # sparse: 40 silos, 61 core links
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    gc = u.connectivity_graph(comp_time_ms=Tc)
    ring = C.design_overlay("ring", gc, tp)
    ov = search_overlays_jit(
        gc, tp, n_restarts=4, n_steps=32, seed=0, incumbent=ring
    )
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-6
    for (i, j) in ov.edges:
        assert gc.has_edge(i, j)


def test_stale_incumbent_arc_is_skipped_not_crashed():
    """Regression: a link failure can remove a routed pair from the
    connectivity estimate while the incumbent overlay still uses it;
    the search must skip that seed, not KeyError mid-controller."""
    from repro.core.delays import ConnectivityGraph, SiloParams, TrainingParams
    from repro.core.topologies import Overlay

    n = 5
    lat, bw = {}, {}
    for i in range(n):
        for j in range(n):
            if i != j and {i, j} != {1, 2}:  # pair (1,2) partitioned away
                lat[(i, j)] = 5.0 + abs(i - j)
                bw[(i, j)] = 1.0
    params = {i: SiloParams(5.0, 10.0, 10.0) for i in range(n)}
    gc = ConnectivityGraph(tuple(range(n)), lat, bw, params)
    tp = TrainingParams(model_size_mbits=10.0, local_steps=1)
    stale = Overlay(
        name="ring",
        edges=((0, 1), (1, 2), (2, 3), (3, 4), (4, 0)),  # uses dead 1->2
        cycle_time_ms=50.0,
    )
    ov = search_overlays_jit(
        gc, tp, n_restarts=4, n_steps=16, seed=0, incumbent=stale
    )
    for (i, j) in ov.edges:
        assert gc.has_edge(i, j)


def test_design_overlay_registry_kind():
    gc, tp = _gaia_problem()
    ov = C.design_overlay("sparse_rewire", gc, tp)
    assert ov.name == "sparse_rewire"
    assert "sparse_rewire" in C.OVERLAY_KINDS


def test_design_best_overlay_uses_rewire_pool():
    """Controller pool: with a rewire budget the result can only improve
    on the heuristic-designers + ring-search pool."""
    from repro.dynamics import design_best_overlay

    gc, tp = _gaia_problem()
    base, scored0 = design_best_overlay(
        gc, tp, n_candidates=64, rng=np.random.default_rng(0)
    )
    best, scored1 = design_best_overlay(
        gc,
        tp,
        n_candidates=64,
        rng=np.random.default_rng(0),
        incumbent=base,
        rewire_restarts=4,
        rewire_steps=16,
    )
    assert best.cycle_time_ms <= base.cycle_time_ms + 1e-6
    assert scored1 > scored0


def test_cycle_time_engine_crossover():
    """Satellite of the scaling PR: the size dispatcher must route N=64
    (where BENCH_sparse_search.json has the dense engine winning) to the
    dense path and N=1024 sparse batches (where it loses 6x+) to the
    sparse path — and the auto scorer must agree with both engines."""
    from repro.core.maxplus_sparse import (
        batched_cycle_time_auto,
        batched_cycle_time_sparse,
        cycle_time_engine,
        edge_batch_to_dense,
    )
    from repro.core.maxplus_vec import batched_cycle_time

    assert cycle_time_engine(64, 64 * 8, 256) == "dense"
    assert cycle_time_engine(1024, 1024 * 8, 8) == "sparse"
    # dense crossover also triggers on density, not just size
    assert cycle_time_engine(1024, 1024 * 512, 8) == "dense"

    from benchmarks.sparse_search_bench import random_sparse_overlays

    for n, b in ((64, 4), (256, 3)):
        eb = random_sparse_overlays(np.random.default_rng(n), n, b)
        got = batched_cycle_time_auto(eb)
        np.testing.assert_allclose(got, batched_cycle_time_sparse(eb),
                                   rtol=1e-9)
        np.testing.assert_allclose(
            got, batched_cycle_time(edge_batch_to_dense(eb)), rtol=1e-9)


def test_delta_rewire_registry_kind():
    gc, tp = _gaia_problem()
    ov = C.design_overlay("delta_rewire", gc, tp)
    assert ov.name == "delta_rewire"
    assert "delta_rewire" in C.OVERLAY_KINDS
    ring = C.design_overlay("ring", gc, tp)
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-9


def test_hierarchical_search_valid_overlay():
    gc, tp = _gaia_problem()
    ov = C.design_overlay("hierarchical", gc, tp)
    assert ov.name == "hierarchical"
    assert "hierarchical" in C.OVERLAY_KINDS
    W = overlay_delay_matrix(gc, tp, ov.edges)
    assert bool(batched_is_strongly_connected(W))
    assert np.isfinite(ov.cycle_time_ms) and ov.cycle_time_ms > 0
    for (i, j) in ov.edges:
        assert gc.has_edge(i, j)


def test_hierarchical_search_with_labels_and_incumbent():
    from repro.core.topologies import search_overlays_hierarchical

    gc, tp = _gaia_problem()
    labels = {v: k % 3 for k, v in enumerate(gc.silos)}
    ring = C.design_overlay("ring", gc, tp)
    ov = search_overlays_hierarchical(
        gc, tp, labels=labels, n_restarts=2, n_steps=16, seed=0,
        incumbent=ring)
    # the incumbent competes in the final exact pricing, so a redesign
    # can never regress below it
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-9
    for (i, j) in ov.edges:
        assert gc.has_edge(i, j)


def test_cluster_silos_modes():
    from repro.core.topologies import cluster_silos

    gc, _ = _gaia_problem()
    n = gc.num_silos
    by_delay = cluster_silos(gc)
    assert sorted(v for c in by_delay for v in c) == sorted(gc.silos)
    by_label = cluster_silos(gc, labels=[k % 4 for k in range(n)])
    assert len(by_label) == 4
    assert sorted(v for c in by_label for v in c) == sorted(gc.silos)
    one = cluster_silos(gc, n_clusters=1)
    assert one == [list(gc.silos)]


def test_sa_schedule_and_forced_engines_agree_on_quality():
    """SA acceptance tracks the best state separately, so turning the
    temperature up cannot make the result worse than the ring seed; both
    forced engines must satisfy the same guarantee."""
    gc, tp = _gaia_problem()
    ring = C.design_overlay("ring", gc, tp)
    for kw in (dict(engine="jit", sa_t0=0.0), dict(engine="jit", sa_t0=0.3),
               dict(engine="delta")):
        ov = search_overlays_jit(
            gc, tp, n_restarts=4, n_steps=24, seed=0, **kw)
        assert ov.name == "sparse_rewire"
        assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-9
