"""Topology design algorithms: optimality / approximation / structural
guarantees from Sect. 3, certified against brute force on small instances."""

import math
import random

import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as C
from repro.core.delays import ConnectivityGraph, SiloParams, TrainingParams
from repro.core.topologies import (
    algorithm1_mbst,
    brute_force_mct,
    christofides_tour,
    delta_prim,
    evaluate_overlay,
    mst_overlay,
    ring_overlay,
    star_overlay,
    two_opt_ring_overlay,
)


def random_euclidean_gc(n, seed, access=10.0, comp=5.0):
    rng = random.Random(seed)
    pts = [(rng.uniform(0, 100), rng.uniform(0, 100)) for _ in range(n)]

    def dist(a, b):
        return math.hypot(pts[a][0] - pts[b][0], pts[a][1] - pts[b][1])

    lat = {}
    bw = {}
    for i in range(n):
        for j in range(n):
            if i != j:
                lat[(i, j)] = 4.0 + dist(i, j) * 0.1
                bw[(i, j)] = 1.0
    params = {i: SiloParams(comp, access, access) for i in range(n)}
    return ConnectivityGraph(tuple(range(n)), lat, bw, params)


TP = TrainingParams(model_size_mbits=42.88, local_steps=1)


def test_mst_optimal_undirected_edge_capacitated():
    """Prop. 3.1: the MST is optimal among undirected overlays on
    edge-capacitated graphs — certified by brute force (n=5,6)."""
    for n, seed in ((5, 0), (6, 1)):
        gc = random_euclidean_gc(n, seed, access=1e5)  # huge access => edge-cap
        mst = mst_overlay(gc, TP)
        best = brute_force_mct(gc, TP, undirected=True)
        assert mst.cycle_time_ms == pytest.approx(best.cycle_time_ms, rel=1e-6)


def test_ring_within_3n_approximation():
    """Prop. 3.3/3.6: the Christofides ring is a 3N-approximation."""
    for n, seed in ((5, 2), (6, 3)):
        gc = random_euclidean_gc(n, seed)
        ring = ring_overlay(gc, TP)
        best_und = brute_force_mct(gc, TP, undirected=True)
        # optimal (directed) <= optimal undirected, so this bound is looser
        assert ring.cycle_time_ms <= 3 * n * best_und.cycle_time_ms


def test_ring_is_a_hamiltonian_cycle():
    gc = random_euclidean_gc(8, 4)
    ring = ring_overlay(gc, TP)
    assert len(ring.edges) == 8
    outs = {i for (i, _) in ring.edges}
    ins = {j for (_, j) in ring.edges}
    assert outs == set(gc.silos) and ins == set(gc.silos)
    for v in gc.silos:
        assert ring.out_degree(v) == 1 and ring.in_degree(v) == 1


def test_two_opt_never_worse_than_christofides():
    for seed in range(3):
        gc = random_euclidean_gc(9, seed)
        r0 = ring_overlay(gc, TP)
        r1 = two_opt_ring_overlay(gc, TP)
        assert r1.cycle_time_ms <= r0.cycle_time_ms + 1e-9


def test_delta_prim_degree_bound():
    gc = random_euclidean_gc(10, 5)
    for delta in (2, 3, 4):
        tree = delta_prim(gc, lambda i, j: gc.latency_ms[(i, j)], delta)
        deg = {v: 0 for v in gc.silos}
        for (u, v) in tree:
            deg[u] += 1
            deg[v] += 1
        assert max(deg.values()) <= delta
        assert len(tree) == len(gc.silos) - 1


def test_algorithm1_beats_or_matches_star_on_node_capacitated():
    """In the node-capacitated regime low-degree overlays must win."""
    gc = random_euclidean_gc(10, 6, access=0.05)  # slow access links
    star = star_overlay(gc, TP)
    mbst = algorithm1_mbst(gc, TP)
    ring = ring_overlay(gc, TP)
    assert mbst.cycle_time_ms < star.cycle_time_ms
    assert ring.cycle_time_ms < star.cycle_time_ms


def test_christofides_tour_visits_every_node_once():
    nodes = list(range(12))
    rng = random.Random(7)
    pts = {v: (rng.uniform(0, 1), rng.uniform(0, 1)) for v in nodes}

    def w(a, b):
        return math.hypot(pts[a][0] - pts[b][0], pts[a][1] - pts[b][1])

    tour = christofides_tour(nodes, w)
    assert sorted(tour) == nodes


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 8), st.integers(0, 1000))
def test_property_designed_overlays_strongly_connected(n, seed):
    gc = random_euclidean_gc(n, seed)
    from repro.core.delays import overlay_delay_digraph
    from repro.core.maxplus import is_strongly_connected

    for kind in ("mst", "ring", "delta_mbst"):
        ov = C.design_overlay(kind, gc, TP)
        dg = overlay_delay_digraph(gc, TP, ov.edges)
        assert is_strongly_connected(dg), kind


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 7), st.integers(0, 100))
def test_property_slower_access_never_helps(n, seed):
    """Cycle time is monotone in access capacity for every designer."""
    for kind in ("mst", "ring"):
        fast = C.design_overlay(kind, random_euclidean_gc(n, seed, access=10.0), TP)
        slow = C.design_overlay(kind, random_euclidean_gc(n, seed, access=0.1), TP)
        assert slow.cycle_time_ms >= fast.cycle_time_ms - 1e-9


def test_brute_force_heuristic_cut_is_opt_in_and_unsound():
    """Regression for the unsound ``r >= n + 2`` early exit.

    Minimally strong digraphs can need up to 2(N-1) arcs (bidirected
    trees), so stopping at n+2 arcs can certify a suboptimal overlay.
    Construction: hub + 4 leaves, hub<->leaf latency 1, the single
    leaf-leaf pair latency 100, bandwidth effectively unlimited.  Every
    strong overlay with <= n+2 = 7 arcs must contain a directed circuit
    of length >= 3, which must use the latency-100 link (tau >= 34);
    the bidirected star needs 8 arcs and achieves tau ~= 1.
    """
    hub, leaves = "h", ["l1", "l2", "l3", "l4"]
    silos = tuple([hub] + leaves)
    lat, bw = {}, {}

    def link(a, b, latency):
        for (i, j) in ((a, b), (b, a)):
            lat[(i, j)] = latency
            bw[(i, j)] = 1e6

    for l in leaves:
        link(hub, l, 1.0)
    link("l1", "l2", 100.0)
    params = {v: SiloParams(0.0, 1e6, 1e6) for v in silos}
    gc = ConnectivityGraph(silos, lat, bw, params)
    tp = TrainingParams(model_size_mbits=1e-6, local_steps=0)

    exact = brute_force_mct(gc, tp)  # exhaustive by default now
    cut = brute_force_mct(gc, tp, exhaustive=False)
    assert exact.cycle_time_ms == pytest.approx(1.0, rel=1e-3)
    assert cut.cycle_time_ms == pytest.approx(102.0 / 3.0, rel=1e-3)
    assert exact.cycle_time_ms < cut.cycle_time_ms
    # the certified optimum is the bidirected star
    assert set(exact.edges) == {(hub, l) for l in leaves} | {
        (l, hub) for l in leaves
    }


def test_table3_reproduction_bands():
    """Gaia / AWS-NA are rebuilt from real coordinates: our cycle times
    must land within 15% of the paper's Table 3 for MST and RING and the
    RING must beat the STAR on every network."""
    from benchmarks.common import PAPER_TABLE3, cycle_times_for_network

    for net, tol in (("gaia", 0.15), ("aws_na", 0.15)):
        ct = cycle_times_for_network(net)
        paper = PAPER_TABLE3[net]
        assert abs(ct["star"] - paper[0]) / paper[0] < tol
        assert abs(ct["mst"] - paper[2]) / paper[2] < tol
        assert abs(ct["ring"] - paper[4]) / paper[4] < tol
    for net in C.NETWORK_NAMES:
        ct = cycle_times_for_network(net, overlays=("star", "ring"))
        assert ct["ring"] < ct["star"]
