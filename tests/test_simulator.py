"""Time simulator (Algorithm 3) and its agreement with the analytic
cycle time — the paper's Thm 3.23 identity, end to end."""

import pytest

import repro.core as C
from repro.core.delays import TrainingParams
from repro.core.simulator import (
    predicted_cycle_time,
    simulate_overlay,
    training_time_ms,
)


def setup_gc(name="gaia", access=10.0, s=1):
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay(name, access_capacity_gbps=access)
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=s)
    return u, gc, tp


@pytest.mark.parametrize("kind", ["mst", "ring", "delta_mbst"])
def test_simulator_slope_matches_karp(kind):
    u, gc, tp = setup_gc()
    ov = C.design_overlay(kind, gc, tp)
    tl = simulate_overlay(gc, tp, ov.edges, num_rounds=200)
    emp = tl.empirical_cycle_time()
    assert emp == pytest.approx(ov.cycle_time_ms, rel=0.02)


def test_training_time_is_cycle_time_times_rounds_asymptotically():
    u, gc, tp = setup_gc()
    ov = C.design_overlay("ring", gc, tp)
    t100 = training_time_ms(gc, tp, ov.edges, 100)
    t200 = training_time_ms(gc, tp, ov.edges, 200)
    assert (t200 - t100) / 100 == pytest.approx(ov.cycle_time_ms, rel=0.02)


def test_ring_throughput_beats_star_in_rounds_completed():
    """The headline claim, via the simulator: within a fixed wall-clock
    budget the RING completes ~3x more rounds than the STAR on Gaia."""
    u, gc, tp = setup_gc("gaia")
    ring = C.design_overlay("ring", gc, tp)
    star = C.star_overlay(gc, tp, center=u.load_centrality_center())
    budget = 60_000.0  # 60 s
    ring_rounds = budget / ring.cycle_time_ms
    star_rounds = budget / star.cycle_time_ms
    assert ring_rounds / star_rounds > 2.5


def test_local_steps_shrink_relative_gap():
    """Fig. 4: as s grows, overlays converge (computation dominates)."""
    gaps = []
    for s in (1, 10):
        u, gc, tp = setup_gc(s=s)
        ring = C.design_overlay("ring", gc, tp)
        star = C.star_overlay(gc, tp, center=u.load_centrality_center())
        gaps.append(star.cycle_time_ms / ring.cycle_time_ms)
    assert gaps[1] < gaps[0]


def test_timeline_rounds_completed_by():
    u, gc, tp = setup_gc()
    ov = C.design_overlay("mst", gc, tp)
    tl = simulate_overlay(gc, tp, ov.edges, num_rounds=50)
    k = tl.rounds_completed_by(10 * ov.cycle_time_ms)
    assert 5 <= k <= 12
