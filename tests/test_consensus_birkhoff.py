"""Consensus matrices and Birkhoff decomposition (the topology -> TPU
collective-schedule bridge)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.birkhoff import birkhoff_decomposition, reconstruct, schedule_cost
from repro.core.consensus import (
    is_doubly_stochastic,
    local_degree_matrix,
    metropolis_matrix,
    ring_matrix,
    spectral_gap,
    star_matrix,
)


def undirected_edges(pairs):
    out = []
    for (i, j) in pairs:
        out += [(i, j), (j, i)]
    return out


def test_local_degree_rule_doubly_stochastic_on_trees():
    edges = undirected_edges([(0, 1), (1, 2), (1, 3), (3, 4)])
    A = local_degree_matrix(5, edges)
    assert is_doubly_stochastic(A)
    assert (A >= 0).all()
    # support matches overlay
    assert A[0, 2] == 0 and A[2, 0] == 0


def test_ring_matrix_doubly_stochastic():
    A = ring_matrix(6, list(range(6)))
    assert is_doubly_stochastic(A)
    assert np.allclose(np.diag(A), 0.5)


def test_star_matrix_is_full_averaging():
    A = star_matrix(5, 0)
    assert is_doubly_stochastic(A)
    w = np.random.default_rng(0).normal(size=(5, 3))
    assert np.allclose(A @ w, w.mean(0, keepdims=True))


def test_birkhoff_exact_reconstruction_ring():
    A = ring_matrix(8, list(range(8)))
    terms = birkhoff_decomposition(A)
    assert np.allclose(reconstruct(terms, 8), A, atol=1e-9)
    assert schedule_cost(terms) == 1  # a ring is ONE ppermute


def test_birkhoff_tree_cost_bounded_by_degree_plus_one():
    edges = undirected_edges([(0, 1), (1, 2), (1, 3), (3, 4), (4, 5)])
    A = local_degree_matrix(6, edges)
    terms = birkhoff_decomposition(A)
    assert np.allclose(reconstruct(terms, 6), A, atol=1e-8)
    max_deg = 3
    assert schedule_cost(terms) <= 2 * max_deg + 1


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 9), st.integers(0, 10_000))
def test_property_birkhoff_roundtrip_random_ds(n, seed):
    """Random doubly stochastic (Sinkhorn) matrices decompose and
    reconstruct exactly; coefficients form a distribution."""
    rng = np.random.default_rng(seed)
    A = rng.random((n, n)) + 0.05
    for _ in range(200):
        A /= A.sum(1, keepdims=True)
        A /= A.sum(0, keepdims=True)
    terms = birkhoff_decomposition(A, tol=1e-10)
    R = reconstruct(terms, n)
    assert np.allclose(R, A, atol=1e-6)
    coeffs = np.array([c for c, _ in terms])
    assert coeffs.sum() == pytest.approx(1.0, abs=1e-9)
    assert (coeffs > 0).all()


def test_spectral_gap_ordering():
    """Denser consensus mixes faster: star > ring > chain in gap."""
    n = 8
    star = star_matrix(n, 0)
    ring = ring_matrix(n, list(range(n)))
    chain_edges = undirected_edges([(i, i + 1) for i in range(n - 1)])
    chain = local_degree_matrix(n, chain_edges)
    g_star, g_ring, g_chain = map(spectral_gap, (star, ring, chain))
    assert g_star > g_ring > g_chain > 0


def test_consensus_converges_to_mean():
    n = 8
    A = ring_matrix(n, list(range(n)))
    w = np.random.default_rng(1).normal(size=(n, 4))
    target = w.mean(0)
    x = w.copy()
    for _ in range(400):
        x = A @ x
    assert np.allclose(x, target, atol=1e-6)
