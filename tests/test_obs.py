"""Observability package tests: span nesting and the near-zero disabled
path, metrics snapshot/reset, JSONL flight-recorder schema round-trips,
the report renderers, and — closing the loop — the controller emitting a
complete decision record on a forced regression (the Gaia link-failure
scenario from ``examples/dynamic_topology.py``)."""

import io
import json
import time

import pytest

import repro.core as C
from repro.core import TrainingParams
from repro.dynamics import (
    ControllerConfig,
    DynamicTimeline,
    OnlineTopologyController,
    active_subgraph,
    link_failure_scenario,
)
from repro.obs import events, log, metrics, report, spans


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts and ends with obs disabled and empty."""
    spans.disable()
    spans.reset()
    metrics.reset()
    yield
    spans.disable()
    spans.reset()
    metrics.reset()


# ---------------------------------------------------------------------------
# Spans


class TestSpans:
    def test_disabled_span_is_the_shared_noop(self):
        assert spans.span("x") is spans.span("y")
        with spans.span("x") as s:
            s.set(ignored=1)
        assert spans.summary() == {}

    def test_disabled_path_overhead_is_near_zero(self):
        n = 200_000
        t0 = time.perf_counter()
        for _ in range(n):
            with spans.span("hot"):
                pass
        per_call = (time.perf_counter() - t0) / n
        # one flag read + a shared context manager; budget is generous
        # (CI jitter) but still catches an accidental allocation path
        assert per_call < 5e-6, f"{per_call*1e6:.2f}us per disabled span"
        assert spans.summary() == {}

    def test_nesting_records_parent_and_depth(self):
        spans.enable()
        with spans.span("outer"):
            with spans.span("inner"):
                pass
        recs = {r.name: r for r in spans.pop_finished()}
        assert recs["outer"].parent is None and recs["outer"].depth == 0
        assert recs["inner"].parent == "outer" and recs["inner"].depth == 1

    def test_summary_aggregates_count_total_max(self):
        spans.enable()
        for _ in range(3):
            with spans.span("agg"):
                pass
        s = spans.summary()["agg"]
        assert s["count"] == 3
        assert s["total_s"] >= s["max_s"] >= 0
        assert s["mean_s"] == pytest.approx(s["total_s"] / 3)

    def test_span_fn_decorator_only_times_when_enabled(self):
        @spans.span_fn("decorated")
        def f(x):
            return x + 1

        assert f(1) == 2
        assert "decorated" not in spans.summary()
        spans.enable()
        assert f(2) == 3
        assert spans.summary()["decorated"]["count"] == 1

    def test_attrs_land_on_the_record(self):
        spans.enable()
        with spans.span("job", phase="init") as s:
            s.set(items=4)
        (rec,) = spans.pop_finished()
        assert rec.attrs == {"phase": "init", "items": 4}

    def test_reset_clears_aggregate_and_ring(self):
        spans.enable()
        with spans.span("gone"):
            pass
        spans.reset()
        assert spans.summary() == {} and spans.pop_finished() == []


# ---------------------------------------------------------------------------
# Metrics


class TestMetrics:
    def test_counter_gauge_histogram_snapshot(self):
        metrics.counter("c").inc()
        metrics.counter("c").inc(2)
        metrics.gauge("g").set(7.5)
        for v in range(10):
            metrics.histogram("h").observe(float(v))
        snap = metrics.snapshot()
        assert snap["c"] == 3  # counters/gauges snapshot as bare scalars
        assert snap["g"] == 7.5
        h = snap["h"]
        assert h["count"] == 10 and h["min"] == 0.0 and h["max"] == 9.0
        assert h["p50"] <= h["p95"] <= h["max"]

    def test_same_name_same_instrument(self):
        assert metrics.counter("x") is metrics.counter("x")

    def test_kind_mismatch_raises(self):
        metrics.counter("typed")
        with pytest.raises(TypeError):
            metrics.gauge("typed")

    def test_reset_empties_registry(self):
        metrics.counter("tmp").inc()
        metrics.reset()
        assert metrics.snapshot() == {}


# ---------------------------------------------------------------------------
# Flight recorder / schema


class TestFlightRecorder:
    def test_round_trip_validates(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        with events.FlightRecorder(p, meta={"test": True},
                                   silo_names=["a", "b"]) as rec:
            rec.emit("epoch", index=0, t_start_ms=0.0, active=[0, 1])
            rec.emit("round", step=0, duration_ms=10.0,
                     predicted_window_ms=9.0, measured_window_ms=None,
                     drift=None)
        records, problems = events.validate_trace(p)
        assert problems == []
        assert [r["kind"] for r in records] == [
            "run_start", "epoch", "round", "run_end"]
        meta = records[0]["meta"]
        assert meta["schema_version"] == events.TRACE_SCHEMA_VERSION
        assert meta["test"] is True and meta["silo_names"] == ["a", "b"]
        # run_end embeds the metrics snapshot and span summary
        assert set(records[-1]) >= {"metrics", "spans", "summary"}

    def test_unknown_kind_and_missing_field_raise_at_emit(self, tmp_path):
        rec = events.FlightRecorder(str(tmp_path / "t.jsonl"))
        with pytest.raises(ValueError, match="unknown"):
            rec.emit("nope")
        with pytest.raises(ValueError, match="missing required"):
            rec.emit("epoch", index=0)  # no t_start_ms/active
        rec.close()
        with pytest.raises(ValueError, match="closed"):
            rec.emit("epoch", index=0, t_start_ms=0.0, active=[])

    def test_validator_catches_corruption(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        with events.FlightRecorder(p):
            pass
        records = events.read_trace(p)
        records[0]["seq"] = 5  # break seq contiguity
        with open(p, "w") as fh:
            for r in records:
                fh.write(json.dumps(r) + "\n")
        _, problems = events.validate_trace(p)
        assert any("seq" in pr for pr in problems)

    def test_numpy_payloads_serialize(self, tmp_path):
        np = pytest.importorskip("numpy")
        p = str(tmp_path / "t.jsonl")
        with events.FlightRecorder(p) as rec:
            rec.emit("epoch", index=np.int64(1),
                     t_start_ms=np.float64(2.5),
                     active=np.arange(3))
        (_, ep, _) = events.read_trace(p)
        assert ep["index"] == 1 and ep["active"] == [0, 1, 2]

    def test_run_metadata_never_initializes_jax(self):
        # jax may or may not be imported by earlier tests; either way the
        # helper reports without forcing an XLA client into existence.
        meta = events.run_metadata()
        assert meta["device_kind"] in ("cpu", "gpu", "tpu", "uninitialized",
                                       "unknown")
        assert meta["schema_version"] == events.TRACE_SCHEMA_VERSION


# ---------------------------------------------------------------------------
# Structured logger


class TestLog:
    def test_human_line_and_jsonl_share_fields(self, tmp_path):
        stream = io.StringIO()
        jp = str(tmp_path / "log.jsonl")
        lg = log.StructuredLogger("t", stream=stream, jsonl_path=jp)
        lg.info("swap", "plan moved", version=3)
        lg.debug("hidden")  # below the default info level
        assert "[t] swap plan moved version=3" in stream.getvalue()
        assert "hidden" not in stream.getvalue()
        (rec,) = [json.loads(ln) for ln in open(jp)]
        assert rec["event"] == "swap" and rec["version"] == 3

    def test_get_logger_is_a_singleton_registry(self):
        assert log.get_logger("same") is log.get_logger("same")


# ---------------------------------------------------------------------------
# Report rendering


def _write_trace(path, redesign_kw=None):
    with events.FlightRecorder(str(path), silo_names=["x", "y", "z"]) as rec:
        rec.emit("epoch", index=0, t_start_ms=0.0, active=[0, 1, 2])
        rec.emit("round", step=0, duration_ms=12.0,
                 predicted_window_ms=10.0, measured_window_ms=11.0,
                 drift=0.1)
        kw = dict(round_idx=5, winner="fixed", name="ring",
                  predicted_tau_ms=10.0, measured_ms=13.0,
                  expected_window_ms=11.0, drift=0.18, n_candidates=100,
                  elapsed_s=0.2, bottleneck=[0, 2, 0],
                  bottleneck_names=["x", "z", "x"], membership=None)
        kw.update(redesign_kw or {})
        rec.emit("redesign", **kw)
    return str(path)


class TestReport:
    def test_timeline_and_bottlenecks_render(self, tmp_path):
        trace = report.load_trace(_write_trace(tmp_path / "t.jsonl"))
        out = report.render_report(trace)
        assert "controller actuations" in out
        assert "x-z-x" in out  # circuit by silo name
        assert "ring" in out

    def test_check_trace_flags_problems(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        p.write_text('{"v": 1, "seq": 0, "kind": "epoch"}\n')
        ok, lines = report.check_trace(str(p))
        assert not ok and any("problem" in ln for ln in lines)

    def test_diff_reports_circuit_change(self, tmp_path):
        a = report.load_trace(_write_trace(tmp_path / "a.jsonl"))
        b = report.load_trace(_write_trace(
            tmp_path / "b.jsonl",
            redesign_kw=dict(bottleneck=[0, 1, 0],
                             bottleneck_names=["x", "y", "x"])))
        out = report.diff_traces(a, b)
        assert "DIFFER" in out
        same = report.diff_traces(a, a)
        assert "structurally identical" in same


# ---------------------------------------------------------------------------
# Controller decision records (forced regression, Gaia link failure)


def test_controller_emits_complete_decision_record(tmp_path):
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("gaia")
    gc0 = u.connectivity_graph(comp_time_ms=Tc)
    overlay = C.design_overlay("ring", gc0, tp)
    names = [name for name, _ in C.GAIA_SITES]
    deadline_ms = 400 * overlay.cycle_time_ms
    scenario = link_failure_scenario(
        u, Tc, t_fail_ms=deadline_ms / 3, overlay_edges=overlay.edges,
        horizon_ms=deadline_ms)
    timeline = DynamicTimeline(scenario, tp)
    timeline.set_overlay(overlay.edges)
    p = str(tmp_path / "ctl.jsonl")
    recorder = events.FlightRecorder(p, silo_names=names)
    timeline.attach_recorder(recorder)
    controller = OnlineTopologyController(
        gc0, tp, overlay,
        config=ControllerConfig(seed=0, rewire_restarts=0),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active),
        recorder=recorder,
        silo_names=names,
    )
    redesign = None
    while timeline.now_ms < deadline_ms and redesign is None:
        redesign = controller.observe_round(timeline.step())
    recorder.close()
    assert redesign is not None, "link failure never tripped the detector"

    records, problems = events.validate_trace(p)
    assert problems == []
    by_kind = {}
    for r in records:
        by_kind.setdefault(r["kind"], []).append(r)
    # the failure crosses one epoch boundary: both epochs recorded
    assert [e["index"] for e in by_kind["epoch"]] == [0, 1]
    # the strike detector left its audit trail before actuating
    (reg,) = by_kind["regression"]
    assert reg["strikes"] >= controller.config.patience
    assert reg["measured_ms"] > reg["expected_window_ms"]
    (rd,) = by_kind["redesign"]
    assert rd["round_idx"] == redesign.round_idx
    assert rd["winner"] == "fixed" and rd["name"] == redesign.overlay.name
    assert rd["n_candidates"] == redesign.n_candidates
    assert rd["drift"] == pytest.approx(redesign.drift)
    assert rd["expected_window_ms"] == pytest.approx(
        redesign.expected_window_ms)
    # satellite contract: predicted-vs-measured drift is assertable from
    # the Redesign record itself
    assert redesign.drift == pytest.approx(
        redesign.measured_ms / redesign.expected_window_ms - 1.0)
    # bottleneck attribution carries real Gaia site names
    assert rd["bottleneck"] == list(redesign.bottleneck)
    assert rd["bottleneck_names"] == [names[s] for s in redesign.bottleneck]
    assert set(rd["bottleneck_names"]) <= set(names)
    # metrics side-channel moved too
    snap = metrics.snapshot()
    assert snap["controller.redesigns"] == 1
    assert snap["controller.regressions"] == 1
    # and the report renders site names end to end
    out = report.render_report(report.load_trace(p))
    assert "saopaulo" in out or "sydney" in out or "virginia" in out
