"""Docs gate: the README quickstart must run as-is, and docs must not
reference files that do not exist.

Run standalone by scripts/ci.sh before the full suite — a broken
quickstart or a dead cross-reference fails CI even if the library
itself is healthy.
"""

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

# backtick-quoted or markdown-linked tokens that look like repo paths
_PATH_EXTS = (".py", ".md", ".sh", ".json", ".txt", ".toml")


def _python_blocks(md_text: str):
    """Fenced ```python blocks, in document order."""
    return re.findall(r"```python\n(.*?)```", md_text, flags=re.DOTALL)


def test_readme_quickstart_runs():
    """Execute every ```python block of README.md in one shared
    namespace (later blocks may build on earlier ones)."""
    readme = (REPO / "README.md").read_text()
    blocks = _python_blocks(readme)
    assert blocks, "README.md has no ```python quickstart block"
    ns: dict = {}
    for block in blocks:
        exec(compile(block, "README.md", "exec"), ns)
    # the quickstart designed a real overlay and built a gossip plan
    assert ns["ring"].cycle_time_ms < ns["star"].cycle_time_ms
    assert ns["plan"].n_silos == ns["gc"].num_silos


def _referenced_paths(md_text: str):
    # markdown links to local files: [text](path)
    for m in re.finditer(r"\]\(([^)#]+)\)", md_text):
        target = m.group(1).strip()
        if not target.startswith(("http://", "https://", "mailto:")):
            yield target
    # backticked repo paths: `src/.../file.py`
    for m in re.finditer(r"`([^`\s]+)`", md_text):
        token = m.group(1)
        if "/" in token and token.endswith(_PATH_EXTS) and "*" not in token:
            yield token


@pytest.mark.parametrize(
    "doc",
    sorted(
        str(p.relative_to(REPO))
        for p in [REPO / "README.md", *(REPO / "docs").glob("*.md")]
    ),
)
def test_docs_cross_references_resolve(doc):
    """Every repo-path mentioned in README.md / docs/*.md must exist."""
    base = (REPO / doc).parent
    missing = []
    for ref in _referenced_paths((REPO / doc).read_text()):
        # relative to the doc's directory, falling back to the repo root
        if not ((base / ref).exists() or (REPO / ref).exists()):
            missing.append(ref)
    assert not missing, f"{doc} references missing files: {missing}"


def test_docs_obs_schema_in_sync():
    """The record-kinds table in the Observability section of
    docs/architecture.md must list exactly the kinds in
    ``repro.obs.events.SCHEMA`` — a new kind without docs (or a
    documented kind that no longer exists) fails the gate."""
    from repro.obs.events import SCHEMA

    text = (REPO / "docs" / "architecture.md").read_text()
    m = re.search(r"## Observability.*?(?=\n## |\Z)", text, flags=re.DOTALL)
    assert m, "docs/architecture.md has no '## Observability' section"
    section = m.group(0)
    # first backticked token of each table row is the record kind
    documented = {
        row.group(1)
        for row in re.finditer(r"^\| `([a-z_]+)` \|", section, flags=re.M)
    }
    assert documented == set(SCHEMA), (
        f"docs/architecture.md record-kinds table out of sync with "
        f"repro.obs.events.SCHEMA: undocumented={set(SCHEMA) - documented}, "
        f"stale={documented - set(SCHEMA)}"
    )


def test_docs_protocol_table_in_sync():
    """The 'Protocol machines' table in docs/architecture.md must match
    the registered typestate machines exactly, in both directions:
    every registered protocol documented, every documented row backed
    by a machine, and the states / error-transition / contract cells
    equal to ``protocol_table_row`` of the live declaration."""
    from repro.analysis.protocols import protocol_table_row
    from repro.analysis.rules import PROTOCOL_RULES

    text = (REPO / "docs" / "architecture.md").read_text()
    m = re.search(r"### Protocol machines.*?(?=\n## |\n### |\Z)", text,
                  flags=re.DOTALL)
    assert m, "docs/architecture.md has no 'Protocol machines' table"
    section = m.group(0)
    documented = {}
    for row in re.finditer(
            r"^\| `([\w-]+)` \| ([^|]+) \| ([^|]+) \| ([^|]+) \|",
            section, flags=re.M):
        rule_id, states, errors, desc = (c.strip() for c in row.groups())
        documented[rule_id] = (rule_id, states, errors, desc)
    registered = {rid: protocol_table_row(proto)
                  for rid, proto in PROTOCOL_RULES.items()}
    assert set(documented) == set(registered), (
        f"protocol table out of sync: "
        f"undocumented={set(registered) - set(documented)}, "
        f"stale={set(documented) - set(registered)}")
    for rid in registered:
        assert documented[rid] == registered[rid], (
            f"protocol table row for {rid} differs from the live "
            f"machine:\n  docs: {documented[rid]}\n"
            f"  code: {registered[rid]}")
