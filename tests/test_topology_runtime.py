"""plan_from_overlay: ring-tour recovery must survive non-contiguous and
non-integer silo labels, and fail loudly on malformed rings."""

import numpy as np
import pytest

from repro.core.topologies import Overlay
from repro.fed.topology_runtime import plan_from_overlay


def _ring(edges):
    return Overlay(name="ring", edges=tuple(edges), cycle_time_ms=1.0)


def test_string_labeled_ring():
    ov = _ring([("tokyo", "paris"), ("paris", "lyon"), ("lyon", "tokyo")])
    plan = plan_from_overlay(ov, 3)
    A = plan.matrix
    assert A.shape == (3, 3)
    # (I + P)/2: doubly stochastic with exactly two 1/2 entries per row
    np.testing.assert_allclose(A.sum(0), 1.0)
    np.testing.assert_allclose(A.sum(1), 1.0)
    assert np.count_nonzero(A) == 6
    # order pinning: explicit silo order must transpose consistently
    plan2 = plan_from_overlay(ov, 3, silos=["paris", "lyon", "tokyo"])
    assert plan2.matrix.shape == (3, 3)


def test_ring_not_through_node_zero_and_sparse_ids():
    # silo ids 5, 17, 42 — no node 0, not contiguous
    ov = _ring([(17, 42), (42, 5), (5, 17)])
    plan = plan_from_overlay(ov, 3)
    np.testing.assert_allclose(plan.matrix.sum(0), 1.0)
    assert plan.num_transfers == 1  # a ring is a single ppermute


def test_broken_ring_raises_instead_of_hanging():
    # walk closes early: 2-cycle + isolated pair => not one Hamiltonian tour
    ov = _ring([("a", "b"), ("b", "a"), ("c", "d"), ("d", "c")])
    with pytest.raises(ValueError, match="ring"):
        plan_from_overlay(ov, 4)


def test_double_out_degree_raises():
    ov = _ring([("a", "b"), ("a", "c"), ("b", "a"), ("c", "a")])
    with pytest.raises(ValueError, match="out-degree"):
        plan_from_overlay(ov, 3)


def test_silo_count_mismatch_raises():
    ov = _ring([("a", "b"), ("b", "a")])
    with pytest.raises(ValueError, match="n_silos"):
        plan_from_overlay(ov, 5)


def test_non_ring_overlays_with_string_labels():
    edges = [("a", "b"), ("b", "a"), ("b", "c"), ("c", "b")]
    ov = Overlay(name="mst", edges=tuple(edges), cycle_time_ms=1.0)
    plan = plan_from_overlay(ov, 3)
    A = plan.matrix
    np.testing.assert_allclose(A.sum(0), 1.0)
    np.testing.assert_allclose(A.sum(1), 1.0)
