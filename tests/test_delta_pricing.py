"""DeltaPricer: the incremental cycle-time certificate must agree with
full Karp from scratch after *any* move sequence — bit-identical under
f64, within tolerance under f32 — including moves that disconnect and
reconnect the graph."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as C
from repro.core.maxplus_sparse import (
    NEG_INF,
    DeltaPricer,
    EdgeBatch,
    batched_cycle_time_sparse,
)
from repro.core.topologies import search_overlays_delta, search_overlays_jit


def _fresh_tau(dp: DeltaPricer, n: int) -> float:
    """Full Karp from scratch on the pricer's current graph."""
    src, dst, w = dp.graph()
    return float(batched_cycle_time_sparse(EdgeBatch(
        src[None].astype(np.int32), dst[None].astype(np.int32),
        w[None].astype(np.float64), n))[0])


def _initial_graph(rng, n, slots, integer):
    """Slot arrays: ring + random arcs in [0, slots), self-loops after.
    Integer weights make every Karp quantity exactly representable, so
    f64 agreement can be asserted bitwise."""
    S = slots + n
    src = np.zeros(S, dtype=np.int64)
    dst = np.zeros(S, dtype=np.int64)

    def draw_w(k):
        if integer:
            return rng.integers(1, 50, size=k).astype(np.float64)
        return rng.uniform(0.5, 50.0, size=k)

    w = np.full(S, NEG_INF, dtype=np.float64)
    src[:n] = np.arange(n)
    dst[:n] = (np.arange(n) + 1) % n  # ring keeps it strongly connected
    w[:n] = draw_w(n)
    for s in range(n, slots):
        if rng.random() < 0.5:
            u, v = rng.integers(0, n, size=2)
            src[s], dst[s], w[s] = u, v, draw_w(1)[0]
    src[slots:] = dst[slots:] = np.arange(n)  # comp self-loops
    w[slots:] = draw_w(n)
    return src, dst, w


def _random_moves(rng, dp, n, slots, n_moves, integer):
    """Apply random slot rewrites (swap endpoints / re-weight / drop /
    revive), checking tau against the from-scratch oracle after each."""
    mismatch = 0.0
    for _ in range(n_moves):
        k = int(rng.integers(1, 3))  # 1-2 slots per move (2-opt shape)
        sl = rng.choice(slots, size=k, replace=False).astype(np.int64)
        su = rng.integers(0, n, size=k)
        du = rng.integers(0, n, size=k)
        if integer:
            wu = rng.integers(1, 50, size=k).astype(np.float64)
        else:
            wu = rng.uniform(0.5, 50.0, size=k)
        drop = rng.random(size=k) < 0.3  # disconnect pressure
        wu = np.where(drop, np.full(k, NEG_INF), wu)
        dp.update(sl, su, du, wu)
        mismatch = max(mismatch, abs(dp.tau - _fresh_tau(dp, n)))
    return mismatch


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 10), st.integers(0, 2 ** 31 - 1))
def test_delta_tau_bit_identical_to_full_karp_f64(n, seed):
    rng = np.random.default_rng(seed)
    slots = 3 * n
    src, dst, w = _initial_graph(rng, n, slots, integer=True)
    dp = DeltaPricer(src, dst, w, n)
    assert dp.tau == _fresh_tau(dp, n)
    mismatch = _random_moves(rng, dp, n, slots, n_moves=40, integer=True)
    assert mismatch == 0.0, f"delta tau drifted from Karp by {mismatch}"
    assert sum(dp.stats.values()) >= 40  # every commit took *some* path


def test_fast_path_actually_fires():
    """Certificate reuse is the speedup: on a 16-node graph random
    single-slot moves must mostly price without a full Karp pass."""
    rng = np.random.default_rng(2)
    n, slots = 16, 48
    src, dst, w = _initial_graph(rng, n, slots, integer=True)
    dp = DeltaPricer(src, dst, w, n)
    mismatch = _random_moves(rng, dp, n, slots, n_moves=60, integer=True)
    assert mismatch == 0.0
    assert dp.stats["fast"] > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 10), st.integers(0, 2 ** 31 - 1))
def test_delta_tau_matches_full_karp_continuous_weights(n, seed):
    rng = np.random.default_rng(seed)
    slots = 3 * n
    src, dst, w = _initial_graph(rng, n, slots, integer=False)
    dp = DeltaPricer(src, dst, w, n)
    mismatch = _random_moves(rng, dp, n, slots, n_moves=30, integer=False)
    assert mismatch <= 1e-9 * 50.0


def test_f32_pricer_stays_within_tolerance_and_reanchors():
    rng = np.random.default_rng(11)
    n, slots = 8, 24
    src, dst, w = _initial_graph(rng, n, slots, integer=False)
    dp = DeltaPricer(src, dst, w, n, dtype=np.float32)
    for t in range(30):
        sl = np.array([int(rng.integers(0, slots))])
        dp.update(sl, rng.integers(0, n, 1), rng.integers(0, n, 1),
                  rng.uniform(0.5, 50.0, 1))
        if (t + 1) % 10 == 0:
            dp.reanchor()
        assert abs(dp.tau - _fresh_tau(dp, n)) <= 1e-3 * 50.0
    assert dp.stats["reanchor"] >= 3


def test_price_does_not_mutate_until_commit():
    rng = np.random.default_rng(5)
    n, slots = 6, 18
    src, dst, w = _initial_graph(rng, n, slots, integer=True)
    dp = DeltaPricer(src, dst, w, n)
    tau0 = dp.tau
    g0 = dp.graph()
    pm = dp.price(np.array([0]), np.array([2]), np.array([4]),
                  np.array([40.0]))
    assert dp.tau == tau0
    for a, b in zip(dp.graph(), g0):
        np.testing.assert_array_equal(a, b)
    dp.commit(pm)
    assert dp.tau == _fresh_tau(dp, n)


def test_force_full_is_the_oracle():
    rng = np.random.default_rng(9)
    n, slots = 7, 21
    src, dst, w = _initial_graph(rng, n, slots, integer=True)
    dp = DeltaPricer(src, dst, w, n)
    sl = np.array([1, 2])
    su, du = np.array([0, 3]), np.array([2, 5])
    wu = np.array([10.0, 20.0])
    fast = dp.price(sl, su, du, wu)
    full = dp.price(sl, su, du, wu, force_full=True)
    assert full.kind == "reanchor"
    assert fast.tau == full.tau


# --- the delta-engine search built on the pricer --------------------------


def _gaia_problem():
    u = C.make_underlay("gaia")
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    return u.connectivity_graph(comp_time_ms=Tc), tp


def test_search_overlays_delta_matches_jit_quality_on_gaia():
    gc, tp = _gaia_problem()
    stats = {}
    ov = search_overlays_delta(gc, tp, n_restarts=4, n_steps=300,
                               delta_max=3, seed=0, stats_out=stats)
    assert ov.name == "delta_rewire"
    ring = C.design_overlay("ring", gc, tp)
    assert ov.cycle_time_ms <= ring.cycle_time_ms + 1e-9
    for (i, j) in ov.edges:
        assert gc.has_edge(i, j)
    assert stats["proposals"] == 4 * 300
    # the whole point: most accepted proposals avoid the full-Karp path
    assert stats["fast"] + stats["propagated"] > stats["reanchor"]


def test_search_overlays_delta_full_pricing_same_quality():
    gc, tp = _gaia_problem()
    dl = search_overlays_delta(gc, tp, n_restarts=2, n_steps=150, seed=3)
    fl = search_overlays_delta(gc, tp, n_restarts=2, n_steps=150, seed=3,
                               pricing="full")
    assert np.isfinite(dl.cycle_time_ms) and np.isfinite(fl.cycle_time_ms)
    ring = C.design_overlay("ring", gc, tp)
    assert dl.cycle_time_ms <= ring.cycle_time_ms + 1e-9
    assert fl.cycle_time_ms <= ring.cycle_time_ms + 1e-9


def test_search_jit_auto_delegates_to_delta_above_threshold(monkeypatch):
    import repro.core.topologies as T

    gc, tp = _gaia_problem()
    called = {}
    orig = T.search_overlays_delta

    def spy(*a, **kw):
        called["yes"] = True
        return orig(*a, **kw)

    monkeypatch.setattr(T, "search_overlays_delta", spy)
    monkeypatch.setattr(T, "_DELTA_ENGINE_MIN_N", 2)
    ov = search_overlays_jit(gc, tp, n_restarts=2, n_steps=16, seed=0)
    assert called.get("yes") and ov.name == "sparse_rewire"
    called.clear()
    monkeypatch.setattr(T, "_DELTA_ENGINE_MIN_N", 10_000)
    ov = search_overlays_jit(gc, tp, n_restarts=2, n_steps=16, seed=0,
                             engine="delta")
    assert called.get("yes") and ov.name == "sparse_rewire"
