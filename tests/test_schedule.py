"""Schedule subsystem: randomized plan distributions as first-class
citizens of the batched engines.

Key identities under test:

* the vectorized ``MatchaSchedule`` τ̄ reproduces the legacy scalar
  ``Matcha.average_cycle_time`` oracle on equal seeds (the acceptance
  identity — the masks consume the same ``random.Random`` stream and the
  pricing/recursion are the same f64 operations);
* the budgets × seeds batched sweep equals per-schedule pricing;
* ``round_edges`` is a pure function of (schedule, round counter), so
  silos sharing the counter derive identical per-round gossip plans with
  no coordination (``ScheduleSlot`` cross-silo determinism);
* the unique-rounds / time-varying edge-list recursion agrees with
  per-round dense recursion steps, and its JAX twin with numpy;
* ``critical_circuit_sparse`` agrees with the dense extractor oracle;
* budget validation kills the ``budget <= 0`` infinite resample loop at
  construction (legacy ``Matcha`` and ``MatchaSchedule`` alike).
"""

import math
import random

import numpy as np
import pytest

import repro.core as C
from repro.core.delays import TrainingParams, overlay_delay_matrix
from repro.core.matcha import Matcha, greedy_edge_coloring
from repro.core.maxplus_sparse import (
    batched_overlay_delay_edges,
    critical_circuit_sparse,
    dense_to_edge_batch,
    timing_recursion_time_varying_sparse,
    timing_recursion_time_varying_sparse_jax,
    timing_recursion_unique_rounds_sparse,
)
from repro.core.maxplus_vec import (
    NEG_INF,
    batched_timing_recursion,
    critical_circuit_dense,
    timing_recursion_dense,
)
from repro.core.schedule import (
    FixedSchedule,
    MatchaSchedule,
    average_cycle_times_batched,
    design_matcha_schedule,
)
from repro.fed.gossip import ScheduleSlot


def gaia_setup(s=1):
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=s)
    return u, gc, tp


# ---------------------------------------------------------------------------
# Budget validation (the sample_round infinite-loop fix)


@pytest.mark.parametrize("budget", [0.0, -0.5, 1.5, 2])
def test_budget_outside_unit_interval_rejected_at_construction(budget):
    with pytest.raises(ValueError, match="budget"):
        Matcha(matchings=[[(0, 1)]], budget=budget)
    with pytest.raises(ValueError, match="budget"):
        MatchaSchedule(matchings=(((0, 1),),), budget=budget)


def test_budget_one_is_valid_and_always_activates_everything():
    m = Matcha(matchings=[[(0, 1)], [(2, 3)]], budget=1.0)
    assert sorted(m.sample_round(random.Random(0))) == [(0, 1), (2, 3)]
    s = MatchaSchedule(matchings=(((0, 1),), ((2, 3),)), budget=1.0)
    assert s.round_active(17) == (0, 1)


def test_empty_matchings_rejected():
    with pytest.raises(ValueError, match="matching"):
        MatchaSchedule(matchings=(), budget=0.5)


# ---------------------------------------------------------------------------
# Acceptance: seeded vectorized tau-bar == legacy scalar oracle


@pytest.mark.parametrize("network", ["gaia", "aws_na"])
@pytest.mark.parametrize("budget", [0.1, 0.5, 1.0])
def test_vectorized_tau_matches_legacy_oracle(network, budget):
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay(network)
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    for seed in (0, 7):
        legacy = C.matcha_plus_from_underlay(u, budget).average_cycle_time(
            gc, tp, rounds=80, seed=seed
        )
        est = C.matcha_schedule_from_underlay(u, budget).price(
            gc, tp, rounds=80, seeds=(seed,)
        )
        assert est.tau_ms == pytest.approx(legacy, rel=1e-6)


def test_connectivity_schedule_matches_legacy_oracle_with_local_steps():
    u, gc, tp = gaia_setup(s=3)
    legacy = C.matcha_from_connectivity(gc, 0.4).average_cycle_time(
        gc, tp, rounds=60, seed=5
    )
    est = C.matcha_schedule_from_connectivity(gc, 0.4).price(
        gc, tp, rounds=60, seeds=(5,)
    )
    assert est.tau_ms == pytest.approx(legacy, rel=1e-6)


@pytest.mark.slow  # Monte-Carlo schedule sweep: ci.sh --fast skips
def test_batched_sweep_equals_per_schedule_pricing():
    u, gc, tp = gaia_setup()
    budgets = (0.2, 0.6, 1.0)
    seeds = (0, 1)
    scheds = [C.matcha_schedule_from_underlay(u, b) for b in budgets]
    grid = average_cycle_times_batched(scheds, gc, tp, rounds=50, seeds=seeds)
    assert grid.shape == (3, 2)
    for i, s in enumerate(scheds):
        for j, seed in enumerate(seeds):
            solo = s.price(gc, tp, rounds=50, seeds=(seed,))
            assert grid[i, j] == pytest.approx(solo.tau_ms, rel=1e-12)


def test_schedule_estimate_confidence_interval():
    u, gc, tp = gaia_setup()
    s = C.matcha_schedule_from_underlay(u, 0.3)
    est = s.price(gc, tp, rounds=60, seeds=(0, 1, 2, 3))
    assert len(est.per_seed_ms) == 4
    assert est.tau_ms == pytest.approx(np.mean(est.per_seed_ms))
    assert est.ci95_ms > 0
    single = s.price(gc, tp, rounds=60, seeds=(0,))
    assert single.ci95_ms == 0.0


@pytest.mark.slow  # Monte-Carlo schedule sweep: ci.sh --fast skips
def test_budget_sweep_picks_the_smallest_mean_tau():
    u, gc, tp = gaia_setup()
    budgets = (0.2, 0.5, 1.0)
    best, est = design_matcha_schedule(
        gc, tp, budgets=budgets, rounds=60, seeds=(0, 1)
    )
    scheds = [
        MatchaSchedule(matchings=best.matchings, budget=b) for b in budgets
    ]
    grid = average_cycle_times_batched(scheds, gc, tp, rounds=60, seeds=(0, 1))
    means = grid.mean(axis=1)
    assert best.budget == budgets[int(np.argmin(means))]
    assert est.tau_ms == pytest.approx(means.min())


# ---------------------------------------------------------------------------
# FixedSchedule degenerate case


def test_fixed_schedule_prices_exactly_and_never_varies():
    u, gc, tp = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    fs = FixedSchedule(ring)
    assert not fs.is_randomized and fs.name == "ring"
    est = fs.price(gc, tp)
    assert est.tau_ms == pytest.approx(ring.cycle_time_ms) and est.ci95_ms == 0
    assert fs.round_edges(0) == ring.edges == fs.round_edges(123)
    W = overlay_delay_matrix(gc, tp, ring.edges)
    ref = np.diff(timing_recursion_dense(W, 40).max(axis=1))
    np.testing.assert_allclose(fs.simulate_rounds(gc, tp, 40), ref, rtol=1e-12)


# ---------------------------------------------------------------------------
# Round-counter sampling determinism (the cross-silo contract)


def test_round_edges_deterministic_across_instances_and_varying():
    u, gc, tp = gaia_setup()
    a = C.matcha_schedule_from_underlay(u, 0.4, sample_seed=9)
    b = C.matcha_schedule_from_underlay(u, 0.4, sample_seed=9)
    assert all(a.round_edges(k) == b.round_edges(k) for k in range(40))
    assert any(a.round_edges(k) != a.round_edges(k + 1) for k in range(20))
    c = C.matcha_schedule_from_underlay(u, 0.4, sample_seed=10)
    assert any(a.round_edges(k) != c.round_edges(k) for k in range(20))
    # every sampled round is nonempty (Appendix G.3 resampling)
    assert all(len(a.round_edges(k)) > 0 for k in range(40))


def test_schedule_slot_cross_silo_determinism():
    u, gc, tp = gaia_setup()
    mk = lambda: ScheduleSlot(
        C.matcha_schedule_from_underlay(u, 0.4, sample_seed=3), gc.num_silos
    )
    silo_a, silo_b = mk(), mk()  # two silos, no shared state
    for k in (0, 1, 2, 9, 33):
        A = silo_a.matrix_for_round(k)
        assert np.array_equal(A, silo_b.matrix_for_round(k))
        assert np.allclose(A.sum(axis=0), 1.0)
        assert np.allclose(A.sum(axis=1), 1.0)
        pa, pb = silo_a.plan_for_round(k), silo_b.plan_for_round(k)
        assert pa.terms == pb.terms


def test_schedule_slot_swap_contract():
    u, gc, tp = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    slot = ScheduleSlot(FixedSchedule(ring), gc.num_silos, silos=gc.silos)
    assert slot.version == 0
    assert slot.plan_for_round(0) is slot.plan_for_round(5)  # cached constant
    seen = []
    slot.on_swap(lambda plan, version: seen.append(version))
    ms = C.matcha_schedule_from_underlay(u, 0.5)
    v = slot.swap_schedule(ms, label="to-matcha")
    assert v == 1 and seen == [1] and slot.schedule is ms
    assert slot.history[-1] == (1, "to-matcha")
    # per-round sampling does NOT bump the version
    slot.plan_for_round(3)
    assert slot.version == 1


# ---------------------------------------------------------------------------
# Engine: round-varying recursion + sparse critical circuit vs oracles


def test_time_varying_recursion_matches_per_round_dense_steps():
    rng = np.random.default_rng(0)
    for _ in range(15):
        N = int(rng.integers(2, 8))
        Cc = int(rng.integers(1, 4))
        R = int(rng.integers(1, 10))
        E = int(rng.integers(1, 14))
        src = rng.integers(0, N, E)
        dst = rng.integers(0, N, E)
        w = np.where(
            rng.random((Cc, R, E)) < 0.7,
            rng.uniform(0.1, 10.0, (Cc, R, E)),
            -np.inf,
        )
        out = timing_recursion_time_varying_sparse(src, dst, w, N)
        assert out.shape == (Cc, R + 1, N)
        for c in range(Cc):
            t = np.zeros(N)
            for k in range(R):
                W = np.full((N, N), -np.inf)
                np.maximum.at(W, (src, dst), w[c, k])
                t = batched_timing_recursion(W[None], 1, t[None])[0, 1]
                assert np.array_equal(out[c, k + 1], t)


def test_unique_rounds_recursion_equals_dense_stack_form():
    rng = np.random.default_rng(1)
    N, Cc, R, E, U = 6, 3, 12, 10, 5
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    w_unique = np.where(
        rng.random((U, E)) < 0.8, rng.uniform(0.1, 10.0, (U, E)), -np.inf
    )
    ids = rng.integers(0, U, (Cc, R))
    a = timing_recursion_unique_rounds_sparse(src, dst, w_unique, ids, N)
    b = timing_recursion_time_varying_sparse(src, dst, w_unique[ids], N)
    np.testing.assert_array_equal(a, b)


def test_time_varying_recursion_jax_matches_numpy():
    rng = np.random.default_rng(2)
    N, Cc, R, E = 5, 2, 8, 7
    src = np.concatenate([rng.integers(0, N, E), np.arange(N)])
    dst = np.concatenate([rng.integers(0, N, E), np.arange(N)])
    w = np.where(
        rng.random((Cc, R, E + N)) < 0.8,
        rng.uniform(0.1, 10.0, (Cc, R, E + N)),
        -np.inf,
    )
    w[:, :, E:] = rng.uniform(0.0, 3.0, (Cc, R, N))  # self-loops present
    a = timing_recursion_time_varying_sparse(src, dst, w, N)
    b = np.asarray(timing_recursion_time_varying_sparse_jax(src, dst, w, N))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def random_strong_dense(rng, n):
    W = np.full((n, n), -np.inf)
    for i in range(n):
        W[i, (i + 1) % n] = rng.uniform(0.5, 20.0)
        W[i, i] = rng.uniform(0.0, 5.0)
        j = rng.randrange(n)
        if j != i:
            W[i, j] = rng.uniform(0.5, 20.0)
    return W


def test_critical_circuit_sparse_matches_dense_oracle():
    for seed in range(40):
        rng = random.Random(seed)
        n = rng.randint(2, 12)
        W = random_strong_dense(rng, n)
        tau_d, circ_d = critical_circuit_dense(W)
        eb = dense_to_edge_batch(W)
        tau_s, circ_s = critical_circuit_sparse(
            eb.src[0], eb.dst[0], eb.w[0], n
        )
        assert tau_s == pytest.approx(tau_d, rel=1e-9)
        hops = list(zip(circ_s[:-1], circ_s[1:]))
        mean = sum(W[a, b] for (a, b) in hops) / len(hops)
        assert mean == pytest.approx(tau_s, rel=1e-6)


def test_critical_circuit_sparse_acyclic_and_self_loop():
    W = np.full((3, 3), -np.inf)
    W[0, 1], W[1, 2] = 1.0, 2.0
    eb = dense_to_edge_batch(W)
    assert critical_circuit_sparse(eb.src[0], eb.dst[0], eb.w[0], 3) == (
        -math.inf,
        [],
    )
    W = np.full((2, 2), -np.inf)
    W[1, 1] = 4.0
    eb = dense_to_edge_batch(W)
    tau, circ = critical_circuit_sparse(eb.src[0], eb.dst[0], eb.w[0], 2)
    assert tau == pytest.approx(4.0) and circ == [1, 1]


def test_degree_table_pricing_path_is_bit_identical():
    """bode's large-batch degree-table fast path must equal the general
    per-entry path exactly (same expressions, same order)."""
    u, gc, tp = gaia_setup()
    arcs = [e for e in gc.edges() if e[0] != e[1]]
    rng = np.random.default_rng(3)
    masks = rng.random((600, len(arcs))) < 0.3  # B >> D^2: table path
    eb_big = batched_overlay_delay_edges(gc, tp, arcs, masks)
    for b in rng.choice(600, 25, replace=False):
        eb_row = batched_overlay_delay_edges(gc, tp, arcs, masks[b : b + 1])
        np.testing.assert_array_equal(eb_row.w[0], eb_big.w[int(b)])


# ---------------------------------------------------------------------------
# Registry + dynamics-facing behavior


def test_design_schedule_registry():
    u, gc, tp = gaia_setup()
    fs = C.design_schedule("ring", gc, tp)
    assert isinstance(fs, FixedSchedule) and not fs.is_randomized
    ms = C.design_schedule("matcha", gc, tp, budgets=(0.2, 0.5), rounds=40,
                           seeds=(0,))
    assert isinstance(ms, MatchaSchedule) and ms.budget in (0.2, 0.5)
    assert "matcha" in C.SCHEDULE_KINDS
    with pytest.raises(KeyError):
        C.design_schedule("nope", gc, tp)


def test_fixed_schedule_simulate_rounds_with_no_arcs_is_comp_only():
    """A degenerate (edge-less) overlay after heavy churn must calibrate
    to the comp-only self-loop profile, not raise — the controller calls
    this from inside observe_round."""
    from repro.core.topologies import Overlay

    u, gc, tp = gaia_setup()
    fs = FixedSchedule(Overlay(name="trivial", edges=(), cycle_time_ms=0.0))
    d = fs.simulate_rounds(gc, tp, 10)
    comp = max(tp.local_steps * gc.silo_params[v].comp_time_ms
               for v in gc.silos)
    np.testing.assert_allclose(d, comp)


def test_design_matcha_schedule_raises_infeasible_on_pairless_graph():
    from repro.core.delays import ConnectivityGraph, SiloParams
    from repro.core.schedule import ScheduleInfeasibleError

    _, _, tp = gaia_setup()
    gc = ConnectivityGraph(
        silos=(0, 1),
        latency_ms={(0, 1): 5.0},  # one direction only: no symmetric pair
        available_bw_gbps={(0, 1): 1.0},
        silo_params={v: SiloParams(1.0, 1.0, 1.0) for v in (0, 1)},
    )
    with pytest.raises(ScheduleInfeasibleError):
        design_matcha_schedule(gc, tp, budgets=(0.5,), rounds=10, seeds=(0,))


def test_simulate_rounds_batch_matches_per_seed_calls():
    u, gc, tp = gaia_setup()
    ms = C.matcha_schedule_from_underlay(u, 0.4)
    batch = ms.simulate_rounds_batch(gc, tp, 30, seeds=(0, 1, 2))
    assert batch.shape == (3, 30)
    for i, s in enumerate((0, 1, 2)):
        np.testing.assert_array_equal(
            batch[i], ms.simulate_rounds(gc, tp, 30, seed=s)
        )


def test_matcha_pricing_filters_vanished_silos():
    """Dynamics: pricing on an active-subgraph estimate drops matching
    pairs whose silos left — no KeyError, finite tau."""
    from repro.dynamics import active_subgraph

    u, gc, tp = gaia_setup()
    ms = C.matcha_schedule_from_underlay(u, 0.4)
    sub = active_subgraph(gc, [v for v in gc.silos if v != 4])
    est = ms.price(sub, tp, rounds=40)
    assert np.isfinite(est.tau_ms) and est.tau_ms > 0
