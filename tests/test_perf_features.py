"""§Perf features: banded SWA attention and the flash-style custom VJP
must be exact drop-ins for the naive chunked formulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.attention import (
    banded_swa_attention,
    chunked_attention,
    flash_attention_vjp,
    naive_attention,
)


def _inputs(key, B, S, K, G, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    return q, k, v


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.sampled_from([64, 100, 256]),
       st.sampled_from([64, 128]))
def test_banded_swa_matches_naive(seed, window, q_block):
    q, k, v = _inputs(jax.random.PRNGKey(seed), 1, 512, 2, 1, 32)
    pos = jnp.arange(512, dtype=jnp.int32)
    a = banded_swa_attention(q, k, v, pos, window=window, q_block=q_block)
    b = naive_attention(q, k, v, pos, pos, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_forward_matches_chunked(window):
    q, k, v = _inputs(jax.random.PRNGKey(0), 2, 128, 2, 2, 32)
    pos = jnp.arange(128, dtype=jnp.int32)
    a = flash_attention_vjp(q, k, v, pos, pos, True, window, 64)
    b = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                          kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_grads_match_autodiff(window):
    q, k, v = _inputs(jax.random.PRNGKey(1), 2, 128, 2, 2, 32)
    pos = jnp.arange(128, dtype=jnp.int32)

    def f_ref(q, k, v):
        return (chunked_attention(q, k, v, pos, pos, causal=True,
                                  window=window, kv_block=64) ** 2).sum()

    def f_new(q, k, v):
        return (flash_attention_vjp(q, k, v, pos, pos, True, window, 64) ** 2).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_model_forward_same_with_flash_vjp():
    """End-to-end: enabling flash_vjp must not change the model output."""
    from repro.configs import get_config
    from repro.models import init_params, transformer as T
    import dataclasses

    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    base, _ = T.forward(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, flash_vjp=True)
    new, _ = T.forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(new),
                               atol=2e-3, rtol=2e-3)


def test_model_forward_same_with_banded_swa():
    from repro.configs import get_config
    from repro.models import init_params, transformer as T
    import dataclasses

    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window == 32
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0,
                                cfg.vocab_size)
    base, _ = T.forward(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, banded_swa=True)
    new, _ = T.forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(new),
                               atol=2e-3, rtol=2e-3)
