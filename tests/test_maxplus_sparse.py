"""Sparse (edge-list) engine: equivalence against the dense engine.

The dense engine is itself equivalence-tested against the ``*_legacy``
dict oracles (tests/test_maxplus_vec.py), so agreement here closes the
chain legacy == dense == sparse.  Property tests cover random
strongly-connected overlays in f32 and f64 plus the padded-edge and
duplicate-arc edge cases of the ``[B, E]`` representation.
"""

import math
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

import repro.core as C
from repro.core.delays import batched_overlay_delay_matrices
from repro.core.maxplus_sparse import (
    EdgeBatch,
    batched_cycle_time_sparse,
    batched_is_strongly_connected_sparse,
    batched_overlay_delay_edges,
    batched_timing_recursion_sparse,
    cycle_time_sparse,
    dense_to_edge_batch,
    edge_batch_to_dense,
    reachable_from_sparse,
    scc_labels_sparse,
)
from repro.core.maxplus_vec import (
    NEG_INF,
    batched_cycle_time,
    batched_is_strongly_connected,
    batched_timing_recursion,
    missing_mask,
    reachability_closure,
    scc_labels,
)


def random_dense_batch(rng, b, n, density=0.35):
    """[B, N, N] random digraphs with -inf holes."""
    W = np.where(
        rng.random((b, n, n)) < density,
        rng.uniform(0.1, 30.0, (b, n, n)),
        -np.inf,
    )
    return W


def random_strong_batch(rng, b, n):
    """Ring + chords + self loops: strongly connected by construction."""
    W = np.full((b, n, n), -np.inf)
    idx = np.arange(n)
    for k in range(b):
        perm = rng.permutation(n)
        W[k, perm, np.roll(perm, -1)] = rng.uniform(0.5, 20.0, n)
        W[k, idx, idx] = rng.uniform(0.0, 5.0, n)
        chords = rng.integers(0, n, size=(2 * n, 2))
        for (i, j) in chords:
            if i != j:
                W[k, i, j] = rng.uniform(0.5, 20.0)
    return W


def test_round_trip_dense_edge_batch():
    rng = np.random.default_rng(0)
    W = random_dense_batch(rng, 17, 7)
    eb = dense_to_edge_batch(W)
    np.testing.assert_array_equal(edge_batch_to_dense(eb), W)


def test_cycle_time_matches_dense_on_random_digraphs():
    """Including disconnected and acyclic instances (tau = -inf)."""
    rng = np.random.default_rng(1)
    for density in (0.1, 0.35, 0.8):
        W = random_dense_batch(rng, 32, 8, density)
        ref = batched_cycle_time(W)
        got = batched_cycle_time_sparse(dense_to_edge_batch(W))
        np.testing.assert_allclose(got, ref, rtol=1e-12)


def test_padding_and_duplicates_are_neutral():
    rng = np.random.default_rng(2)
    W = random_strong_batch(rng, 8, 6)
    eb = dense_to_edge_batch(W)
    ref = batched_cycle_time_sparse(eb)
    # extra padded capacity
    wide = dense_to_edge_batch(W, e_max=eb.max_edges + 13)
    np.testing.assert_array_equal(batched_cycle_time_sparse(wide), ref)
    # duplicate arcs with *smaller* weights never win a segment max
    dup = EdgeBatch(
        np.concatenate([eb.src, eb.src], axis=1),
        np.concatenate([eb.dst, eb.dst], axis=1),
        np.concatenate([eb.w, eb.w - 5.0], axis=1),
        eb.num_nodes,
    )
    np.testing.assert_array_equal(batched_cycle_time_sparse(dup), ref)


def test_batch_chunking_is_invisible():
    """The [N+1, chunk, N] Karp DP table is bounded by max_dp_bytes via
    batch chunking; chunk size must not affect results (mirrors the
    dense engine's test)."""
    rng = np.random.default_rng(3)
    eb = dense_to_edge_batch(random_dense_batch(rng, 33, 7, 0.4))
    full = batched_cycle_time_sparse(eb)
    tiny = batched_cycle_time_sparse(eb, max_dp_bytes=8 * 7 * 10)
    np.testing.assert_array_equal(tiny, full)


def test_empty_and_tiny_graphs():
    eb = EdgeBatch(
        np.zeros((3, 1), dtype=np.int32),
        np.zeros((3, 1), dtype=np.int32),
        np.full((3, 1), -np.inf),
        4,
    )
    assert np.all(batched_cycle_time_sparse(eb) == -np.inf)
    assert cycle_time_sparse([0], [0], [5.0], 1) == pytest.approx(5.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000), st.booleans())
def test_property_sparse_dense_agree_on_strong_overlays(n, seed, use_f32):
    """Acceptance: sparse and dense batched_cycle_time agree on random
    strongly-connected overlays, f32 and f64, with padded edges."""
    rng = np.random.default_rng(seed)
    W = random_strong_batch(rng, 6, n)
    eb = dense_to_edge_batch(W, e_max=W.shape[1] * W.shape[1] + 3)
    assert np.all(batched_is_strongly_connected_sparse(eb))
    if use_f32:
        ref = batched_cycle_time(W.astype(np.float32), dtype=np.float32)
        got = batched_cycle_time_sparse(
            EdgeBatch(eb.src, eb.dst, eb.w.astype(np.float32), n)
        )
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)
    else:
        ref = batched_cycle_time(W)
        got = batched_cycle_time_sparse(eb)
        np.testing.assert_allclose(got, ref, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_property_timing_recursion_matches_dense(n, seed):
    rng = np.random.default_rng(seed)
    W = random_dense_batch(rng, 4, n, density=0.5)
    ref = batched_timing_recursion(W, 20)
    got = batched_timing_recursion_sparse(dense_to_edge_batch(W), 20)
    np.testing.assert_allclose(got, ref, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 9), st.integers(0, 10_000))
def test_property_strong_connectivity_matches_dense(n, seed):
    rng = np.random.default_rng(seed)
    W = random_dense_batch(rng, 16, n, density=rng.uniform(0.1, 0.6))
    ref = batched_is_strongly_connected(W)
    got = batched_is_strongly_connected_sparse(dense_to_edge_batch(W))
    np.testing.assert_array_equal(got, ref)


def test_reachability_matches_dense_closure():
    rng = np.random.default_rng(5)
    W = random_dense_batch(rng, 12, 9, density=0.25)
    eb = dense_to_edge_batch(W)
    got = reachable_from_sparse(eb, start=0)
    adj = W > -np.inf
    idx = np.arange(9)
    adj[:, idx, idx] = False
    ref = reachability_closure(adj)[:, 0, :]  # row 0: reachable from 0
    np.testing.assert_array_equal(got, ref)


def test_scc_labels_same_partition_as_dense():
    rng = np.random.default_rng(6)
    for _ in range(20):
        n = int(rng.integers(1, 12))
        A = (rng.random((n, n)) < 0.25) & ~np.eye(n, dtype=bool)
        dense = scc_labels(A, dense_threshold=1024)
        i, j = np.nonzero(A)
        sparse = scc_labels_sparse(i, j, n)
        f, g = {}, {}
        for a, b in zip(dense.tolist(), sparse.tolist()):
            assert f.setdefault(a, b) == b
            assert g.setdefault(b, a) == a


def test_overlay_delay_edges_matches_dense_matrices():
    """Eq. 3 pricing: the sparse builder and the dense builder price the
    same candidate masks identically (degrees, sharing, self loops)."""
    u = C.make_underlay("gaia")
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    gc = u.connectivity_graph(comp_time_ms=Tc)
    arcs = [e for e in gc.edges() if e[0] != e[1]]
    rng = np.random.default_rng(7)
    masks = rng.random((12, len(arcs))) < 0.15
    Wd = batched_overlay_delay_matrices(gc, tp, arcs, masks)
    eb = batched_overlay_delay_edges(gc, tp, arcs, masks)
    np.testing.assert_allclose(edge_batch_to_dense(eb), Wd, rtol=1e-15)
    np.testing.assert_allclose(
        batched_cycle_time_sparse(eb), batched_cycle_time(Wd), rtol=1e-12
    )


# ---------------------------------------------------------------------------
# Adversarial NEG_INF arithmetic: the sentinel must stay absorbing (never
# NaN) under f32 and under padded-edge masks — the failure modes the
# repro-lint sentinel-discipline rule exists to keep out of the engines.
# ---------------------------------------------------------------------------


def test_all_padding_f32_yields_neg_inf_not_nan():
    """A fully padded f32 batch: every reduction walks -inf + -inf chains,
    which must stay -inf (absorbing), never NaN (-inf - -inf)."""
    z = np.zeros((4, 6), dtype=np.int32)
    eb = EdgeBatch(z, z, np.full((4, 6), NEG_INF, dtype=np.float32), 5)
    tau = batched_cycle_time_sparse(eb)
    assert np.all(np.isneginf(np.asarray(tau, dtype=np.float64)))
    assert not np.any(np.isnan(tau))
    times = batched_timing_recursion_sparse(eb, 7)
    assert not np.any(np.isnan(times))
    assert not np.all(batched_is_strongly_connected_sparse(eb))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000), st.booleans())
def test_property_interleaved_neg_inf_padding_is_absorbing(n, seed, use_f32):
    """Padded arcs shuffled *between* real arcs (not just appended at the
    tail, the layout dense_to_edge_batch emits) pointing at arbitrary
    node pairs must be invisible to every engine, in f32 and f64."""
    rng = np.random.default_rng(seed)
    dtype = np.float32 if use_f32 else np.float64
    W = random_strong_batch(rng, 4, n)
    eb = dense_to_edge_batch(W)
    b, e = eb.src.shape
    p = int(rng.integers(1, 2 * n + 2))
    pad_src = rng.integers(0, n, (b, p)).astype(eb.src.dtype)
    pad_dst = rng.integers(0, n, (b, p)).astype(eb.dst.dtype)
    perm = rng.permutation(e + p)
    adv = EdgeBatch(
        np.concatenate([eb.src, pad_src], axis=1)[:, perm],
        np.concatenate([eb.dst, pad_dst], axis=1)[:, perm],
        np.concatenate(
            [eb.w, np.full((b, p), NEG_INF)], axis=1
        )[:, perm].astype(dtype),
        n,
    )
    ref_eb = EdgeBatch(eb.src, eb.dst, eb.w.astype(dtype), n)
    ref = batched_cycle_time_sparse(ref_eb)
    got = batched_cycle_time_sparse(adv)
    # max-plus reductions are order-independent and -inf is absorbing,
    # so agreement is exact even in f32 — not merely close.
    np.testing.assert_array_equal(got, ref)
    assert not np.any(np.isnan(got))
    np.testing.assert_array_equal(
        batched_is_strongly_connected_sparse(adv),
        batched_is_strongly_connected_sparse(ref_eb),
    )
    t_ref = batched_timing_recursion_sparse(ref_eb, 6)
    t_got = batched_timing_recursion_sparse(adv, 6)
    np.testing.assert_array_equal(t_got, t_ref)
    assert not np.any(np.isnan(t_got))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 9), st.integers(0, 10_000))
def test_property_missing_mask_survives_round_trip(n, seed):
    """missing_mask is the sanctioned absent-arc test: it must identify
    exactly the -inf holes through dense -> sparse -> dense, and treat a
    huge-but-finite f32 value as a real arc, not padding."""
    rng = np.random.default_rng(seed)
    W = random_dense_batch(rng, 6, n, density=0.3)
    back = edge_batch_to_dense(dense_to_edge_batch(W))
    np.testing.assert_array_equal(missing_mask(back), missing_mask(W))
    np.testing.assert_array_equal(missing_mask(W), np.isneginf(W))
    assert bool(missing_mask(np.float32(NEG_INF)))
    assert not bool(missing_mask(np.float32(-3.0e38)))  # finite in f32


def test_jax_sparse_matches_numpy_sparse():
    jax = pytest.importorskip("jax")
    from repro.core.maxplus_sparse import batched_cycle_time_sparse_jax

    rng = np.random.default_rng(8)
    W = random_dense_batch(rng, 16, 10, density=0.4)
    eb = dense_to_edge_batch(W)
    ref = batched_cycle_time_sparse(eb)
    jit = jax.jit(batched_cycle_time_sparse_jax, static_argnums=3)
    got = np.asarray(jit(eb.src, eb.dst, eb.w.astype(np.float32), 10))
    finite = np.isfinite(ref)
    np.testing.assert_array_equal(finite, np.isfinite(got))
    np.testing.assert_allclose(got[finite], ref[finite], rtol=1e-4, atol=1e-4)
