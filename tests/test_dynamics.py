"""Dynamics subsystem: scenario model, event-driven simulator, and the
online re-design controller (plus the vectorized critical circuit the
controller explains bottlenecks with).

Key identities under test:

* a no-event scenario reproduces ``timing_recursion_dense`` exactly
  (bit-for-bit, not approximately);
* inside each static segment of an eventful scenario, the realized
  round-time slope matches ``cycle_time_dense`` of that segment's delay
  matrix (the Thm 3.23 identity, per epoch);
* on a seeded Gaia core-link failure the controller beats the
  non-adaptive designed overlay in rounds-by-deadline, and one re-design
  step over >= 256 candidates at N=22 completes in under a second.
"""

import math
import random
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as C
from repro.core.delays import TrainingParams, overlay_delay_matrix
from repro.core.maxplus import DelayDigraph, critical_circuit, critical_circuit_legacy
from repro.core.maxplus_vec import (
    critical_circuit_dense,
    cycle_time_dense,
    graph_to_matrix,
    timing_recursion_dense,
    timing_recursion_piecewise,
)
from repro.dynamics import (
    ComputeStraggler,
    ControllerConfig,
    DynamicTimeline,
    LinkDegraded,
    LinkFailed,
    LinkRestored,
    OnlineTopologyController,
    Scenario,
    SiloJoin,
    SiloLeave,
    active_subgraph,
    churn_scenario,
    design_best_overlay,
    design_best_schedule,
    link_failure_scenario,
    random_scenario,
    schedule_epoch_estimates,
    silo_degrade_scenario,
    simulate_dynamic,
    simulate_scenarios_batched,
    static_scenario,
)
from repro.fed.gossip import PlanSlot, ScheduleSlot


def gaia_setup(workload="inaturalist", s=1):
    M, Tc = C.WORKLOADS[workload]
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=s)
    return u, gc, tp, Tc


# ---------------------------------------------------------------------------
# Scenario model


def test_no_event_scenario_is_single_segment_of_the_measured_network():
    u, gc, tp, Tc = gaia_setup()
    segs = static_scenario(u, Tc).segments()
    assert len(segs) == 1 and segs[0].t_end_ms == math.inf
    for e, lat in gc.latency_ms.items():
        assert segs[0].gc.latency_ms[e] == pytest.approx(lat)
        assert segs[0].gc.available_bw_gbps[e] == pytest.approx(
            gc.available_bw_gbps[e]
        )


def test_events_fold_into_piecewise_epochs():
    u, gc, tp, Tc = gaia_setup()
    link = u.core_edges[0]
    sc = Scenario(
        name="t",
        underlay=u,
        comp_time_ms=Tc,
        events=(
            LinkDegraded(t_ms=1000.0, link=link, factor=0.1),
            ComputeStraggler(t_ms=1000.0, silo=2, factor=5.0),
            LinkFailed(t_ms=3000.0, link=link),
            SiloLeave(t_ms=5000.0, silo=4),
            SiloJoin(t_ms=7000.0, silo=4),
        ),
        horizon_ms=10_000.0,
    )
    segs = sc.segments()
    # simultaneous events merge: boundaries at 1000, 3000, 5000, 7000
    assert [s.t_start_ms for s in segs] == [0.0, 1000.0, 3000.0, 5000.0, 7000.0]
    i, j = link
    # degradation scales the direct pair's available bandwidth
    assert segs[1].gc.available_bw_gbps[(i, j)] == pytest.approx(
        0.1 * segs[0].gc.available_bw_gbps[(i, j)]
    )
    # straggler scales computation
    assert segs[1].gc.silo_params[2].comp_time_ms == pytest.approx(5.0 * Tc)
    # failure re-routes: latency strictly grows, pair still reachable
    assert segs[2].gc.latency_ms[(i, j)] > segs[0].gc.latency_ms[(i, j)]
    # churn shrinks and restores the active set
    assert 4 not in segs[3].active and 4 in segs[4].active
    assert all((a, 4) not in segs[3].gc.latency_ms for a in segs[3].active)
    # inactive silo contributes no self-loop circuit
    assert segs[3].gc.silo_params[4].comp_time_ms == 0.0


def test_random_scenario_is_seed_deterministic():
    u, gc, tp, Tc = gaia_setup()
    a = random_scenario(u, Tc, seed=11, n_events=8)
    b = random_scenario(u, Tc, seed=11, n_events=8)
    assert a.events == b.events
    c = random_scenario(u, Tc, seed=12, n_events=8)
    assert a.events != c.events


def test_random_scenario_full_churn_pool_recovers():
    """Regression: at ``p_churn=1.0`` the churn candidate pool must not
    shrink monotonically — a silo whose scheduled rejoin has fired is
    eligible to leave again, so long horizons produce more departures
    than the universe could supply under the old always-grows ``away``
    set (which capped SiloLeave events at N - 3 and starved churn into
    stragglers), and every epoch keeps >= min_active silos."""
    u, gc, tp, Tc = gaia_setup()
    for seed in range(3):
        sc = random_scenario(
            u, Tc, seed=seed, horizon_ms=500_000.0, n_events=60, p_churn=1.0
        )
        leaves = [e for e in sc.events if isinstance(e, SiloLeave)]
        joins = [e for e in sc.events if isinstance(e, SiloJoin)]
        # every leave schedules its paired rejoin inside the horizon
        assert len(leaves) == len(joins)
        assert all(e.t_ms <= sc.horizon_ms for e in joins)
        # pool recovery: strictly more departures than a monotone pool
        # could ever emit (the old bug's hard cap)
        assert len(leaves) > u.num_silos - 3
        # some silo left, rejoined, and left again
        assert max(
            sum(1 for e in leaves if e.silo == v) for v in range(u.num_silos)
        ) >= 2
        # the active floor holds on every folded epoch
        assert min(len(seg.active) for seg in sc.segments()) >= 3


def test_link_restore_after_degrade_keeps_degradation():
    """degrade -> fail -> restore: the decided semantics are
    restore-to-degraded — LinkRestored undoes only the failure, the
    degradation persists until an explicit LinkDegraded(factor=1.0)."""
    u, gc, tp, Tc = gaia_setup()
    link = tuple(sorted(u.core_edges[0]))
    i, j = link
    sc = Scenario(
        name="dfr",
        underlay=u,
        comp_time_ms=Tc,
        events=(
            LinkDegraded(t_ms=1000.0, link=link, factor=0.25),
            LinkFailed(t_ms=2000.0, link=link),
            LinkRestored(t_ms=3000.0, link=link),
            LinkDegraded(t_ms=4000.0, link=link, factor=1.0),
        ),
        horizon_ms=5000.0,
    )
    segs = sc.segments()
    assert [s.t_start_ms for s in segs] == [0.0, 1000.0, 2000.0, 3000.0, 4000.0]
    bw0 = segs[0].gc.available_bw_gbps[(i, j)]
    lat0 = segs[0].gc.latency_ms[(i, j)]
    # degraded: capacity scales, path unchanged
    assert segs[1].gc.available_bw_gbps[(i, j)] == pytest.approx(0.25 * bw0)
    assert segs[1].gc.latency_ms[(i, j)] == pytest.approx(lat0)
    # failed: re-routed around the link
    assert segs[2].gc.latency_ms[(i, j)] > lat0
    # restored: the direct path is back, but STILL at the degraded
    # capacity — restore undoes the failure, not the degradation
    assert segs[3].gc.latency_ms[(i, j)] == pytest.approx(lat0)
    assert segs[3].gc.available_bw_gbps[(i, j)] == pytest.approx(0.25 * bw0)
    # only the explicit factor=1.0 degrade event returns full capacity
    assert segs[4].gc.available_bw_gbps[(i, j)] == pytest.approx(bw0)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_epoch_folding_under_membership_churn(data):
    """Property: under arbitrary initially_inactive sets and interleaved
    SiloJoin/SiloLeave streams, every folded epoch isolates its inactive
    silos (no routed pairs touch them, zero computation time — so they
    contribute no max-plus circuit), ``active_subgraph`` restriction
    loses nothing, and epoch timestamps tile [0, inf) monotonically."""
    u, gc, tp, Tc = gaia_setup()
    n = u.num_silos
    raw = data.draw(st.lists(st.integers(0, n - 1), max_size=n - 2))
    init_inactive = tuple(sorted(set(raw)))[: n - 2]
    active = set(range(n)) - set(init_inactive)
    events = []
    t = 0.0
    for _ in range(data.draw(st.integers(0, 8))):
        t += data.draw(st.floats(1.0, 500.0))
        silo = data.draw(st.integers(0, n - 1))
        if silo in active and len(active) > 1 and data.draw(st.booleans()):
            events.append(SiloLeave(t_ms=t, silo=silo))
            active.discard(silo)
        else:  # join (idempotent when already active)
            events.append(SiloJoin(t_ms=t, silo=silo))
            active.add(silo)
    sc = Scenario(
        name="churn-prop",
        underlay=u,
        comp_time_ms=Tc,
        events=tuple(events),
        horizon_ms=t + 1000.0,
        initially_inactive=init_inactive,
    )
    segs = sc.segments()
    # timestamps: start at 0, strictly increase, tile the half-line
    starts = [s.t_start_ms for s in segs]
    assert starts[0] == 0.0
    assert all(a < b for a, b in zip(starts, starts[1:]))
    assert all(
        s.t_end_ms == nxt.t_start_ms for s, nxt in zip(segs, segs[1:])
    )
    assert segs[-1].t_end_ms == math.inf
    # final epoch's active set matches the folded event stream
    assert set(segs[-1].active) == active
    for seg in segs:
        inactive = set(range(n)) - set(seg.active)
        for v in inactive:
            # zero comp time: no self-loop circuit for inactive silos
            assert seg.gc.silo_params[v].comp_time_ms == 0.0
        # no routed pair touches an inactive silo
        assert all(
            not (set(e) & inactive) for e in seg.gc.latency_ms
        )
        # restriction to the active set is lossless (isolation)
        sub = active_subgraph(seg.gc, seg.active)
        assert set(sub.silos) == set(seg.active)
        assert sub.latency_ms == seg.gc.latency_ms
        assert sub.available_bw_gbps == seg.gc.available_bw_gbps


# ---------------------------------------------------------------------------
# Event-driven simulator


def test_no_event_scenario_reproduces_static_recursion_exactly():
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    run = simulate_dynamic(static_scenario(u, Tc), tp, ring.edges, num_rounds=60)
    W = overlay_delay_matrix(gc, tp, ring.edges)
    assert np.array_equal(run.times, timing_recursion_dense(W, 60))


def test_per_segment_empirical_cycle_time_matches_karp():
    """On static sub-intervals the realized slope equals cycle_time_dense
    of that segment's delay matrix (per-epoch Thm 3.23)."""
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = link_failure_scenario(
        u, Tc, t_fail_ms=60 * ring.cycle_time_ms, overlay_edges=ring.edges
    )
    run = simulate_dynamic(sc, tp, ring.edges, num_rounds=200)
    assert run.predicted_tau_ms.shape == run.empirical_tau_ms.shape == (2,)
    # both segments hold for >= 50 rounds: slopes must have converged
    for emp, pred in zip(run.empirical_tau_ms, run.predicted_tau_ms):
        assert emp == pytest.approx(pred, rel=0.02)
    assert run.predicted_tau_ms[1] > run.predicted_tau_ms[0]


def test_piecewise_recursion_single_epoch_is_dense_recursion():
    rng = np.random.default_rng(3)
    for _ in range(10):
        n = int(rng.integers(2, 9))
        W = np.where(
            rng.random((n, n)) < 0.5, rng.uniform(0.1, 30.0, (n, n)), -np.inf
        )
        a = timing_recursion_dense(W, 30)
        b = timing_recursion_piecewise(W[None], np.zeros(1), 30)
        assert np.array_equal(a, b)


def test_batched_scenarios_match_per_scenario_runs():
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    horizon = 100 * ring.cycle_time_ms
    scenarios = [
        random_scenario(u, Tc, seed=s, horizon_ms=horizon) for s in range(6)
    ]
    batched = simulate_scenarios_batched(scenarios, tp, ring.edges, 80)
    for b, sc in enumerate(scenarios):
        solo = simulate_dynamic(sc, tp, ring.edges, num_rounds=80)
        np.testing.assert_array_equal(batched[b], solo.times)


def test_straggler_slows_rounds_then_recovers():
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    t1, t2 = 30 * ring.cycle_time_ms, 60 * ring.cycle_time_ms
    sc = Scenario(
        name="straggle",
        underlay=u,
        comp_time_ms=Tc,
        events=(
            ComputeStraggler(t_ms=t1, silo=0, factor=40.0),
            ComputeStraggler(t_ms=t2, silo=0, factor=1.0),
        ),
        horizon_ms=100 * ring.cycle_time_ms,
    )
    run = simulate_dynamic(sc, tp, ring.edges, num_rounds=150)
    assert run.predicted_tau_ms[1] > run.predicted_tau_ms[0]
    assert run.predicted_tau_ms[2] == pytest.approx(run.predicted_tau_ms[0])


# ---------------------------------------------------------------------------
# Online controller (acceptance)


def adaptive_vs_static(scenario, tp, gc0, overlay, deadline_ms, **cfg_kw):
    timeline = DynamicTimeline(scenario, tp)
    timeline.set_overlay(overlay.edges)
    slot = PlanSlot(
        OnlineTopologyController(gc0, tp, overlay).plan
    )
    controller = OnlineTopologyController(
        gc0,
        tp,
        overlay,
        config=ControllerConfig(**cfg_kw),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
        plan_slot=slot,
    )
    while timeline.now_ms < deadline_ms:
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_overlay(redesign.overlay.edges)
    adaptive_rounds = sum(
        1 for f in timeline.round_finish_ms[1:] if f <= deadline_ms
    )
    return adaptive_rounds, controller, slot


def test_controller_beats_nonadaptive_on_seeded_gaia_link_failure():
    """Acceptance: seeded Gaia link-failure scenario — the controller's
    realized rounds-by-deadline beat the non-adaptive designed overlay."""
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    deadline = 400 * ring.cycle_time_ms
    sc = link_failure_scenario(
        u, Tc, t_fail_ms=deadline / 3, overlay_edges=ring.edges,
        horizon_ms=deadline,
    )
    adaptive_rounds, controller, slot = adaptive_vs_static(
        sc, tp, gc, ring, deadline, seed=0
    )
    base = simulate_dynamic(sc, tp, ring.edges, num_rounds=500)
    base_rounds = base.rounds_completed_by(deadline)
    assert len(controller.redesigns) >= 1
    assert adaptive_rounds > base_rounds
    # the hot-swap hook actually fired (init + >= 1 re-design)
    assert slot.version >= 2
    # the re-design is explained by a critical circuit of the new overlay
    rd = controller.redesigns[0]
    assert len(rd.bottleneck) >= 2 and rd.bottleneck[0] == rd.bottleneck[-1]


def test_controller_detects_silo_churn_via_fast_rounds():
    """A departed silo breaks the ring: rounds get *faster* while mixing
    silently stops.  The two-sided detector must fire and re-design over
    the surviving silos."""
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = Scenario(
        name="churn",
        underlay=u,
        comp_time_ms=Tc,
        events=(SiloLeave(t_ms=30 * ring.cycle_time_ms, silo=5),),
        horizon_ms=200 * ring.cycle_time_ms,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    controller = OnlineTopologyController(
        gc, tp, ring,
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
    )
    for _ in range(120):
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_overlay(redesign.overlay.edges)
    assert len(controller.redesigns) >= 1
    survivors = {v for e in controller.overlay.edges for v in e}
    assert 5 not in survivors and len(survivors) == 10


def test_churn_redesign_with_plan_slot_does_not_crash():
    """The slot's mesh axis is sized at launch: a re-design over fewer
    silos must leave the old plan running with an audit note, not raise
    from inside observe_round."""
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = Scenario(
        name="churn",
        underlay=u,
        comp_time_ms=Tc,
        events=(SiloLeave(t_ms=30 * ring.cycle_time_ms, silo=5),),
        horizon_ms=200 * ring.cycle_time_ms,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    from repro.fed.topology_runtime import plan_from_overlay

    slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    controller = OnlineTopologyController(
        gc, tp, ring,
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
        plan_slot=slot,
    )
    version_before = slot.version
    for _ in range(120):
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_overlay(redesign.overlay.edges)
    assert len(controller.redesigns) >= 1
    assert slot.version == version_before  # swap skipped, not applied
    assert any("NOT swapped" in note for _, note in slot.history)


def test_controller_membership_swaps_on_leave_and_rejoin():
    """Elastic membership: with a membership provider + MembershipSlot
    the controller reacts to SiloLeave/SiloJoin *immediately* (control
    plane, not timing inference), publishes the new active set, and
    resizes the plan slot across silo counts — no audit-note fallback."""
    from repro.fed.gossip import MembershipSlot
    from repro.fed.topology_runtime import plan_from_overlay

    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    tau = ring.cycle_time_ms
    sc = churn_scenario(
        u, Tc, silo=5, t_leave_ms=20 * tau, t_rejoin_ms=50 * tau,
        horizon_ms=200 * tau,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    mem = MembershipSlot(range(u.num_silos), u.num_silos)
    controller = OnlineTopologyController(
        gc, tp, ring,
        config=ControllerConfig(seed=0, rewire_restarts=0),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
        plan_slot=slot,
        membership_slot=mem,
        membership_provider=timeline.current_active,
    )
    redesigns = []
    for _ in range(150):
        rd = controller.observe_round(timeline.step())
        if rd is not None:
            redesigns.append(rd)
            timeline.set_overlay(rd.overlay.edges)
    churn_rds = [rd for rd in redesigns if rd.membership is not None]
    assert len(churn_rds) == 2  # one per membership event, no extras
    survivors = tuple(v for v in range(u.num_silos) if v != 5)
    assert churn_rds[0].membership == survivors
    assert churn_rds[0].plan.n_silos == u.num_silos - 1  # resized, not skipped
    assert 5 not in {v for e in churn_rds[0].overlay.edges for v in e}
    assert churn_rds[1].membership == tuple(range(u.num_silos))
    assert churn_rds[1].plan.n_silos == u.num_silos
    assert 5 in {v for e in churn_rds[1].overlay.edges for v in e}
    # the membership slot versioned both swaps, the plan slot followed
    assert mem.version == 2 and mem.active == tuple(range(u.num_silos))
    assert slot.plan.n_silos == u.num_silos
    assert not any("NOT swapped" in note for _, note in slot.history)


def test_strike_redesign_never_resizes_plan_without_membership_swap():
    """A MembershipSlot merely *existing* must not let a strike-triggered
    (non-membership) redesign resize the plan across silo counts: without
    a membership swap this actuation carries no rebuild signal, so the
    cross-universe plan must take the audit-note path, and the
    MembershipSlot must not have moved."""
    from repro.fed.gossip import MembershipSlot
    from repro.fed.topology_runtime import plan_from_overlay

    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = Scenario(
        name="churn",
        underlay=u,
        comp_time_ms=Tc,
        events=(SiloLeave(t_ms=30 * ring.cycle_time_ms, silo=5),),
        horizon_ms=200 * ring.cycle_time_ms,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    mem = MembershipSlot(range(u.num_silos), u.num_silos)
    controller = OnlineTopologyController(
        gc, tp, ring,
        config=ControllerConfig(seed=0, rewire_restarts=0),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
        plan_slot=slot,
        membership_slot=mem,  # note: no membership_provider
    )
    for _ in range(120):
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_overlay(redesign.overlay.edges)
    assert len(controller.redesigns) >= 1
    assert controller.redesigns[0].membership is None
    assert mem.version == 0  # never swapped: no membership signal
    assert slot.plan.n_silos == gc.num_silos  # plan NOT resized
    assert any("NOT swapped" in note for _, note in slot.history)


def test_controller_membership_without_connectivity_provider():
    """With only a membership signal (no measurement service) the
    controller must still design over exactly the published active set —
    restricting its launch-time estimate on a leave, and growing back
    from it on the rejoin — so the plan never disagrees with the
    MembershipSlot."""
    from repro.fed.gossip import MembershipSlot
    from repro.fed.topology_runtime import plan_from_overlay

    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    mem = MembershipSlot(range(u.num_silos), u.num_silos)
    slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    membership = [tuple(range(u.num_silos))]
    controller = OnlineTopologyController(
        gc, tp, ring,
        config=ControllerConfig(seed=0, rewire_restarts=0),
        plan_slot=slot,
        membership_slot=mem,
        membership_provider=lambda: membership[0],
    )
    membership[0] = tuple(v for v in range(u.num_silos) if v != 5)
    rd = controller.observe_round(ring.cycle_time_ms)
    assert rd is not None and rd.membership == membership[0]
    assert rd.plan.n_silos == u.num_silos - 1 == mem.n_active
    assert 5 not in {v for e in rd.overlay.edges for v in e}
    membership[0] = tuple(range(u.num_silos))
    rd2 = controller.observe_round(ring.cycle_time_ms)
    assert rd2 is not None and rd2.plan.n_silos == u.num_silos
    assert slot.plan.n_silos == u.num_silos == mem.n_active


@pytest.mark.slow
def test_train_dynamic_random_churn_rebuilds_mesh_and_state():
    """Acceptance: ``train.py --reduced --dynamic --scenario random`` with
    ``p_churn > 0`` completes end-to-end; the mesh/state are rebuilt on a
    SiloLeave and again on the paired SiloJoin, surviving silos'
    parameters are bit-identical across every migration, and joiners
    re-enter at the survivors' consensus average."""
    import os
    import re
    import subprocess
    import sys

    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "internlm2-1.8b", "--reduced", "--dynamic",
            "--scenario", "random", "--p-churn", "1.0",
            "--scenario-seed", "0", "--verify-migration",
            "--steps", "35", "--seq-len", "16", "--batch-per-silo", "2",
        ],
        capture_output=True,
        text=True,
        timeout=560,
        env=env,
    )
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-2000:]
    swaps = re.findall(
        r"membership v(\d+): (\d+) -> (\d+) silos \(left \[([\d, ]*)\], "
        r"joined \[([\d, ]*)\]\)", out)
    assert len(swaps) >= 2, out[-2000:]
    leavers = {s for _, _, _, left, _ in swaps for s in left.split(", ") if s}
    joiners = {s for _, _, _, _, jn in swaps for s in jn.split(", ") if s}
    # mesh/state rebuilt on a SiloLeave AND again on the paired SiloJoin
    assert leavers and (leavers & joiners), swaps
    shrank = any(int(a) > int(b) for _, a, b, _, _ in swaps)
    grew = any(int(a) < int(b) for _, a, b, _, _ in swaps)
    assert shrank and grew, swaps
    # every migration checked out: survivors bit-identical, joiners at
    # the consensus average (verified in-process, asserted on the log)
    rebuilds = re.findall(r"mesh\+state rebuilt, survivors-bit-identical="
                          r"(\w+), joiners-at-consensus=(\w+)", out)
    assert len(rebuilds) == len(swaps)
    assert all(s == "True" and j == "True" for s, j in rebuilds), rebuilds
    assert "membership swap(s)" in out


def test_controller_is_quiet_on_a_healthy_network():
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = static_scenario(u, Tc)
    adaptive_rounds, controller, _ = adaptive_vs_static(
        sc, tp, gc, ring, 200 * ring.cycle_time_ms
    )
    assert controller.redesigns == []


def test_redesign_latency_256_candidates_n22_under_1s():
    """Acceptance: one controller re-design step over >= 256 candidate
    overlays at N=22 (AWS North America) in under a second."""
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay("aws_na")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    assert u.num_silos == 22
    t0 = time.perf_counter()
    best, scored = design_best_overlay(gc, tp, n_candidates=256)
    elapsed = time.perf_counter() - t0
    assert scored >= 256
    assert elapsed < 1.0, f"re-design took {elapsed:.2f}s"
    # sanity: the search result is a real overlay on this network
    assert best.cycle_time_ms > 0 and len(best.edges) >= u.num_silos


def test_plan_slot_swap_contract():
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    mst = C.design_overlay("mst", gc, tp)
    from repro.fed.topology_runtime import plan_from_overlay

    slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    seen = []
    slot.on_swap(lambda plan, version: seen.append(version))
    v = slot.swap(plan_from_overlay(mst, gc.num_silos), label="mst")
    assert v == 1 and slot.version == 1 and seen == [1]
    assert slot.history[-1] == (1, "mst")
    from repro.fed.gossip import GossipPlan

    with pytest.raises(ValueError):  # silo-count mismatch is rejected
        slot.swap(GossipPlan.from_matrix(np.eye(3)))


# ---------------------------------------------------------------------------
# Randomized schedules under dynamics


@pytest.mark.slow  # Monte-Carlo schedule sweep: ci.sh --fast skips
def test_schedule_epoch_estimates_track_the_drift():
    """Per-epoch pricing of a plan distribution: the degraded epoch's τ̄
    must exceed the healthy epoch's (the ROADMAP 'average cycle time of a
    plan distribution per epoch' item)."""
    u, gc, tp, Tc = gaia_setup()
    ms = C.matcha_schedule_from_underlay(u, 0.3)
    sc = silo_degrade_scenario(u, Tc, silo=3, t_ms=5000.0, factor=0.02)
    ests = schedule_epoch_estimates(sc, tp, ms, rounds=50, seeds=(0, 1))
    assert len(ests) == 2
    assert all(np.isfinite(e.tau_ms) for e in ests)
    assert ests[1].tau_ms > 2.0 * ests[0].tau_ms


def test_design_best_schedule_defaults_to_fixed_pool():
    u, gc, tp, Tc = gaia_setup()
    sched, scored = design_best_schedule(gc, tp, n_candidates=32,
                                         rewire_restarts=0)
    assert not sched.is_randomized
    best_overlay, _ = design_best_overlay(gc, tp, n_candidates=32,
                                          rng=np.random.default_rng(0))
    # same candidate families -> same winner class of cycle times
    assert sched.price(gc, tp).tau_ms <= best_overlay.cycle_time_ms * 1.05


def test_dynamic_timeline_steps_a_randomized_schedule():
    u, gc, tp, Tc = gaia_setup()
    ms = C.matcha_schedule_from_underlay(u, 0.4, sample_seed=2)
    sc = static_scenario(u, Tc)
    timeline = DynamicTimeline(sc, tp)
    timeline.set_schedule(ms)
    durations = [timeline.step() for _ in range(30)]
    assert all(d > 0 for d in durations)
    # round k's realized duration is reproducible from the shared counter
    timeline2 = DynamicTimeline(sc, tp)
    timeline2.set_schedule(C.matcha_schedule_from_underlay(u, 0.4,
                                                           sample_seed=2))
    assert durations == [timeline2.step() for _ in range(30)]


@pytest.mark.slow  # Monte-Carlo schedule sweep: ci.sh --fast skips
def test_controller_hot_swaps_to_randomized_schedule():
    """Acceptance: under schedule_family='matcha' a regression re-design
    re-fits the plan distribution and hot-swaps the ScheduleSlot from a
    fixed overlay to a randomized schedule."""
    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = silo_degrade_scenario(
        u, Tc, silo=3, t_ms=30 * ring.cycle_time_ms, factor=0.02,
        horizon_ms=300 * ring.cycle_time_ms,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    slot = ScheduleSlot(C.FixedSchedule(ring), gc.num_silos, silos=gc.silos)
    controller = OnlineTopologyController(
        gc, tp, ring,
        config=ControllerConfig(
            seed=0, schedule_family="matcha",
            matcha_budgets=(0.1, 0.2, 0.3, 0.5),
            matcha_rounds=80, matcha_seeds=(0, 1), rewire_restarts=0,
        ),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active
        ),
        schedule_slot=slot,
    )
    for _ in range(100):
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_schedule(redesign.schedule)
    assert len(controller.redesigns) >= 1
    rd = controller.redesigns[0]
    assert rd.schedule is not None and rd.schedule.is_randomized
    assert rd.overlay is None  # randomized winner carries no single overlay
    assert np.isfinite(rd.predicted_tau_ms) and rd.predicted_tau_ms > 0
    # the slot followed: init swap + redesign swap, now randomized
    assert slot.version >= 2 and slot.schedule.is_randomized
    # per-round plans keep flowing from the shared counter after the swap
    A = slot.matrix_for_round(timeline.rounds_done)
    assert np.allclose(A.sum(axis=0), 1.0) and np.allclose(A.sum(axis=1), 1.0)
    # the plant keeps stepping on the sampled topologies
    assert timeline.step() > 0


def test_redesign_under_time_to_eps_carries_rho_through_the_trace(tmp_path):
    """Co-design audit: under ``objective="time_to_eps"`` every
    re-design actuation carries the winner's (τ, ρ) pair, and both
    round-trip through the flight-recorder trace schema."""
    from repro.obs.events import FlightRecorder, validate_trace

    u, gc, tp, Tc = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    sc = silo_degrade_scenario(
        u, Tc, silo=3, t_ms=30 * ring.cycle_time_ms, factor=0.02,
        horizon_ms=300 * ring.cycle_time_ms,
    )
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    slot = ScheduleSlot(C.FixedSchedule(ring), gc.num_silos, silos=gc.silos)
    trace = str(tmp_path / "codesign.jsonl")
    with FlightRecorder(trace, silo_names=list(gc.silos)) as rec:
        controller = OnlineTopologyController(
            gc, tp, ring,
            config=ControllerConfig(
                seed=0, schedule_family="matcha", objective="time_to_eps",
                matcha_budgets=(0.3, 0.5), matcha_rounds=60,
                matcha_seeds=(0,), mixing_rounds=60, rewire_restarts=0,
            ),
            connectivity_provider=lambda: active_subgraph(
                timeline.current_epoch().gc, timeline.current_epoch().active
            ),
            schedule_slot=slot,
            recorder=rec,
            silo_names=list(gc.silos),
        )
        for _ in range(100):
            redesign = controller.observe_round(timeline.step())
            if redesign is not None:
                timeline.set_schedule(redesign.schedule)
    assert len(controller.redesigns) >= 1
    rd = controller.redesigns[0]
    # the actuation itself carries the priced pair
    assert rd.objective == "time_to_eps"
    assert np.isfinite(rd.rho) and 0.0 < rd.rho < 1.0
    assert np.isfinite(rd.predicted_tau_ms) and rd.predicted_tau_ms > 0
    # ...and the trace round-trips it under schema validation
    records, problems = validate_trace(trace)
    assert problems == []
    emitted = [r for r in records if r["kind"] == "redesign"]
    assert len(emitted) == len(controller.redesigns)
    for rec_line, actuation in zip(emitted, controller.redesigns):
        assert rec_line["objective"] == "time_to_eps"
        assert rec_line["rho"] == pytest.approx(actuation.rho)


@pytest.mark.slow  # subprocess train acceptance: ci.sh --fast skips
def test_train_dynamic_matcha_completes_hot_swap():
    """Acceptance: ``train.py --dynamic --designer matcha`` completes a
    controller hot-swap to a randomized schedule (traced-consensus step,
    no per-round re-lowering)."""
    import os
    import subprocess
    import sys

    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "internlm2-1.8b", "--reduced", "--dynamic",
            "--designer", "matcha", "--scenario", "silodegrade",
            "--steps", "30", "--seq-len", "16", "--batch-per-silo", "2",
        ],
        capture_output=True,
        text=True,
        timeout=560,
        env=env,
    )
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-2000:]
    assert "matcha schedule" in out  # initial budget-swept design
    assert "controller re-design -> randomized schedule" in out, out[-2000:]
    assert "final randomized schedule" in out


# ---------------------------------------------------------------------------
# Vectorized critical circuit (controller's bottleneck explanation)


def random_strong_digraph(rng, n):
    delays = {(i, (i + 1) % n): rng.uniform(0.5, 20.0) for i in range(n)}
    for i in range(n):
        delays[(i, i)] = rng.uniform(0.0, 5.0)
        j = rng.randrange(n)
        if j != i:
            delays[(i, j)] = rng.uniform(0.5, 20.0)
    return DelayDigraph(tuple(range(n)), delays)


def test_critical_circuit_dense_matches_legacy_tau_and_attains_it():
    for seed in range(60):
        rng = random.Random(seed)
        g = random_strong_digraph(rng, rng.randint(2, 12))
        tau_l, circ_l = critical_circuit_legacy(g)
        tau, circ = critical_circuit(g)
        assert tau == pytest.approx(tau_l, rel=1e-9)
        assert len(circ) >= 2 and circ[0] == circ[-1]
        hops = list(zip(circ[:-1], circ[1:]))
        mean = sum(g.delays[e] for e in hops) / len(hops)
        assert mean == pytest.approx(tau, rel=1e-6, abs=1e-6)


def test_critical_circuit_dense_acyclic_and_self_loop():
    dag = DelayDigraph((0, 1), {(0, 1): 2.0})
    W, _ = graph_to_matrix(dag)
    assert critical_circuit_dense(W) == (-math.inf, [])
    loop = DelayDigraph((0,), {(0, 0): 7.0})
    W, _ = graph_to_matrix(loop)
    tau, circ = critical_circuit_dense(W)
    assert tau == pytest.approx(7.0) and circ == [0, 0]
