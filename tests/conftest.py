"""Shared test configuration.

Shape contracts (:mod:`repro.analysis.contracts`) are runtime-checked
throughout the suite: every engine call in every test doubles as a
contract check.  Production runs leave the env var unset and pay only a
dict lookup per call.
"""

import os

os.environ.setdefault("REPRO_CHECK_CONTRACTS", "1")
