"""DPASGD + gossip semantics.

The multi-device checks run in a subprocess with 8 virtual host devices
(``tests/fed_worker.py``) so this pytest process keeps the default
single-device view.  Pure-python plan/bridge checks run inline."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fed.topology_runtime import plan_for_n_silos, plan_from_overlay


@pytest.mark.slow  # subprocess train acceptance: ci.sh --fast skips
def test_multi_device_fed_worker():
    script = os.path.join(os.path.dirname(__file__), "fed_worker.py")
    r = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL_FED_CHECKS_PASSED" in r.stdout
    for name in ("gossip_impls_agree", "dpasgd_trains_and_converges",
                 "full_mixing_equals_single_worker"):
        assert f"PASS:{name}" in r.stdout


def test_ring_plan_is_one_transfer_star_is_dense():
    ring = plan_for_n_silos("ring", 8)
    star = plan_for_n_silos("star", 8)
    assert ring.num_transfers == 1
    assert star.num_transfers == 7  # full averaging = N-1 permutations


def test_chain_plan_matches_local_degree_matrix():
    plan = plan_for_n_silos("chain", 5)
    from repro.core.consensus import is_doubly_stochastic

    assert is_doubly_stochastic(plan.matrix)
    assert plan.num_transfers >= 2  # needs left+right neighbour transfers


def test_plan_from_designed_overlay():
    """Bridge from the paper's designed overlays to runtime plans."""
    import repro.core as C

    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M)
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    ring = C.ring_overlay(gc, tp)
    plan = plan_from_overlay(ring, gc.num_silos)
    assert plan.num_transfers == 1
    mst = C.mst_overlay(gc, tp)
    plan_mst = plan_from_overlay(mst, gc.num_silos)
    from repro.core.consensus import is_doubly_stochastic

    assert is_doubly_stochastic(plan_mst.matrix)
    deg = max(max(mst.out_degree(v) for v in gc.silos), 1)
    assert plan_mst.num_transfers <= 2 * deg + 2
    # schedule traffic prediction: ring strictly cheaper than star
    from repro.fed.gossip import collective_bytes_per_round

    star_plan = plan_from_overlay(
        C.star_overlay(gc, tp, center=u.load_centrality_center()), gc.num_silos)
    pb = 10_000_000
    assert collective_bytes_per_round(plan, pb) < collective_bytes_per_round(
        star_plan, pb)
