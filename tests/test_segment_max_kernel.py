"""Pallas segment-max kernel: bit-identity against ``jax.ops.segment_max``
(interpret mode on CPU), the dispatch policy, and the degree-padded
Karp path it competes with."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

pytest.importorskip("jax")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.maxplus_sparse import (  # noqa: E402
    EdgeBatch,
    batched_cycle_time_sparse,
    batched_cycle_time_sparse_jax,
)
from repro.kernels.ops import edge_segment_max  # noqa: E402
from repro.kernels.segment_max import (  # noqa: E402
    edge_segment_max_pallas,
    segment_max,
    segment_max_pallas,
    select_segment_max_impl,
)


def _ref_flat(vals, ids, S):
    return jax.ops.segment_max(jnp.asarray(vals), jnp.asarray(ids),
                               num_segments=S)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 90), st.integers(1, 40),
       st.integers(0, 2 ** 31 - 1))
def test_edge_segment_max_bit_identical(B, E, S, seed):
    """Random values (including -inf entries and out-of-range ids) match
    vmapped ``jax.ops.segment_max`` bit for bit, empty segments included."""
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal((B, E)).astype(np.float32)
    vals[rng.random((B, E)) < 0.15] = -np.inf
    # ids in [-1, S]: -1 and S are out of range and must be dropped,
    # exactly like segment_max's out-of-bounds scatter semantics.
    ids = rng.integers(-1, S + 1, size=(B, E)).astype(np.int32)
    got = edge_segment_max_pallas(vals, ids, S, block=32, n_block=16,
                                  interpret=True)
    want = jax.vmap(lambda v, i: _ref_flat(v, i, S))(
        jnp.asarray(vals), jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_flat_form_and_jitted_wrapper_bit_identical():
    rng = np.random.default_rng(0)
    vals = rng.standard_normal(513).astype(np.float64)
    ids = rng.integers(0, 100, size=513).astype(np.int32)
    want = np.asarray(_ref_flat(vals, ids, 100))
    got_flat = segment_max_pallas(vals, ids, 100, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_flat), want)
    got_router = segment_max(jnp.asarray(vals), jnp.asarray(ids), 100,
                             impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got_router), want)
    got_jit = edge_segment_max(jnp.asarray(vals)[None], jnp.asarray(ids)[None],
                               num_segments=100, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_jit)[0], want)


def test_all_segments_empty_is_all_neg_inf():
    vals = np.full((2, 8), -np.inf, dtype=np.float32)
    ids = np.full((2, 8), -1, dtype=np.int32)
    out = np.asarray(edge_segment_max_pallas(vals, ids, 5, interpret=True))
    assert np.all(np.isneginf(out)) and out.shape == (2, 5)


def test_int_dtype_rejected():
    with pytest.raises(TypeError):
        edge_segment_max_pallas(np.ones((1, 4), dtype=np.int32),
                                np.zeros((1, 4), dtype=np.int32), 3,
                                interpret=True)


def test_dispatch_policy_on_cpu():
    """On this (CPU, interpret-default) container auto must never pick
    the interpret Pallas path: padded when the caller can bound the
    in-degree, xla otherwise.  Explicit names pass through."""
    assert select_segment_max_impl("auto") == "xla"
    assert select_segment_max_impl("auto", padded=True) == "padded"
    for name in ("xla", "padded", "pallas"):
        assert select_segment_max_impl(name) == name
        assert select_segment_max_impl(name, padded=True) == name
    with pytest.raises(ValueError):
        select_segment_max_impl("mosaic")
    with pytest.raises(ValueError):
        segment_max(jnp.ones(4), jnp.zeros(4, jnp.int32), 2, impl="padded")


def _random_edge_batch(rng, B, n, deg):
    """Strongly cyclic sparse batch with in-degree <= deg + 1 (ring +
    chords + self-loops), f32 weights."""
    E = n * (deg + 1)
    src = np.empty((B, E), dtype=np.int32)
    dst = np.empty((B, E), dtype=np.int32)
    w = np.empty((B, E), dtype=np.float32)
    idx = np.arange(n, dtype=np.int32)
    for b in range(B):
        cols = [(idx, np.roll(idx, -1))]
        for off in rng.choice(np.arange(2, n - 1), size=deg - 1,
                              replace=False):
            cols.append((idx, (idx + off) % n))
        cols.append((idx, idx))
        src[b] = np.concatenate([s for (s, _) in cols])
        dst[b] = np.concatenate([d for (_, d) in cols])
        w[b] = rng.uniform(0.5, 20.0, E).astype(np.float32)
    return src, dst, w


@pytest.mark.parametrize("kernel,kw", [
    ("padded", {"max_in_degree": 6}),
    ("pallas", {}),
])
def test_karp_recursion_kernels_bit_identical_to_xla(kernel, kw):
    """The hot Karp recursion produces bit-identical cycle times through
    every segment-max implementation (max is exact, order-independent)."""
    rng = np.random.default_rng(3)
    src, dst, w = _random_edge_batch(rng, B=3, n=24, deg=4)
    ref = batched_cycle_time_sparse_jax(src, dst, w, 24, kernel="xla")
    got = batched_cycle_time_sparse_jax(src, dst, w, 24, kernel=kernel, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and the xla path agrees with the host oracle to fp tolerance
    host = batched_cycle_time_sparse(
        EdgeBatch(src, dst, w.astype(np.float64), 24))
    np.testing.assert_allclose(np.asarray(ref, np.float64), host, rtol=1e-5)


def test_padded_layout_drops_absent_arcs_before_ranking():
    """Regression: -inf (absent) arcs must not consume degree-table
    slots and evict real arcs sharing the destination."""
    n = 4
    # 5 arcs into node 0: 3 absent (-inf), 2 real; D=2 only fits the
    # real ones if absent arcs are routed out of the segment first.
    src = np.array([[1, 2, 3, 1, 2, 0, 1, 2, 3]], dtype=np.int32)
    dst = np.array([[0, 0, 0, 0, 0, 1, 2, 3, 1]], dtype=np.int32)
    w = np.array([[-np.inf, -np.inf, -np.inf, 3.0, 4.0,
                   1.0, 1.0, 1.0, 1.0]], dtype=np.float64)
    ref = batched_cycle_time_sparse_jax(src, dst, w, n, kernel="xla")
    got = batched_cycle_time_sparse_jax(src, dst, w, n, kernel="padded",
                                        max_in_degree=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
