"""Vectorized max-plus engine: old-vs-new equivalence and batched APIs.

The legacy dict-based implementations (``*_legacy``) are the oracle: the
dense/batched engine must reproduce them exactly (same floats up to
associativity noise) on arbitrary digraphs — strongly connected or not,
cyclic or not.
"""

import math
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core.maxplus import (
    DelayDigraph,
    critical_circuit,
    cycle_time,
    empirical_cycle_time,
    is_strongly_connected,
    max_cycle_mean,
    max_cycle_mean_legacy,
    timing_recursion,
    timing_recursion_legacy,
)
from repro.core.maxplus_vec import (
    batched_cycle_time,
    batched_is_strongly_connected,
    batched_timing_recursion,
    cycle_time_dense,
    edges_to_matrix,
    graph_to_matrix,
    reachability_closure,
    scc_labels,
    timing_recursion_dense,
)


def random_digraph(rng, n, density=0.35, allow_negative=False):
    lo = -5.0 if allow_negative else 0.1
    delays = {}
    for i in range(n):
        for j in range(n):
            if rng.random() < density:
                delays[(i, j)] = rng.uniform(lo, 20.0)
    if not delays:
        delays[(0, 0)] = rng.uniform(0.1, 5.0)
    return DelayDigraph(tuple(range(n)), delays)


def random_strong_digraph(rng, n):
    """Ring (guarantees strong connectivity) + random chords + self loops."""
    delays = {(i, (i + 1) % n): rng.uniform(0.5, 20.0) for i in range(n)}
    for i in range(n):
        delays[(i, i)] = rng.uniform(0.0, 5.0)
        j = rng.randrange(n)
        if j != i:
            delays[(i, j)] = rng.uniform(0.5, 20.0)
    return DelayDigraph(tuple(range(n)), delays)


def test_equivalence_on_100_random_digraphs():
    """Acceptance: batched_cycle_time == legacy Karp on >= 100 digraphs,
    including disconnected, acyclic, and negative-weight instances."""
    rng = random.Random(20260729)
    graphs = []
    for trial in range(120):
        n = rng.randint(1, 9)
        g = random_digraph(
            rng, n, density=rng.uniform(0.15, 0.9),
            allow_negative=(trial % 3 == 0),
        )
        graphs.append(g)
    for g in graphs:
        legacy = max_cycle_mean_legacy(g)
        W, _ = graph_to_matrix(g)
        vec = cycle_time_dense(W)
        if legacy == -math.inf:
            assert vec == -math.inf
        else:
            assert vec == pytest.approx(legacy, rel=1e-9, abs=1e-9)


def test_batched_matches_per_graph_on_common_size():
    rng = random.Random(7)
    n = 8
    graphs = [random_digraph(rng, n, density=0.4) for _ in range(64)]
    W = np.stack([edges_to_matrix(g.delays, g.nodes) for g in graphs])
    taus = batched_cycle_time(W)
    for k, g in enumerate(graphs):
        expect = max_cycle_mean_legacy(g)
        if expect == -math.inf:
            assert taus[k] == -math.inf
        else:
            assert taus[k] == pytest.approx(expect, rel=1e-9)


def test_batched_chunking_is_invisible():
    rng = random.Random(11)
    W = np.stack(
        [edges_to_matrix(g.delays, g.nodes)
         for g in (random_digraph(rng, 6, 0.5) for _ in range(33))]
    )
    full = batched_cycle_time(W)
    tiny_chunks = batched_cycle_time(W, max_dp_bytes=W.shape[1] * 100)
    np.testing.assert_array_equal(full, tiny_chunks)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000))
def test_property_strong_equivalence(n, seed):
    """cycle_time (vec) == legacy Karp on random strongly-connected digraphs."""
    g = random_strong_digraph(random.Random(seed), n)
    assert is_strongly_connected(g)
    assert cycle_time(g) == pytest.approx(max_cycle_mean_legacy(g), rel=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_property_strong_connectivity_equivalence(n, seed):
    rng = random.Random(seed)
    g = random_digraph(rng, n, density=rng.uniform(0.1, 0.7))
    W, _ = graph_to_matrix(g)
    # legacy oracle: Tarjan SCC count
    from repro.core.maxplus import strongly_connected_components

    sccs = strongly_connected_components(g)
    legacy = len(sccs) == 1 and len(sccs[0]) == g.num_nodes
    assert bool(batched_is_strongly_connected(W)) == legacy


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_property_timing_recursion_equivalence(n, seed):
    g = random_strong_digraph(random.Random(seed), n)
    legacy = timing_recursion_legacy(g, 30)
    W, nodes = graph_to_matrix(g)
    dense = timing_recursion_dense(W, 30)
    for k, v in enumerate(nodes):
        np.testing.assert_allclose(legacy[v], dense[:, k], rtol=1e-12)
    # and the public dict API (now vectorized) agrees with its legacy self
    new = timing_recursion(g, 30)
    for v in nodes:
        np.testing.assert_allclose(legacy[v], new[v], rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 8), st.integers(0, 10_000))
def test_property_recursion_slope_is_tau(n, seed):
    """t_i(k)/k -> tau through the dense recursion (Thm 3.23)."""
    g = random_strong_digraph(random.Random(seed), n)
    tau = cycle_time(g)
    est = empirical_cycle_time(g, num_rounds=400)
    assert est == pytest.approx(tau, rel=0.05, abs=0.05)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_property_critical_circuit_attains_tau(n, seed):
    """The returned circuit's own mean must equal the reported tau."""
    g = random_strong_digraph(random.Random(seed), n)
    tau, circ = critical_circuit(g)
    assert len(circ) >= 2 and circ[0] == circ[-1]
    hops = list(zip(circ[:-1], circ[1:]))
    mean = sum(g.delays[e] for e in hops) / len(hops)
    assert mean == pytest.approx(tau, rel=1e-6, abs=1e-6)


def test_batched_timing_recursion_shapes_and_slope():
    rng = random.Random(3)
    graphs = [random_strong_digraph(rng, 6) for _ in range(8)]
    W = np.stack([edges_to_matrix(g.delays, g.nodes) for g in graphs])
    series = batched_timing_recursion(W, 200)
    assert series.shape == (8, 201, 6)
    taus = batched_cycle_time(W)
    slopes = np.max((series[:, 200] - series[:, 100]) / 100.0, axis=1)
    np.testing.assert_allclose(slopes, taus, rtol=0.05, atol=0.05)


def test_scc_labels_matrix_vs_tarjan():
    rng = random.Random(5)
    for _ in range(25):
        n = rng.randint(1, 12)
        A = np.zeros((n, n), dtype=bool)
        for i in range(n):
            for j in range(n):
                if i != j and rng.random() < 0.25:
                    A[i, j] = True
        dense = scc_labels(A, dense_threshold=1024)
        tarjan = scc_labels(A, dense_threshold=0)
        # labels may differ by name but must induce the same partition
        f, g = {}, {}
        for a, b in zip(dense.tolist(), tarjan.tolist()):
            assert f.setdefault(a, b) == b
            assert g.setdefault(b, a) == a


def test_reachability_closure_tiny():
    A = np.array([[0, 1, 0], [0, 0, 1], [0, 0, 0]], dtype=bool)
    R = reachability_closure(A)
    assert R[0, 2] and R[0, 0] and not R[2, 0]


def test_jax_variant_matches_numpy():
    jax = pytest.importorskip("jax")
    rng = np.random.default_rng(0)
    B, N = 16, 10
    W = np.where(
        rng.random((B, N, N)) < 0.4,
        rng.uniform(0.1, 30.0, (B, N, N)),
        -np.inf,
    )
    ref = batched_cycle_time(W)
    from repro.core.maxplus_vec import batched_cycle_time_jax

    got = np.asarray(jax.jit(batched_cycle_time_jax)(W))
    finite = np.isfinite(ref)
    np.testing.assert_array_equal(finite, np.isfinite(got))
    # jax default f32: compare at f32 tolerance
    np.testing.assert_allclose(got[finite], ref[finite], rtol=1e-4, atol=1e-4)


def test_acyclic_and_empty_conventions():
    # pure DAG: no circuit, tau = -inf
    dag = DelayDigraph((0, 1, 2), {(0, 1): 3.0, (1, 2): 4.0})
    W, _ = graph_to_matrix(dag)
    assert cycle_time_dense(W) == -math.inf
    assert max_cycle_mean(dag) == -math.inf
    # single self loop: tau = loop weight
    loop = DelayDigraph((0,), {(0, 0): 5.0})
    assert cycle_time(loop) == pytest.approx(5.0)
