"""Per-architecture smoke tests: a REDUCED variant of each assigned
architecture family (2 layers, d_model<=512, <=4 experts) runs one
forward pass and one train step on CPU; output shapes asserted, no NaNs.
The FULL configs are exercised only by the dry-run (ShapeDtypeStructs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, count_params
from repro.models import transformer as T
from repro.fed.dpasgd import local_sgd_steps, make_loss_fn
from repro.optim import sgd


def _extras(cfg, B):
    out = {}
    if cfg.is_encdec:
        out["enc_frames"] = jnp.ones((B, cfg.encoder.seq_len, 128), jnp.float32)
    if cfg.vision_prefix_len:
        out["vision_embeds"] = jnp.ones((B, cfg.vision_prefix_len, 1024), jnp.float32)
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_shapes_no_nan(arch_id):
    cfg = get_config(arch_id).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, T.model_specs(cfg))
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = _extras(cfg, B)
    logits, aux = T.forward(params, cfg, tokens, **extras)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: NaN in logits"
    assert bool(jnp.isfinite(aux)), f"{arch_id}: NaN aux loss"


@pytest.mark.slow  # train acceptance over the whole zoo (~5 min of the
# tier-1 wall time): ci.sh --fast skips; the forward-shape smoke above
# still covers every arch in the fast lane
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step_decreases_loss(arch_id):
    cfg = get_config(arch_id).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, T.model_specs(cfg))
    opt = sgd(0.1)
    opt_state = opt.init(params)
    B, S = 2, 16
    tokens = jax.random.randint(key, (1, B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update({k: v[None] for k, v in _extras(cfg, B).items()})
    loss_fn = make_loss_fn(cfg)
    l0 = loss_fn(params, jax.tree_util.tree_map(lambda x: x[0], batch))
    p, o, s_, l1 = local_sgd_steps(loss_fn, opt, params, opt_state, batch,
                                   jnp.zeros((), jnp.int32))
    for _ in range(4):
        p, o, s_, l2 = local_sgd_steps(loss_fn, opt, p, o, batch, s_)
    assert bool(jnp.isfinite(l2))
    assert float(l2) < float(l0), f"{arch_id}: loss did not decrease"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_step(arch_id):
    cfg = get_config(arch_id).reduced()
    if cfg.vision_prefix_len:
        pytest.skip("VLM decode exercised via dry-run serve_step")
    key = jax.random.PRNGKey(2)
    params = init_params(key, T.model_specs(cfg))
    B = 2
    cache = T.init_cache(cfg, B, 64, jnp.float32)
    if cfg.is_encdec:
        enc_out = T.encode(params, cfg, _extras(cfg, B)["enc_frames"])
        xc = T.prefill_cross_cache(params, cfg, enc_out)
        for i, (xk, xv) in enumerate(xc):
            cache[i]["xk"] = xk
            cache[i]["xv"] = xv
    tok = jnp.zeros((B,), jnp.int32)
    for pos in range(3):
        logits, cache = T.decode_step(params, cfg, tok, cache, jnp.int32(pos))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = logits.argmax(-1).astype(jnp.int32)


def test_full_config_dims_match_assignment():
    expect = {
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (L, D, H, K, F, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D, arch
        assert cfg.n_heads == H and cfg.n_kv_heads == K, arch
        assert cfg.vocab_size == V, arch
        if arch == "qwen3-moe-30b-a3b":
            assert cfg.moe.d_expert == 768 and cfg.moe.n_experts == 128
            assert cfg.moe.top_k == 8
        elif arch == "deepseek-v2-lite-16b":
            assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
            assert cfg.mla.kv_lora_rank == 512
        else:
            assert cfg.d_ff == F, arch


def test_param_counts_in_family_range():
    """Total parameter counts should be near the advertised sizes."""
    import repro.models.transformer as TT

    targets = {
        "xlstm-350m": (0.2e9, 0.6e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "granite-20b": (15e9, 25e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "mistral-large-123b": (100e9, 135e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "internvl2-76b": (60e9, 85e9),
    }
    for arch, (lo, hi) in targets.items():
        cfg = get_config(arch)
        n = count_params(TT.model_specs(cfg))
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
