"""Protocol typestate rules: positive/negative snippets per rule, the
four seeded-injection acceptance tests (mutating real repo files), and
the runtime cross-check replaying a FlightRecorder churn trace through
the same slot-ordering machine the static rule interprets."""

import ast
import textwrap

import pytest

from repro.analysis.lint import LintConfig, lint_source
from repro.analysis.protocols import Protocol, Replay, ReplayError, \
    run_protocol
from repro.analysis.rules import PROTOCOL_RULES
from repro.analysis.rules.slot_protocol import ORDERING_PROTOCOL, \
    replay_slot_trace


def run(src, path="src/repro/dynamics/snippet.py", config=None,
        extra_files=None):
    return lint_source(textwrap.dedent(src), path=path, config=config,
                       extra_files=extra_files)


def rules_of(vs):
    return {v.rule for v in vs}


# ---------------------------------------------------------------------------
# slot-protocol
# ---------------------------------------------------------------------------

class TestSlotProtocol:
    def test_resize_without_membership_swap_flagged(self):
        vs = run("""
            def actuate(schedule_slot, sched, gc):
                schedule_slot.swap_schedule(
                    sched, label="x", silos=tuple(gc.silos))
            """)
        assert any(v.rule == "slot-protocol" and "resizing" in v.message
                   for v in vs)

    def test_plan_resize_without_membership_swap_flagged(self):
        vs = run("""
            def actuate(plan_slot, plan):
                plan_slot.swap(plan, label="x", allow_resize=True)
            """)
        assert any(v.rule == "slot-protocol" and "resizing" in v.message
                   for v in vs)

    def test_membership_swap_before_resize_is_clean(self):
        vs = run("""
            def actuate(membership_slot, plan_slot, plan, active):
                membership_slot.swap(active, label="churn")
                plan_slot.swap(plan, label="x", allow_resize=True)
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_branch_correlated_swap_is_clean(self):
        # the real controller shape: swap guarded on slot presence,
        # resize on the shared continuation.  One clean path suffices.
        vs = run("""
            def actuate(self, plan, active):
                if self.membership_slot is not None:
                    self.membership_slot.swap(active, label="churn")
                self.plan_slot.swap(plan, label="x", allow_resize=True)
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_non_resizing_swap_needs_no_membership(self):
        vs = run("""
            def actuate(plan_slot, plan):
                plan_slot.swap(plan, label="x")
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_literal_false_resize_is_clean(self):
        vs = run("""
            def actuate(plan_slot, plan):
                plan_slot.swap(plan, label="x", allow_resize=False)
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_direct_field_store_flagged(self):
        vs = run("""
            def patch(plan_slot, plan):
                plan_slot.plan = plan
            """)
        assert any(v.rule == "slot-protocol" and "bypasses" in v.message
                   for v in vs)

    def test_version_read_on_fresh_slot_flagged(self):
        vs = run("""
            def build(plan):
                slot = PlanSlot(plan)
                return slot.version
            """)
        assert any(v.rule == "slot-protocol"
                   and "never-swapped" in v.message for v in vs)

    def test_version_read_after_swap_is_clean(self):
        vs = run("""
            def build(plan):
                slot = PlanSlot(plan)
                slot.swap(plan, label="init")
                return slot.version
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_version_read_on_external_slot_is_clean(self):
        # a slot received as a parameter has unknown swap history
        vs = run("""
            def probe(plan_slot):
                return plan_slot.version
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_escaped_slot_is_not_tracked(self):
        vs = run("""
            def build(plan, registry):
                slot = PlanSlot(plan)
                registry.register(slot)
                return slot.version
            """)
        assert "slot-protocol" not in rules_of(vs)

    def test_home_module_exempt(self):
        vs = run("""
            def swap(self, plan):
                self.version += 1
                self.plan = plan
            """, path="src/repro/fed/gossip.py")
        assert "slot-protocol" not in rules_of(vs)


# ---------------------------------------------------------------------------
# pricer-protocol
# ---------------------------------------------------------------------------

class TestPricerProtocol:
    def test_commit_without_price_flagged(self):
        vs = run("""
            def bad(src, dst, w, n, pm):
                dp = DeltaPricer(src, dst, w, n)
                dp.commit(pm)
            """)
        assert any(v.rule == "pricer-protocol"
                   and "no live certificate" in v.message for v in vs)

    def test_stale_certificate_commit_flagged(self):
        vs = run("""
            def bad(src, dst, w, n, slots, moves):
                dp = DeltaPricer(src, dst, w, n)
                pm1 = dp.price(slots, src, dst, w)
                pm2 = dp.price(slots, src, dst, w)
                dp.commit(pm1)
            """)
        assert any(v.rule == "pricer-protocol" and "stale" in v.message
                   for v in vs)

    def test_reanchor_invalidates_certificate(self):
        vs = run("""
            def bad(src, dst, w, n, slots):
                dp = DeltaPricer(src, dst, w, n)
                pm = dp.price(slots, src, dst, w)
                dp.reanchor()
                dp.commit(pm)
            """)
        assert any(v.rule == "pricer-protocol" and "stale" in v.message
                   for v in vs)

    def test_price_commit_loop_with_continue_is_clean(self):
        # the search_overlays_delta shape: re-price each iteration,
        # commit only accepted moves
        vs = run("""
            def climb(src, dst, w, n, slots, moves):
                dp = DeltaPricer(src, dst, w, n)
                for m in moves:
                    pm = dp.price(slots, m.src, m.dst, m.w)
                    if pm.tau > 100.0:
                        continue
                    dp.commit(pm)
                dp.reanchor()
            """)
        assert "pricer-protocol" not in rules_of(vs)

    def test_update_is_self_contained(self):
        vs = run("""
            def step(src, dst, w, n, slots):
                dp = DeltaPricer(src, dst, w, n)
                dp.update(slots, src, dst, w)
            """)
        assert "pricer-protocol" not in rules_of(vs)

    def test_external_pricer_commit_not_flagged(self):
        # a pricer parameter has unknown history: may hold a live cert
        vs = run("""
            def apply(pricer, pm):
                pricer.commit(pm)
            """)
        assert "pricer-protocol" not in rules_of(vs)

    def test_escaped_pricer_not_tracked(self):
        vs = run("""
            def bad(src, dst, w, n, helper, pm):
                dp = DeltaPricer(src, dst, w, n)
                helper(dp)
                dp.commit(pm)
            """)
        assert "pricer-protocol" not in rules_of(vs)

    def test_schedule_price_is_not_a_pricer(self):
        # Schedule.price() shares the method name but not the protocol
        vs = run("""
            def estimate(schedule, gc, tp):
                return schedule.price(gc, tp, rounds=100).tau_ms
            """)
        assert "pricer-protocol" not in rules_of(vs)

    def test_force_full_literal_flagged_in_src(self):
        vs = run("""
            def bad(dp, slots, src, dst, w):
                return dp.price(slots, src, dst, w, force_full=True)
            """, path="src/repro/core/thing.py")
        assert any(v.rule == "pricer-protocol"
                   and "force_full" in v.message for v in vs)

    def test_force_full_literal_allowed_in_tests_and_benchmarks(self):
        snippet = """
            def probe(dp, slots, src, dst, w):
                return dp.price(slots, src, dst, w, force_full=True)
            """
        for path in ("tests/test_thing.py", "benchmarks/bench_thing.py"):
            vs = run(snippet, path=path)
            assert "pricer-protocol" not in rules_of(vs), path

    def test_force_full_variable_is_clean(self):
        vs = run("""
            def ok(dp, slots, src, dst, w, force_full):
                return dp.price(slots, src, dst, w,
                                force_full=force_full)
            """, path="src/repro/core/thing.py")
        assert "pricer-protocol" not in rules_of(vs)


# ---------------------------------------------------------------------------
# edgebatch-provenance
# ---------------------------------------------------------------------------

class TestEdgeBatchProvenance:
    def test_raw_arith_on_w_flagged(self):
        vs = run("""
            def bad(src, dst, w, n):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                return weights + 1.0
            """)
        assert any(v.rule == "edgebatch-provenance" for v in vs)

    def test_inline_field_arith_flagged(self):
        vs = run("""
            def bad(batch):
                return batch.w * 2.0
            """)
        assert any(v.rule == "edgebatch-provenance" for v in vs)

    def test_reduction_on_raw_field_flagged(self):
        vs = run("""
            import numpy as np

            def bad(src, dst, w, n):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                return np.sum(weights)
            """)
        assert any(v.rule == "edgebatch-provenance" for v in vs)

    def test_masked_then_arith_is_clean(self):
        vs = run("""
            import numpy as np

            def ok(src, dst, w, n):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                mask = missing_mask(weights)
                total = np.sum(np.where(mask, 0.0, weights))
                return weights + total
            """)
        assert "edgebatch-provenance" not in rules_of(vs)

    def test_branch_masked_on_one_path_is_clean(self):
        # must-reporting: one masked path keeps the join legal
        vs = run("""
            def ok(src, dst, w, n, flag):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                if flag:
                    missing_mask(weights)
                return weights + 1.0
            """)
        assert "edgebatch-provenance" not in rules_of(vs)

    def test_obligation_transfers_to_callee(self):
        vs = run("""
            def ok(src, dst, w, n, engine_fn):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                engine_fn(weights)
                return weights + 1.0
            """)
        assert "edgebatch-provenance" not in rules_of(vs)

    def test_engine_home_exempt(self):
        vs = run("""
            def kernel(eb):
                return eb.w + 0.0
            """, path="src/repro/core/maxplus_vec.py")
        assert "edgebatch-provenance" not in rules_of(vs)

    def test_untracked_object_is_clean(self):
        vs = run("""
            def ok(graph):
                return graph.w + 1.0
            """)
        assert "edgebatch-provenance" not in rules_of(vs)


# ---------------------------------------------------------------------------
# effect-purity (traced host effects; the loop facets are covered in
# test_lint_rules.py where they moved from trace-safety)
# ---------------------------------------------------------------------------

class TestEffectPurityTraced:
    def test_print_in_jitted_body_flagged(self):
        vs = run("""
            import jax

            @jax.jit
            def step(x):
                print("step!")
                return x + 1
            """)
        assert any(v.rule == "effect-purity"
                   and "trace time" in v.message for v in vs)

    def test_clock_in_jax_twin_flagged(self):
        vs = run("""
            import time

            def cycle_time_jax(w):
                t0 = time.perf_counter()
                return w.max(), t0
            """)
        assert any(v.rule == "effect-purity" for v in vs)

    def test_global_write_in_traced_body_flagged(self):
        vs = run("""
            import jax

            _CALLS = 0

            @jax.jit
            def step(x):
                global _CALLS
                _CALLS += 1
                return x
            """)
        assert any(v.rule == "effect-purity" and "global" in v.message
                   for v in vs)

    def test_host_function_may_print(self):
        vs = run("""
            def report(x):
                print(x)
                return x
            """)
        assert "effect-purity" not in rules_of(vs)


# ---------------------------------------------------------------------------
# seeded injections into the real tree (acceptance)
# ---------------------------------------------------------------------------

def _lint_real(path, appended=""):
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    return lint_source(src + textwrap.dedent(appended), path=path)


class TestSeededInjections:
    """Mutation tests: each rule catches a violation seeded into the
    real module it guards, and the unmutated module is clean."""

    def test_clean_tree_has_no_protocol_violations(self):
        for path in ("src/repro/dynamics/controller.py",
                     "src/repro/core/topologies.py",
                     "src/repro/launch/train.py",
                     "src/repro/dynamics/events.py"):
            vs = _lint_real(path)
            fresh = {v.rule for v in vs}
            assert not (fresh & set(PROTOCOL_RULES)), (path, vs)
            assert "effect-purity" not in fresh, (path, vs)

    def test_slot_protocol_injection_caught(self):
        vs = _lint_real("src/repro/dynamics/controller.py", """

            def _injected_bad_resize(schedule_slot, sched, gc):
                schedule_slot.swap_schedule(
                    sched, label="bad", silos=tuple(gc.silos))
            """)
        hits = [v for v in vs if v.rule == "slot-protocol"]
        assert len(hits) == 1
        assert hits[0].func == "_injected_bad_resize"

    def test_pricer_protocol_injection_caught(self):
        vs = _lint_real("src/repro/core/topologies.py", """

            def _injected_stale_commit(src, dst, w, n, slots):
                dp = DeltaPricer(src, dst, w, n)
                pm = dp.price(slots, src, dst, w)
                dp.reanchor()
                dp.commit(pm)
            """)
        hits = [v for v in vs if v.rule == "pricer-protocol"]
        assert len(hits) == 1
        assert hits[0].func == "_injected_stale_commit"

    def test_edgebatch_injection_caught(self):
        vs = _lint_real("src/repro/dynamics/simulate.py", """

            def _injected_raw_sum(src, dst, w, n):
                eb = EdgeBatch(src, dst, w, n)
                weights = eb.w
                return np.sum(weights)
            """)
        hits = [v for v in vs if v.rule == "edgebatch-provenance"]
        assert len(hits) == 1
        assert hits[0].func == "_injected_raw_sum"

    def test_effect_purity_injection_caught(self):
        vs = _lint_real("src/repro/launch/train.py", """

            def _injected_loop_sync(step_fn, xs):
                out = []
                for x in xs:
                    out.append(float(step_fn(x)))
                return out
            """)
        hits = [v for v in vs if v.rule == "effect-purity"]
        assert len(hits) == 1
        assert hits[0].func == "_injected_loop_sync"


# ---------------------------------------------------------------------------
# declarative machine + runtime replay
# ---------------------------------------------------------------------------

class TestReplayMachine:
    def test_legal_sequence(self):
        r = Replay(ORDERING_PROTOCOL)
        for ev in ("membership_swap", "resize", "redesign", "redesign"):
            r.feed(ev)
        assert r.state == "idle"
        assert r.errors == []

    def test_resize_in_idle_raises(self):
        r = Replay(ORDERING_PROTOCOL)
        with pytest.raises(ReplayError):
            r.feed("resize")

    def test_freshness_does_not_survive_redesign(self):
        r = Replay(ORDERING_PROTOCOL)
        r.feed("membership_swap")
        r.feed("redesign")
        with pytest.raises(ReplayError):
            r.feed("resize")

    def test_non_strict_collects_errors(self):
        r = Replay(ORDERING_PROTOCOL)
        r.feed("resize", strict=False)
        assert len(r.errors) == 1

    def test_trace_record_mapping(self):
        bad_trace = [
            {"kind": "round", "step": 0},
            {"kind": "swap", "slot": "schedule", "resized": True},
        ]
        with pytest.raises(ReplayError):
            replay_slot_trace(bad_trace)
        ok_trace = [
            {"kind": "membership", "step": 3},
            {"kind": "swap", "slot": "schedule", "resized": True},
            {"kind": "swap", "slot": "plan", "resized": True},
            {"kind": "redesign", "step": 3},
            {"kind": "swap", "slot": "plan"},  # pre-PR10 record: no field
        ]
        r = replay_slot_trace(ok_trace)
        assert r.errors == []


def _churn_trace(tmp_path, with_membership_slot):
    """Drive a real churn scenario through the controller with a
    FlightRecorder attached; return the validated records."""
    import repro.core as C
    from repro.core.delays import TrainingParams
    from repro.dynamics import (ControllerConfig, DynamicTimeline,
                                OnlineTopologyController, active_subgraph,
                                churn_scenario)
    from repro.fed.gossip import MembershipSlot, PlanSlot, ScheduleSlot
    from repro.fed.topology_runtime import plan_from_overlay
    from repro.obs.events import FlightRecorder, validate_trace

    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    ring = C.design_overlay("ring", gc, tp)
    tau = ring.cycle_time_ms
    sc = churn_scenario(u, Tc, silo=5, t_leave_ms=20 * tau,
                        t_rejoin_ms=50 * tau, horizon_ms=200 * tau)
    timeline = DynamicTimeline(sc, tp)
    timeline.set_overlay(ring.edges)
    plan_slot = PlanSlot(plan_from_overlay(ring, gc.num_silos))
    mem = (MembershipSlot(range(u.num_silos), u.num_silos)
           if with_membership_slot else None)
    trace = str(tmp_path / "churn.jsonl")
    with FlightRecorder(trace, silo_names=list(gc.silos)) as rec:
        controller = OnlineTopologyController(
            gc, tp, ring,
            config=ControllerConfig(seed=0, rewire_restarts=0),
            connectivity_provider=lambda: active_subgraph(
                timeline.current_epoch().gc,
                timeline.current_epoch().active),
            plan_slot=plan_slot,
            membership_slot=mem,
            membership_provider=timeline.current_active,
            recorder=rec,
            silo_names=list(gc.silos),
        )
        for _ in range(150):
            rd = controller.observe_round(timeline.step())
            if rd is not None:
                timeline.set_overlay(rd.overlay.edges)
    records, problems = validate_trace(trace)
    assert problems == []
    return records


@pytest.mark.slow  # full churn simulation: ci.sh --fast skips
class TestRuntimeCrossCheck:
    def test_churn_trace_replays_clean_and_static_agrees(self, tmp_path):
        """The instrumented churn run's trace satisfies the slot
        machine, and the static verdict on the controller module agrees
        (no slot-protocol violations in the code that produced it)."""
        records = _churn_trace(tmp_path, with_membership_slot=True)
        resizes = [r for r in records
                   if r.get("kind") == "swap" and r.get("resized")]
        assert resizes, "scenario produced no resizing swap"
        replay = replay_slot_trace(records)
        assert replay.errors == []
        # static side of the cross-check
        vs = _lint_real("src/repro/dynamics/controller.py")
        assert not any(v.rule == "slot-protocol" for v in vs)

    def test_no_membership_slot_churn_never_resizes(self, tmp_path):
        """Without a MembershipSlot the controller must take the
        audit-note path instead of resizing — the trace stays
        protocol-clean by *not* containing a resize, which is exactly
        the runtime shadow of the static audit-note fix."""
        records = _churn_trace(tmp_path, with_membership_slot=False)
        assert not any(r.get("kind") == "swap" and r.get("resized")
                       for r in records)
        replay = replay_slot_trace(records)
        assert replay.errors == []


# ---------------------------------------------------------------------------
# machine registry sanity
# ---------------------------------------------------------------------------

class TestProtocolRegistry:
    def test_registered_machines_are_well_formed(self):
        assert set(PROTOCOL_RULES) == {"slot-protocol", "pricer-protocol",
                                       "edgebatch-provenance"}
        for rule_id, proto in PROTOCOL_RULES.items():
            assert isinstance(proto, Protocol)
            assert proto.rule_id == rule_id
            assert proto.states
            assert proto.home
            assert proto.errors
            # every error state is a declared state
            for (state, _event) in proto.errors:
                assert state in proto.states + (proto.hint_initial,)

    def test_run_protocol_module_level_code(self):
        # module-level statements are a degenerate "function" body
        tree = ast.parse(textwrap.dedent("""
            slot = PlanSlot(plan)
            v = slot.version
            """))
        findings = run_protocol(PROTOCOL_RULES["slot-protocol"], tree)
        assert len(findings) == 1
