"""Multi-device federated tests, executed in a subprocess with 8 virtual
host devices (the main pytest process keeps the default single device,
per the dry-run isolation rule).  Each check prints PASS:<name>."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.fed import DPASGDConfig, make_train_step, init_state
from repro.fed.gossip import gossip_einsum, gossip_shard_map
from repro.fed.topology_runtime import plan_for_n_silos
from repro.models import ModelConfig
from repro.optim import sgd
from repro.data import SyntheticLMStream, FederatedBatcher


def small_cfg(n_silos):
    return ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 256, n_silos=n_silos)


from repro.launch.mesh import compat_make_mesh, mesh_context as mesh_ctx


def make_mesh(n):
    return compat_make_mesh((n,), ("data",))


def shard_state(state, mesh):
    def put(x):
        if getattr(x, "ndim", 0) > 0:
            return jax.device_put(
                x, NamedSharding(mesh, P(*(("data",) + (None,) * (x.ndim - 1)))))
        return x

    return jax.tree_util.tree_map(put, state)


def check_gossip_impls_agree():
    n = 4
    mesh = make_mesh(n)
    cfg = small_cfg(n)
    state = init_state(cfg, sgd(0.1), jax.random.PRNGKey(0))
    params = shard_state(state, mesh)["params"]
    for kind in ("ring", "star", "chain"):
        plan = plan_for_n_silos(kind, n)
        A = jnp.asarray(plan.matrix)
        with mesh_ctx(mesh):
            ein = gossip_einsum(params, A)
            ppm = gossip_shard_map(params, plan, mesh, "data")
            pal = gossip_shard_map(params, plan, mesh, "data", use_pallas=True)
        for a, b in zip(jax.tree_util.tree_leaves(ein),
                        jax.tree_util.tree_leaves(ppm)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(ein),
                        jax.tree_util.tree_leaves(pal)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    print("PASS:gossip_impls_agree")


def check_dpasgd_trains_and_converges():
    n = 4
    mesh = make_mesh(n)
    cfg = small_cfg(n)
    opt = sgd(0.05)
    plan = plan_for_n_silos("ring", n)
    fed = DPASGDConfig(local_steps=2, gossip_impl="ppermute", silo_axis="data")
    step_fn = make_train_step(cfg, fed, opt, plan, mesh)
    state = shard_state(init_state(cfg, opt, jax.random.PRNGKey(0)), mesh)
    stream = SyntheticLMStream(cfg.vocab_size, 32, n_silos=n)
    batcher = FederatedBatcher(stream, local_steps=2, batch_per_silo=4)
    jstep = jax.jit(step_fn)
    losses = []
    with mesh_ctx(mesh):
        for i in range(8):
            b = {k: jnp.asarray(v) for k, v in batcher.batch(i).items()}
            state, m = jstep(state, b)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    w = np.asarray(state["params"]["embed"])
    spread = np.abs(w - w.mean(0, keepdims=True)).max()
    assert spread < 0.5 * np.abs(w).max()
    print("PASS:dpasgd_trains_and_converges")


def check_full_mixing_equals_single_worker():
    n = 4
    mesh = make_mesh(n)
    cfg = small_cfg(n)
    opt = sgd(0.1)
    plan = plan_for_n_silos("star", n)
    fed = DPASGDConfig(local_steps=1, gossip_impl="ppermute", silo_axis="data")
    step_fn = make_train_step(cfg, fed, opt, plan, mesh)
    key = jax.random.PRNGKey(1)
    from repro.models import init_params
    from repro.models.transformer import model_specs

    p0 = init_params(key, model_specs(cfg))
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), p0)
    state = {"params": params,
             "opt_state": jax.vmap(opt.init)(params),
             "step": jnp.zeros((), jnp.int32)}
    state = shard_state(state, mesh)
    stream = SyntheticLMStream(cfg.vocab_size, 16, n_silos=1, seed=3)
    one = stream.sample(0, 4, 0)
    batch = {k: jnp.broadcast_to(jnp.asarray(v)[None, None], (n, 1) + v.shape)
             for k, v in one.items()}
    with mesh_ctx(mesh):
        state, _ = jax.jit(step_fn)(state, batch)
    from repro.fed.dpasgd import local_sgd_steps, make_loss_fn

    loss_fn = make_loss_fn(ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 256))
    ref_p, _, _, _ = local_sgd_steps(
        loss_fn, opt, p0, opt.init(p0),
        {k: jnp.asarray(v)[None] for k, v in one.items()},
        jnp.zeros((), jnp.int32))
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), state["params"])
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref_p)):
        np.testing.assert_allclose(a, np.asarray(b), atol=1e-5)
    print("PASS:full_mixing_equals_single_worker")


if __name__ == "__main__":
    check_gossip_impls_agree()
    check_dpasgd_trains_and_converges()
    check_full_mixing_equals_single_worker()
    print("ALL_FED_CHECKS_PASSED")
