"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gossip_mix import gossip_mix_pallas
from repro.kernels.mlstm_scan import mlstm_scan_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _attn_inputs(key, B, S, K, G, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,K,G,hd,bq,bkv", [
    (1, 128, 1, 1, 64, 64, 64),
    (2, 256, 2, 2, 64, 128, 128),
    (1, 256, 4, 1, 128, 64, 128),
    (2, 128, 1, 4, 32, 32, 64),
])
def test_flash_attention_shapes_dtypes(B, S, K, G, hd, bq, bkv, dtype):
    q, k, v = _attn_inputs(jax.random.PRNGKey(B * S), B, S, K, G, hd, dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=None,
                                 block_q=bq, block_kv=bkv, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    q, k, v = _attn_inputs(jax.random.PRNGKey(7), 1, 256, 2, 2, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_kv=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_attention_matches_model_chunked_reference():
    """The model's chunked jnp attention and the Pallas kernel implement
    the same contract."""
    from repro.models.attention import chunked_attention

    q, k, v = _attn_inputs(jax.random.PRNGKey(3), 2, 256, 2, 2, 64, jnp.float32)
    pos = jnp.arange(256, dtype=jnp.int32)
    a = chunked_attention(q, k, v, pos, pos, causal=True, window=64)
    b = flash_attention_pallas(q, k, v, causal=True, window=64,
                               block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from([1, 2, 3]),           # K neighbours
    st.integers(1, 5),                    # size multiplier
    st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_gossip_mix_property(k_extra, mult, dtype):
    K = k_extra + 1
    N = 1000 * mult + 13
    key = jax.random.PRNGKey(K * N)
    nb = jax.random.normal(key, (K, N), jnp.float32).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (K,)))
    out = gossip_mix_pallas(nb, w, block=512, interpret=True)
    expect = ref.gossip_mix_ref(nb, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_gossip_mix_convex_combination_preserves_constants():
    """Mixing identical replicas with a stochastic weight vector is the
    identity — the consensus fixed point."""
    K, N = 4, 5000
    w = jnp.array([0.25, 0.25, 0.25, 0.25])
    blocks = jnp.broadcast_to(jnp.arange(N, dtype=jnp.float32), (K, N))
    out = gossip_mix_pallas(blocks, w, block=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.arange(N), rtol=1e-6)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 128, 2, 32, 32),
    (2, 256, 2, 64, 64),
    (1, 256, 4, 32, 128),
])
def test_mlstm_scan_vs_sequential_ref(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    li = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    out = mlstm_scan_pallas(q, k, v, li, lf, chunk=chunk, interpret=True)
    expect = ref.mlstm_scan_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_kernel_matches_model_chunked_ref():
    from repro.models.ssm import mlstm_chunked_ref

    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, S, H, hd = 2, 256, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    li = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    a = mlstm_scan_pallas(q, k, v, li, lf, chunk=64, interpret=True)
    b = mlstm_chunked_ref(q, k, v, li, lf, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)


def test_attention_chunked_equals_naive_small():
    """Model chunked attention == naive O(S^2) attention (both maskings)."""
    from repro.models.attention import chunked_attention, naive_attention

    q, k, v = _attn_inputs(jax.random.PRNGKey(5), 2, 96, 2, 2, 32, jnp.float32)
    pos = jnp.arange(96, dtype=jnp.int32)
    for window in (None, 17):
        a = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                              kv_block=32)
        b = naive_attention(q, k, v, pos, pos, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
