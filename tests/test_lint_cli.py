"""The lint CLI's incremental machinery: the hash-keyed result cache,
git-diff file selection (--changed), and the annotations output format.
Each test builds a throwaway repo root so the project baseline and
cache are never touched."""

import json
import os
import subprocess
import textwrap

import pytest

from repro.analysis.lint import changed_paths, main

CLEAN = textwrap.dedent("""
    def add(a, b):
        return a + b
    """)

BAD = textwrap.dedent("""
    import numpy as np

    def bad(xs):
        np.random.seed(0)
        return xs
    """)


def make_root(tmp_path, files):
    root = tmp_path / "proj"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    (root / "scripts").mkdir(exist_ok=True)
    (root / "scripts" / "lint_baseline.txt").write_text("")
    return root


def run_cli(root, *extra):
    return main(["--root", str(root), *extra])


class TestCache:
    def test_second_run_hits_cache(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN})
        assert run_cli(root) == 0
        first = capsys.readouterr().out
        assert "1 checked, 0 cached" in first
        assert run_cli(root) == 0
        second = capsys.readouterr().out
        assert "0 checked, 1 cached" in second
        assert (root / ".repro_lint_cache.json").exists()

    def test_edit_invalidates_only_that_file(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN,
                                    "src/repro/core/b.py": CLEAN})
        run_cli(root)
        capsys.readouterr()
        (root / "src/repro/core/b.py").write_text(CLEAN + "\nX = 1\n")
        run_cli(root)
        assert "1 checked, 1 cached" in capsys.readouterr().out

    def test_cached_violations_replayed(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": BAD})
        assert run_cli(root, "--no-baseline") == 1
        fresh = capsys.readouterr().out
        assert "rng-discipline" in fresh
        # the cache hit must reproduce the violation, not swallow it
        assert run_cli(root, "--no-baseline") == 1
        replayed = capsys.readouterr().out
        assert "rng-discipline" in replayed
        assert "1 cached" in replayed

    def test_corrupt_cache_is_ignored(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN})
        (root / ".repro_lint_cache.json").write_text("{not json")
        assert run_cli(root) == 0
        assert "1 checked" in capsys.readouterr().out

    def test_no_cache_flag(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN})
        run_cli(root, "--no-cache")
        capsys.readouterr()
        assert not (root / ".repro_lint_cache.json").exists()
        run_cli(root, "--no-cache")
        assert "1 checked, 0 cached" in capsys.readouterr().out


def git(root, *argv):
    return subprocess.run(["git", "-C", str(root), *argv],
                          capture_output=True, text=True, check=True,
                          env={**os.environ,
                               "GIT_AUTHOR_NAME": "t",
                               "GIT_AUTHOR_EMAIL": "t@t",
                               "GIT_COMMITTER_NAME": "t",
                               "GIT_COMMITTER_EMAIL": "t@t"})


@pytest.fixture
def git_root(tmp_path):
    root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN,
                                "src/repro/core/b.py": CLEAN})
    git(root, "init", "-q", "-b", "main")
    git(root, "add", "-A")
    git(root, "commit", "-qm", "seed")
    return root


class TestChanged:
    def test_clean_tree_reports_nothing_changed(self, git_root):
        assert changed_paths(str(git_root), base="main") == []

    def test_edited_and_untracked_files_selected(self, git_root):
        (git_root / "src/repro/core/b.py").write_text(CLEAN + "\nY = 2\n")
        (git_root / "src/repro/core/new.py").write_text(CLEAN)
        (git_root / "notes.txt").write_text("not python")
        assert changed_paths(str(git_root), base="main") == [
            "src/repro/core/b.py", "src/repro/core/new.py"]

    def test_changed_mode_flags_only_changed_files(self, git_root,
                                                   capsys):
        # a pre-existing violation in an UNCHANGED file must not fail a
        # --changed run; one in the changed file must
        (git_root / "src/repro/core/a.py").write_text(BAD)
        git(git_root, "add", "-A")
        git(git_root, "commit", "-qm", "bad a")
        git(git_root, "checkout", "-qb", "feature")
        (git_root / "src/repro/core/b.py").write_text(CLEAN + "\nZ = 3\n")
        assert run_cli(git_root, "--changed", "--base", "main",
                       "--no-baseline") == 0
        assert "1 changed" in capsys.readouterr().out
        (git_root / "src/repro/core/b.py").write_text(BAD)
        assert run_cli(git_root, "--changed", "--base", "main",
                       "--no-baseline") == 1
        out = capsys.readouterr().out
        assert "b.py" in out and "a.py" not in out

    def test_no_merge_base_falls_back_to_full(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": CLEAN})
        # not a git repo: --changed warns and lints everything
        assert run_cli(root, "--changed") == 0
        captured = capsys.readouterr()
        assert "falling back to a full lint" in captured.err
        assert "1 checked" in captured.out

    def test_update_baseline_refused_with_changed(self, git_root,
                                                  capsys):
        assert run_cli(git_root, "--changed", "--base", "main",
                       "--update-baseline") == 2
        assert "refusing" in capsys.readouterr().err


class TestAnnotations:
    def test_annotation_format(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": BAD})
        assert run_cli(root, "--no-baseline",
                       "--format=annotations") == 1
        out = capsys.readouterr().out
        assert "::error file=src/repro/core/a.py,line=" in out
        assert "[rng-discipline]" in out

    def test_text_format_is_default(self, tmp_path, capsys):
        root = make_root(tmp_path, {"src/repro/core/a.py": BAD})
        run_cli(root, "--no-baseline")
        out = capsys.readouterr().out
        assert "::error" not in out
        assert "src/repro/core/a.py:" in out
