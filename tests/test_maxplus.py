"""Max-plus algebra layer: Karp's algorithm, the timing recursion, and
the paper's worked examples (Appendix C)."""

import math

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.maxplus import (
    DelayDigraph,
    cycle_time,
    critical_circuit,
    empirical_cycle_time,
    is_strongly_connected,
    max_cycle_mean,
    timing_recursion,
)


def ring_graph(delays):
    n = len(delays)
    d = {(i, (i + 1) % n): delays[i] for i in range(n)}
    for i in range(n):
        d[(i, i)] = 0.0
    return DelayDigraph(tuple(range(n)), d)


def test_appendix_c_three_node_example():
    """Fig. 5a: undirected tree tau=3, directed ring tau=8/3."""
    und = DelayDigraph((1, 2, 3), {
        (1, 2): 1.0, (2, 1): 1.0, (2, 3): 3.0, (3, 2): 3.0,
        (1, 1): 0.0, (2, 2): 0.0, (3, 3): 0.0,
    })
    ring = DelayDigraph((1, 2, 3), {
        (1, 2): 1.0, (2, 3): 3.0, (3, 1): 4.0,
        (1, 1): 0.0, (2, 2): 0.0, (3, 3): 0.0,
    })
    assert cycle_time(und) == pytest.approx(3.0)
    assert cycle_time(ring) == pytest.approx(8.0 / 3.0)


def test_appendix_c_chain_vs_ring_family():
    """Fig. 5b: chain tau=n, ring tau=(4n-2)/(n+1) < 4."""
    for n in (3, 5, 9):
        # chain 1-2-...-n-(n+1) with delays 1 except last link n
        d = {}
        for i in range(1, n):
            d[(i, i + 1)] = 1.0
            d[(i + 1, i)] = 1.0
        d[(n, n + 1)] = float(n)
        d[(n + 1, n)] = float(n)
        for i in range(1, n + 2):
            d[(i, i)] = 0.0
        chain = DelayDigraph(tuple(range(1, n + 2)), d)
        assert cycle_time(chain) == pytest.approx(n)
        ring_d = {(i, i + 1): 1.0 for i in range(1, n)}
        ring_d[(n, n + 1)] = float(n)
        ring_d[(n + 1, 1)] = float(n + (n - 1))
        for i in range(1, n + 2):
            ring_d[(i, i)] = 0.0
        ring = DelayDigraph(tuple(range(1, n + 2)), ring_d)
        assert cycle_time(ring) == pytest.approx((4 * n - 2) / (n + 1))


def test_self_loop_only():
    g = DelayDigraph((0,), {(0, 0): 5.0})
    assert cycle_time(g) == pytest.approx(5.0)


def test_ring_cycle_time_is_mean():
    g = ring_graph([1.0, 2.0, 3.0, 6.0])
    assert cycle_time(g) == pytest.approx(3.0)


def test_critical_circuit_recovers_tau():
    g = ring_graph([1.0, 2.0, 3.0, 6.0])
    tau, circ = critical_circuit(g)
    assert tau == pytest.approx(3.0)
    assert len(circ) >= 2


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=8))
def test_property_ring_mean(delays):
    """Property: ring cycle time == mean of edge delays (single circuit)."""
    g = ring_graph(delays)
    assert cycle_time(g) == pytest.approx(sum(delays) / len(delays), rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(3, 6),
    st.data(),
)
def test_property_recursion_slope_matches_karp(n, data):
    """The paper's central identity: lim t_i(k)/k = max cycle mean."""
    delays = {}
    for i in range(n):
        delays[(i, (i + 1) % n)] = data.draw(st.floats(0.5, 20.0))
        delays[(i, i)] = data.draw(st.floats(0.0, 5.0))
        # random extra chord
        j = data.draw(st.integers(0, n - 1))
        if j != i:
            delays[(i, j)] = data.draw(st.floats(0.5, 20.0))
    g = DelayDigraph(tuple(range(n)), delays)
    tau = cycle_time(g)
    est = empirical_cycle_time(g, num_rounds=400)
    assert est == pytest.approx(tau, rel=0.05, abs=0.05)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 7), st.data())
def test_property_adding_edge_cannot_decrease_reachability(n, data):
    """Adding an edge to a strong digraph can only increase (or keep) the
    max cycle mean (more circuits to maximize over)."""
    delays = {(i, (i + 1) % n): data.draw(st.floats(1.0, 10.0)) for i in range(n)}
    for i in range(n):
        delays[(i, i)] = 0.0
    g = DelayDigraph(tuple(range(n)), delays)
    tau0 = cycle_time(g)
    i = data.draw(st.integers(0, n - 1))
    j = data.draw(st.integers(0, n - 1))
    if i == j or (i, j) in delays:
        return
    delays2 = dict(delays)
    delays2[(i, j)] = data.draw(st.floats(1.0, 10.0))
    tau1 = cycle_time(DelayDigraph(tuple(range(n)), delays2))
    assert tau1 >= tau0 - 1e-9


def test_timing_recursion_monotone_nondecreasing_increments():
    g = ring_graph([2.0, 4.0])
    t = timing_recursion(g, 50)
    for series in t.values():
        diffs = [b - a for a, b in zip(series, series[1:])]
        assert all(d >= -1e-9 for d in diffs)


def test_strongly_connected_detection():
    g = DelayDigraph((0, 1, 2), {(0, 1): 1.0, (1, 0): 1.0, (1, 2): 1.0})
    assert not is_strongly_connected(g)
    g2 = DelayDigraph((0, 1, 2), {(0, 1): 1.0, (1, 2): 1.0, (2, 0): 1.0})
    assert is_strongly_connected(g2)
