"""Mixing-rate pricing: the convergence half of (τ, ρ) co-design.

Key identities under test:

* closed-form contraction factors — K_n under Metropolis is exact full
  averaging (ρ = 0), the undirected cycle C_n under local-degree has
  eigenvalues ``1/3 + (2/3)·cos(2πk/n)``, the star S_n under Metropolis
  is ``I − L/n`` with ρ = 1 − 1/n, and the deployed directed-ring
  matrix ``(I + P)/2`` is circulant (normal), so its singular values
  are eigenvalue moduli and ρ = cos(π/n) — each checked in f64 and f32
  (hypothesis over n in [3, 64]);
* the batched eigvalsh/SVD paths are *bit-identical* to a per-matrix
  ``numpy.linalg`` oracle loop on random doubly-stochastic stacks
  (same LAPACK driver per slice), and the jittable JAX twin agrees to
  f32 tolerance;
* ``batched_mixing_matrices`` over an activation-mask stack equals the
  per-row :func:`repro.core.consensus.local_degree_matrix` /
  ``metropolis_matrix`` loop exactly, with all-zero rows yielding the
  identity;
* a budget-1.0 MATCHA schedule is deterministic, so its empirical
  ``E[WᵀW]`` collapses to ``WᵀW`` and the expected contraction equals
  the fixed-matrix ρ;
* the auto-family arbitration flips with the objective: on Gaia the
  ring wins under ``objective="tau"`` (the paper's Table 1 regime) and
  MATCHA wins under ``objective="time_to_eps"`` (mixing-per-traffic
  finally visible to the designer).
"""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as C
from repro.core.consensus import (
    is_doubly_stochastic,
    local_degree_matrix,
    metropolis_matrix,
    ring_matrix,
    spectral_gap,
)
from repro.core.delays import TrainingParams
from repro.core.mixing import (
    OBJECTIVES,
    RHO_FLOOR,
    batched_mixing_matrices,
    batched_rho,
    batched_spectral_gap,
    contraction_from_gram,
    matcha_expected_gram,
    mixing_matrix,
    overlay_mixing_matrix,
    overlay_rho,
    overlay_rho_batch,
    pareto_frontier,
    schedule_rho,
    score_estimate,
    wall_clock_to_eps,
)
from repro.core.schedule import FixedSchedule, ScheduleEstimate
from repro.dynamics import design_best_schedule, design_schedule_portfolio


def gaia_setup(s=1):
    M, Tc = C.WORKLOADS["inaturalist"]
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    tp = TrainingParams(model_size_mbits=M, local_steps=s)
    return u, gc, tp


def both_arcs(pairs):
    """Undirected pair list -> the both-directions arc list the repo uses."""
    return [a for (i, j) in pairs for a in ((i, j), (j, i))]


def complete_edges(n):
    return both_arcs([(i, j) for i in range(n) for j in range(i + 1, n)])


def cycle_edges(n):
    return both_arcs([(i, (i + 1) % n) for i in range(n)])


def star_edges(n):
    return both_arcs([(0, j) for j in range(1, n)])


# ---------------------------------------------------------------------------
# Closed-form contraction factors (hypothesis over n, f64 and f32)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 64))
def test_complete_graph_metropolis_is_exact_averaging(n):
    # K_n Metropolis: every weight is 1/n, W = (1/n)·11ᵀ exactly, so the
    # deflated matrix is 0 and ρ = 0 / gap = 1 up to one LAPACK solve.
    W = mixing_matrix(n, complete_edges(n), rule="metropolis")
    assert np.allclose(W, np.full((n, n), 1.0 / n), atol=1e-15)
    rho = batched_rho(W[None], symmetric=True)[0]
    assert rho == pytest.approx(0.0, abs=1e-12)
    assert batched_spectral_gap(W[None], symmetric=True)[0] == pytest.approx(
        1.0, abs=1e-12
    )
    rho32 = batched_rho(W[None].astype(np.float32), symmetric=True)[0]
    assert rho32.dtype == np.float32
    assert float(rho32) == pytest.approx(0.0, abs=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 64))
def test_cycle_local_degree_matches_circulant_eigenvalues(n):
    # Undirected C_n, local-degree: every weight 1/3, diagonal 1/3 —
    # a circulant with eigenvalues 1/3 + (2/3)·cos(2πk/n).
    W = mixing_matrix(n, cycle_edges(n), rule="local_degree")
    assert is_doubly_stochastic(W)
    k = np.arange(1, n)
    expected = float(np.max(np.abs(1.0 / 3.0 + (2.0 / 3.0) * np.cos(2 * np.pi * k / n))))
    assert batched_rho(W[None], symmetric=True)[0] == pytest.approx(
        expected, abs=1e-12
    )
    # SVD path agrees on the symmetric matrix, and so does the scalar
    # consensus-module oracle.
    assert batched_rho(W[None])[0] == pytest.approx(expected, abs=1e-10)
    assert spectral_gap(W) == pytest.approx(1.0 - expected, abs=1e-10)
    rho32 = batched_rho(W[None].astype(np.float32), symmetric=True)[0]
    assert rho32.dtype == np.float32
    assert float(rho32) == pytest.approx(expected, abs=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 64))
def test_star_metropolis_rho_is_one_minus_one_over_n(n):
    # S_n Metropolis: center degree n−1, leaves degree 1, every edge
    # weight 1/n → W = I − L/n; star-Laplacian eigenvalues {0, 1^(n−2), n}
    # give W eigenvalues {1, (1 − 1/n)^(n−2), 0} and ρ = 1 − 1/n.
    W = mixing_matrix(n, star_edges(n), rule="metropolis")
    assert is_doubly_stochastic(W)
    expected = 1.0 - 1.0 / n
    assert batched_rho(W[None], symmetric=True)[0] == pytest.approx(
        expected, abs=1e-12
    )
    rho32 = batched_rho(W[None].astype(np.float32), symmetric=True)[0]
    assert rho32.dtype == np.float32
    assert float(rho32) == pytest.approx(expected, abs=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 64))
def test_directed_ring_half_lazy_rho_is_cos_pi_over_n(n):
    # The deployed ring matrix (I + P)/2 is circulant hence normal: its
    # singular values are the eigenvalue *moduli* |(1 + ω^k)/2| =
    # |cos(πk/n)|, so ρ = cos(π/n) — not the real part 1/2 + cos(2π/n)/2.
    W = ring_matrix(n, list(range(n)))
    expected = math.cos(math.pi / n)
    assert batched_rho(W[None])[0] == pytest.approx(expected, abs=1e-12)
    rho32 = batched_rho(W[None].astype(np.float32))[0]
    assert rho32.dtype == np.float32
    assert float(rho32) == pytest.approx(expected, abs=1e-5)


# ---------------------------------------------------------------------------
# Batched paths vs per-matrix numpy.linalg oracle (bit-consistency)


def _sinkhorn_stack(B, n, seed):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(B):
        A = rng.random((n, n)) + 0.1
        for _ in range(80):
            A = A / A.sum(axis=1, keepdims=True)
            A = A / A.sum(axis=0, keepdims=True)
        out.append(A)
    return np.stack(out)


def test_batched_svd_path_bit_matches_per_matrix_oracle():
    W = _sinkhorn_stack(7, 9, seed=3)
    n = W.shape[-1]
    batched = batched_rho(W)
    oracle = np.array(
        [
            np.linalg.svd(W[k] - np.asarray(1.0 / n, dtype=W.dtype),
                          compute_uv=False)[0]
            for k in range(len(W))
        ]
    )
    # Same LAPACK driver per slice: bit-identical, not just close.
    assert np.array_equal(batched, oracle)


def test_batched_eigvalsh_path_bit_matches_per_matrix_oracle():
    A = _sinkhorn_stack(6, 8, seed=4)
    W = 0.5 * (A + np.transpose(A, (0, 2, 1)))  # symmetric, still d.s.
    n = W.shape[-1]
    batched = batched_rho(W, symmetric=True)
    oracle = []
    for k in range(len(W)):
        M = W[k] - np.asarray(1.0 / n, dtype=W.dtype)
        lam = np.linalg.eigvalsh(0.5 * (M + M.T))
        oracle.append(np.maximum(np.abs(lam[0]), np.abs(lam[-1])))
    assert np.array_equal(batched, np.asarray(oracle))
    # ...and the symmetric fast path agrees with the general SVD path.
    assert np.allclose(batched, batched_rho(W), atol=1e-12)


def test_jax_twin_matches_numpy_to_f32_tolerance():
    jax = pytest.importorskip("jax")
    from repro.core.mixing import batched_rho_jax, batched_spectral_gap_jax

    W = _sinkhorn_stack(4, 6, seed=5)
    ref = batched_rho(W)
    got = np.asarray(jax.jit(lambda x: batched_rho_jax(x))(W))
    assert np.allclose(got, ref, atol=1e-5)
    gap = np.asarray(jax.jit(lambda x: batched_spectral_gap_jax(x))(W))
    assert np.allclose(gap, 1.0 - ref, atol=1e-5)


# ---------------------------------------------------------------------------
# Batched matrix construction vs the per-row consensus loop


def _random_mask_pool(n, seed, B=5, density=0.7):
    rng = np.random.default_rng(seed)
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    keep = rng.choice(len(pairs), size=max(n, len(pairs) // 2), replace=False)
    arcs = both_arcs([pairs[k] for k in sorted(keep)])
    src = np.asarray([a for a, _ in arcs], dtype=np.int64)
    dst = np.asarray([b for _, b in arcs], dtype=np.int64)
    on = rng.random((B, len(arcs) // 2)) < density
    masks = np.repeat(on, 2, axis=1).astype(np.float64)
    return arcs, src, dst, masks


@pytest.mark.parametrize("rule", ["local_degree", "metropolis"])
def test_batched_matrices_equal_per_row_consensus_loop(rule):
    arcs, src, dst, masks = _random_mask_pool(8, seed=0)
    W = batched_mixing_matrices(8, src, dst, masks, rule=rule)
    build = local_degree_matrix if rule == "local_degree" else metropolis_matrix
    for b in range(len(masks)):
        edges = [arcs[e] for e in range(len(arcs)) if masks[b, e]]
        assert np.array_equal(W[b], build(8, edges))


def test_all_zero_activation_row_is_identity():
    arcs, src, dst, masks = _random_mask_pool(6, seed=1, B=3)
    masks[1] = 0.0
    W = batched_mixing_matrices(6, src, dst, masks)
    assert np.array_equal(W[1], np.eye(6))
    assert batched_rho(W[[1]], symmetric=True)[0] == pytest.approx(1.0)


def test_unknown_rule_rejected():
    with pytest.raises(ValueError, match="weight rule"):
        mixing_matrix(3, cycle_edges(3), rule="nope")
    with pytest.raises(ValueError, match="weight rule"):
        batched_mixing_matrices(
            3,
            np.asarray([0], dtype=np.int64),
            np.asarray([1], dtype=np.int64),
            np.ones((1, 1)),
            rule="nope",
        )


# ---------------------------------------------------------------------------
# Overlay / schedule pricing on the measured Gaia graph


def test_overlay_matrices_mirror_deployed_plans():
    _, gc, tp = gaia_setup()
    n = gc.num_silos
    ring = C.design_overlay("ring", gc, tp)
    star = C.design_overlay("star", gc, tp)
    mst = C.design_overlay("mst", gc, tp)
    Wr = overlay_mixing_matrix(ring, n, silos=tuple(gc.silos))
    assert batched_rho(Wr[None])[0] == pytest.approx(math.cos(math.pi / n))
    Ws = overlay_mixing_matrix(star, n, silos=tuple(gc.silos))
    assert np.array_equal(Ws, np.full((n, n), 1.0 / n))
    Wm = overlay_mixing_matrix(mst, n, silos=tuple(gc.silos))
    assert is_doubly_stochastic(Wm)
    # One batched SVD over the pool equals the per-overlay scalars.
    pool = [ring, star, mst]
    rhos = overlay_rho_batch(pool, n, silos=tuple(gc.silos))
    for k, ov in enumerate(pool):
        assert rhos[k] == pytest.approx(
            overlay_rho(ov, n, silos=tuple(gc.silos)), abs=1e-12
        )
    # Trees mix slower than the optimal ring walk on the same n.
    assert rhos[1] < rhos[0] < rhos[2]


def test_budget_one_matcha_gram_collapses_to_fixed_matrix():
    _, gc, tp = gaia_setup()
    sched = C.matcha_schedule_from_connectivity(gc, budget=1.0)
    arcs, _ = sched._arc_pool(gc)
    index = {v: k for k, v in enumerate(gc.silos)}
    W = local_degree_matrix(
        gc.num_silos, [(index[i], index[j]) for (i, j) in arcs]
    )
    G = matcha_expected_gram(sched, gc, rounds=16, seed=0)
    assert np.allclose(G, W.T @ W, atol=1e-12)
    assert contraction_from_gram(G) == pytest.approx(
        float(batched_rho(W[None], symmetric=True)[0]), abs=1e-9
    )
    assert schedule_rho(sched, gc, rounds=16) == pytest.approx(
        contraction_from_gram(G)
    )


def test_fixed_schedule_rho_is_overlay_rho():
    _, gc, tp = gaia_setup()
    ring = C.design_overlay("ring", gc, tp)
    assert schedule_rho(FixedSchedule(ring), gc) == pytest.approx(
        overlay_rho(ring, gc.num_silos, silos=tuple(gc.silos))
    )


def test_matcha_mixes_better_per_round_average_than_it_looks():
    # At budget 0.5 the *expected* contraction beats the ring's ρ on
    # Gaia — the whole reason time_to_eps can flip the arbitration.
    _, gc, tp = gaia_setup()
    sched = C.matcha_schedule_from_connectivity(gc, budget=0.5)
    ring = C.design_overlay("ring", gc, tp)
    assert schedule_rho(sched, gc, rounds=128) < overlay_rho(
        ring, gc.num_silos, silos=tuple(gc.silos)
    )


# ---------------------------------------------------------------------------
# The composite objective, score_estimate, and the Pareto frontier


def test_wall_clock_to_eps_edge_cases():
    assert wall_clock_to_eps(100.0, 0.5) == pytest.approx(100.0 / math.log(2.0))
    assert wall_clock_to_eps(100.0, 1.0) == math.inf
    assert wall_clock_to_eps(100.0, 1.5) == math.inf
    assert math.isnan(wall_clock_to_eps(100.0, float("nan")))
    # ρ = 0 is floored, not free: STAR still pays its τ per round.
    floored = wall_clock_to_eps(100.0, 0.0)
    assert floored == pytest.approx(100.0 / -math.log(RHO_FLOOR))
    assert floored > 0.0
    # Monotone: slower mixing at equal τ can only cost more.
    rhos = [0.0, 0.3, 0.9, 0.99]
    scores = [wall_clock_to_eps(100.0, r) for r in rhos]
    assert scores == sorted(scores)


def test_score_estimate_objectives():
    est = ScheduleEstimate(tau_ms=120.0, ci95_ms=0.0, per_seed_ms=(120.0,), rho=0.5)
    assert score_estimate(est, "tau") == pytest.approx(120.0)
    assert score_estimate(est, "time_to_eps") == pytest.approx(
        wall_clock_to_eps(120.0, 0.5)
    )
    assert est.time_to_eps_score == pytest.approx(wall_clock_to_eps(120.0, 0.5))
    unpriced = ScheduleEstimate(tau_ms=120.0, ci95_ms=0.0, per_seed_ms=(120.0,))
    assert score_estimate(unpriced, "tau") == pytest.approx(120.0)
    with pytest.raises(ValueError, match="rho"):
        score_estimate(unpriced, "time_to_eps")
    with pytest.raises(ValueError, match="objective"):
        score_estimate(est, "rounds")
    assert set(OBJECTIVES) == {"tau", "time_to_eps"}


def test_pareto_frontier_drops_dominated_points():
    taus = np.asarray([100.0, 150.0, 120.0, 200.0, 100.0])
    rhos = np.asarray([0.9, 0.5, 0.95, 0.4, 0.92])
    idx = pareto_frontier(taus, rhos)
    # index 2 dominated by 0 (slower and worse-mixing), 4 by 0 (tie on τ,
    # worse ρ); survivors sorted by τ.
    assert idx.tolist() == [0, 1, 3]
    assert np.all(np.diff(taus[idx]) >= 0)
    assert np.all(np.diff(rhos[idx]) < 0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(0, 10_000))
def test_pareto_frontier_is_exactly_the_nondominated_set(m, seed):
    rng = np.random.default_rng(seed)
    taus = rng.uniform(50.0, 500.0, size=m)
    rhos = rng.uniform(0.0, 1.0, size=m)
    idx = set(pareto_frontier(taus, rhos).tolist())

    def dominated(k):
        return any(
            taus[j] <= taus[k]
            and rhos[j] <= rhos[k]
            and (taus[j] < taus[k] or rhos[j] < rhos[k])
            for j in range(m)
        )

    for k in range(m):
        assert (k not in idx) == dominated(k)


# ---------------------------------------------------------------------------
# The acceptance criterion: auto-family arbitration flips with objective


def test_auto_picker_flips_from_ring_to_matcha_under_time_to_eps():
    _, gc, tp = gaia_setup()
    kw = dict(
        designers=("ring",),
        n_candidates=0,
        rewire_restarts=0,
        matcha_budgets=(0.5,),
        matcha_rounds=60,
        matcha_seeds=(0,),
    )
    by_tau, scored_tau = design_best_schedule(gc, tp, objective="tau", **kw)
    assert isinstance(by_tau, FixedSchedule) and by_tau.name == "ring"
    by_eps, scored_eps = design_best_schedule(
        gc, tp, objective="time_to_eps", **kw
    )
    assert by_eps.is_randomized and by_eps.name.startswith("matcha")
    assert scored_tau == scored_eps == 2
    # The flip is explained by the portfolio's own numbers: MATCHA's τ̄
    # is *worse* (the paper's Table 1 story) but its ρ is far better.
    portfolio, _ = design_schedule_portfolio(
        gc, tp, objective="time_to_eps", **kw
    )
    ests = {s.name.split("@")[0]: e for (s, e) in portfolio}
    assert ests["matcha"].tau_ms > ests["ring"].tau_ms
    assert ests["matcha"].rho < ests["ring"].rho
    assert ests["matcha"].time_to_eps_score < ests["ring"].time_to_eps_score


def test_portfolio_under_tau_skips_spectral_pricing():
    _, gc, tp = gaia_setup()
    portfolio, _ = design_schedule_portfolio(
        gc,
        tp,
        designers=("ring", "mst"),
        n_candidates=0,
        rewire_restarts=0,
        objective="tau",
    )
    assert portfolio and all(math.isnan(e.rho) for (_, e) in portfolio)
    with pytest.raises(ValueError, match="objective"):
        design_schedule_portfolio(
            gc, tp, n_candidates=0, rewire_restarts=0, objective="rounds"
        )
