"""Recompile guard: "sampled topologies never recompile" as an assert.

The traced-consensus lowering (``make_train_step(..., consensus_arg=
True)``) takes the per-round consensus matrix as *data*, so feeding a
fresh MATCHA-sampled matrix every round must cost exactly one
compilation.  :class:`repro.analysis.recompile.TraceCounter` wraps the
step function *before* ``jax.jit``; a second trace means some static
signature varied (dtype drift, weak-type flip, shape change) and the
per-round cost silently became a per-round compile.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.recompile import TraceCounter, assert_max_traces
from repro.core.schedule import MatchaSchedule
from repro.data import FederatedBatcher, SyntheticLMStream
from repro.fed import DPASGDConfig, init_state, make_train_step
from repro.fed.gossip import ScheduleSlot
from repro.models import ModelConfig
from repro.optim import sgd

N_SILOS = 4
N_ROUNDS = 12


def _setup():
    cfg = ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 256,
                      n_silos=N_SILOS)
    fed = DPASGDConfig(local_steps=1, gossip_impl="einsum")
    opt = sgd(0.1)
    step_fn = make_train_step(cfg, fed, opt, plan=None, consensus_arg=True)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    stream = SyntheticLMStream(cfg.vocab_size, 16, n_silos=N_SILOS)
    batcher = FederatedBatcher(stream, local_steps=fed.local_steps,
                               batch_per_silo=2)
    return step_fn, state, batcher


def _matcha_slot():
    sched = MatchaSchedule(
        matchings=(((0, 1), (2, 3)), ((1, 2), (0, 3)), ((0, 2),)),
        budget=0.5,
    )
    return ScheduleSlot(sched, N_SILOS)


def test_traced_consensus_compiles_once_across_sampled_rounds():
    step_fn, state, batcher = _setup()
    counter = TraceCounter(step_fn, name="dpasgd_step")
    jstep = jax.jit(counter)
    slot = _matcha_slot()

    seen = set()
    for k in range(N_ROUNDS):
        batch = {key: jnp.asarray(v)
                 for key, v in batcher.batch(k).items()}
        A = jnp.asarray(slot.matrix_for_round(k))
        seen.add(tuple(np.asarray(A).ravel().tolist()))
        state, aux = jstep(state, batch, A)

    # The schedule really sampled distinct topologies...
    assert len(seen) >= 2, "MATCHA sampling degenerated to one matrix"
    # ...and they all flowed through one compilation.
    assert counter.count == 1, (
        f"train step traced {counter.count} times over {N_ROUNDS} "
        f"sampled rounds"
    )
    assert_max_traces(counter)
    assert np.isfinite(float(aux["loss"]))


def test_assert_max_traces_reports_retrace():
    counter = TraceCounter(lambda x: x + 1, name="toy")
    jtoy = jax.jit(counter)
    jtoy(jnp.zeros((2,)))
    jtoy(jnp.zeros((3,)))  # shape change forces a retrace
    assert counter.count == 2
    try:
        assert_max_traces(counter, limit=1)
    except AssertionError as exc:
        assert "toy" in str(exc)
    else:  # pragma: no cover
        raise AssertionError("expected assert_max_traces to fail")
