"""Substrate layers: optimizers, data pipeline, checkpointing, params."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.optim import sgd, momentum, adam, adamw, clip_by_global_norm
from repro.optim.optimizers import inverse_sqrt_decay
from repro.data import SyntheticLMStream, FederatedBatcher
from repro.data.partition import dirichlet_vocab_partition, lognormal_sizes, jensen_shannon
from repro.checkpoint import save_checkpoint, load_checkpoint, tree_to_bytes, tree_from_bytes
from repro.models import ModelConfig, init_params, count_params, param_pspecs, FSDP_TP
from repro.models.transformer import model_specs


# ---------------------------------------------------------------------------
# optimizers


def quad_loss(p, _=None):
    return jnp.sum((p["w"] - 3.0) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1),
    lambda: momentum(0.05, 0.9),
    lambda: adam(0.5),
    lambda: adamw(0.5, weight_decay=0.0),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    p = {"w": jnp.zeros(4)}
    o = opt.init(p)
    for step in range(200):
        g = jax.grad(quad_loss)(p)
        p, o = opt.update(g, o, p, jnp.int32(step))
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=0.05)


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.1, weight_decay=0.5)
    p = {"w": jnp.ones(4) * 10.0}
    o = opt.init(p)
    zero_g = {"w": jnp.zeros(4)}
    for step in range(50):
        p, o = opt.update(zero_g, o, p, jnp.int32(step))
    assert float(jnp.abs(p["w"]).max()) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(100.0, rel=1e-5)


def test_inverse_sqrt_decay():
    lr = inverse_sqrt_decay(0.1)
    assert float(lr(jnp.int32(1))) == pytest.approx(0.1)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# data pipeline


def test_stream_is_deterministic_and_non_iid():
    s = SyntheticLMStream(vocab_size=128, seq_len=16, n_silos=4, alpha=0.1, seed=1)
    a = s.sample(0, 8, 0)
    b = s.sample(0, 8, 0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # different silos see different distributions
    js = jensen_shannon(
        np.bincount(s.sample(0, 64, 1)["tokens"].ravel(), minlength=128) + 1e-9,
        np.bincount(s.sample(1, 64, 1)["tokens"].ravel(), minlength=128) + 1e-9,
    )
    assert js > 0.05


def test_labels_are_next_tokens():
    s = SyntheticLMStream(vocab_size=64, seq_len=10, n_silos=1)
    b = s.sample(0, 4, 0)
    assert b["tokens"].shape == (4, 10)
    assert b["labels"].shape == (4, 10)


def test_federated_batcher_shapes():
    s = SyntheticLMStream(vocab_size=64, seq_len=8, n_silos=3)
    fb = FederatedBatcher(s, local_steps=2, batch_per_silo=4)
    b = fb.batch(0)
    assert b["tokens"].shape == (3, 2, 4, 8)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(100, 10_000))
def test_lognormal_sizes_property(n, total):
    sizes = lognormal_sizes(n, total)
    assert len(sizes) == n
    assert (sizes >= 1).all()


def test_dirichlet_partition_rows_are_distributions():
    p = dirichlet_vocab_partition(5, 100, alpha=0.5)
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-9)
    assert (p >= 0).all()


# ---------------------------------------------------------------------------
# checkpointing


def test_checkpoint_roundtrip():
    cfg = ModelConfig("t", "dense", 2, 64, 2, 2, 128, 256)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save_checkpoint(path, params, step=7)
        like = init_params(jax.random.PRNGKey(1), model_specs(cfg))
        restored = load_checkpoint(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    cfg = ModelConfig("t", "dense", 2, 64, 2, 2, 128, 256)
    cfg2 = ModelConfig("t", "dense", 2, 64, 2, 2, 256, 256)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    blob = tree_to_bytes(params)
    like = init_params(jax.random.PRNGKey(0), model_specs(cfg2))
    with pytest.raises(ValueError):
        tree_from_bytes(blob, like)


# ---------------------------------------------------------------------------
# param spec system


def test_param_pspecs_structure_matches_params():
    cfg = ModelConfig("t", "dense", 2, 128, 4, 2, 256, 512)
    specs = model_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    pspecs = param_pspecs(specs, FSDP_TP)
    jax.tree_util.tree_map(lambda a, b: None, params, pspecs)  # same structure
    # no duplicate mesh axes within one spec
    from jax.sharding import PartitionSpec as P

    for spec in jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
        axes = [a for a in spec if a is not None]
        assert len(axes) == len(set(axes))


def test_padded_vocab_round():
    cfg = ModelConfig("t", "audio", 2, 128, 4, 4, 256, 51866)
    assert cfg.padded_vocab_size % 128 == 0
    assert cfg.padded_vocab_size >= cfg.vocab_size
    specs = model_specs(cfg)
    assert specs["embed"].shape[0] == cfg.padded_vocab_size
