"""repro-lint rule tests: each rule gets positive (injected violation
is caught) and negative (idiomatic code stays clean) snippets, plus
contract-decorator semantics, baseline grandfathering and inline
suppression."""

import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import contracts
from repro.analysis.contracts import ContractError, contract
from repro.analysis.lint import (
    LintConfig,
    lint_source,
    load_baseline,
    write_baseline,
)

SNIPPET_ENGINE = LintConfig(engine_modules=("snippet.py",))


def run(src, path="snippet.py", config=None, extra_files=None):
    return lint_source(textwrap.dedent(src), path=path, config=config,
                       extra_files=extra_files)


def rules_of(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# trace-safety
# ---------------------------------------------------------------------------

class TestTraceSafety:
    def test_host_sync_in_scanned_body(self):
        vs = run("""
            from jax import lax

            def body(carry, x):
                carry = carry + float(x)
                return carry, carry

            def roll(xs):
                return lax.scan(body, 0.0, xs)
            """)
        assert "trace-safety" in rules_of(vs)
        assert any("float()" in v.message for v in vs)

    def test_item_in_fori_loop_body(self):
        vs = run("""
            from jax import lax

            def step(i, acc):
                return acc + acc.item()

            def run10(acc):
                return lax.fori_loop(0, 10, step, acc)
            """)
        assert "trace-safety" in rules_of(vs)

    def test_np_call_in_jitted_function(self):
        vs = run("""
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                return np.square(x)
            """)
        assert any(v.rule == "trace-safety" and "np.square" in v.message
                   for v in vs)

    def test_branch_on_tracer(self):
        vs = run("""
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """)
        assert any(v.rule == "trace-safety" and "branch" in v.message
                   for v in vs)

    def test_traced_closure_through_same_file_call(self):
        # helper() is only traced because the jitted f() calls it.
        vs = run("""
            import jax

            def helper(x):
                return float(x) + 1.0

            @jax.jit
            def f(x):
                return helper(x)
            """)
        assert "trace-safety" in rules_of(vs)

    def test_shape_branch_is_clean(self):
        vs = run("""
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                if x.shape[0] > 1:
                    return jnp.sum(x)
                return x * 2
            """)
        assert "trace-safety" not in rules_of(vs)

    def test_is_none_branch_is_clean(self):
        vs = run("""
            import jax

            @jax.jit
            def f(x, t0=None):
                if t0 is None:
                    return x
                return x + t0
            """)
        assert "trace-safety" not in rules_of(vs)

    def test_jnp_in_jit_is_clean(self):
        vs = run("""
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.maximum(x, 0.0)
            """)
        assert "trace-safety" not in rules_of(vs)

    def test_per_iteration_sync_in_host_loop(self):
        # moved to the dataflow-based effect-purity rule in PR 10
        vs = run("""
            def drive(fn, xs):
                out = []
                for x in xs:
                    out.append(float(fn(x)))
                return out
            """)
        assert any(v.rule == "effect-purity" and "loop" in v.message
                   for v in vs)

    def test_host_origin_loop_scalar_is_clean(self):
        # the dataflow refinement: rng-derived floats are host values
        vs = run("""
            import numpy as np

            def scenario(seed):
                rng = np.random.default_rng(seed)
                out = []
                for _ in range(8):
                    out.append(float(rng.uniform()))
                return out
            """)
        assert "effect-purity" not in rules_of(vs)

    def test_unbatched_transfers_flagged(self):
        # moved to the dataflow-based effect-purity rule in PR 10
        vs = run("""
            import numpy as np

            def fetch(fn, x):
                a, b, tau = fn(x)
                a = np.asarray(a)
                b = np.asarray(b)
                return a, b, float(tau)
            """)
        assert any(v.rule == "effect-purity" and "device_get" in v.message
                   for v in vs)

    def test_cold_path_not_linted_for_trace_safety(self):
        vs = run("""
            import jax

            @jax.jit
            def f(x):
                return float(x)
            """, path="benchmarks/bench_thing.py")
        assert "trace-safety" not in rules_of(vs)

    def test_traced_root_collected_across_files(self):
        # The jit() call lives in another file; the def is still traced.
        lib = textwrap.dedent("""
            def kernel(x):
                return float(x)
            """)
        driver = textwrap.dedent("""
            import jax
            from lib import kernel

            jitted = jax.jit(kernel)
            """)
        vs = lint_source(lib, path="lib.py",
                         extra_files=[("driver.py", driver)])
        assert "trace-safety" in rules_of(vs)


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------

class TestRngDiscipline:
    def test_global_np_random(self):
        vs = run("""
            import numpy as np

            def sample(n):
                return np.random.rand(n)
            """)
        assert "rng-discipline" in rules_of(vs)

    def test_global_np_random_seed(self):
        vs = run("""
            import numpy as np
            np.random.seed(0)
            """)
        assert "rng-discipline" in rules_of(vs)

    def test_argless_default_rng(self):
        vs = run("""
            import numpy as np

            def sample(n):
                rng = np.random.default_rng()
                return rng.random(n)
            """)
        assert any(v.rule == "rng-discipline" and "OS" in v.message
                   for v in vs)

    def test_stdlib_global_random(self):
        vs = run("""
            import random

            def pick(xs):
                return random.choice(xs)
            """)
        assert "rng-discipline" in rules_of(vs)

    def test_seeded_idioms_are_clean(self):
        vs = run("""
            import random
            import numpy as np

            def sample(seed, n, round_idx):
                rng = np.random.default_rng(
                    np.random.SeedSequence((seed, round_idx)))
                legacy = random.Random(seed)
                return rng.random(n), legacy.random()
            """)
        assert "rng-discipline" not in rules_of(vs)


# ---------------------------------------------------------------------------
# sentinel-discipline
# ---------------------------------------------------------------------------

class TestSentinelDiscipline:
    def test_arithmetic_on_sentinel(self):
        vs = run("""
            from repro.core.maxplus_vec import NEG_INF

            def pad_cost(x):
                return NEG_INF + x
            """)
        assert "sentinel-discipline" in rules_of(vs)

    def test_raw_equality_against_sentinel(self):
        vs = run("""
            from repro.core.maxplus_vec import NEG_INF

            def absent(w):
                return w == NEG_INF
            """)
        assert any(v.rule == "sentinel-discipline"
                   and "missing_mask" in v.message for v in vs)

    def test_negation_of_sentinel(self):
        vs = run("""
            from repro.core.maxplus_vec import NEG_INF

            def worst():
                return -NEG_INF
            """)
        assert "sentinel-discipline" in rules_of(vs)

    def test_redefinition_outside_home(self):
        vs = run("""
            NEG_INF = float("-inf")
            """)
        assert any(v.rule == "sentinel-discipline"
                   and "redefinition" in v.message for v in vs)

    def test_definition_in_home_module_allowed(self):
        vs = run("""
            NEG_INF = float("-inf")
            """, path="src/repro/core/maxplus_vec.py")
        assert "sentinel-discipline" not in rules_of(vs)

    def test_missing_mask_usage_is_clean(self):
        vs = run("""
            import numpy as np
            from repro.core.maxplus_vec import missing_mask

            def absent(w):
                return missing_mask(w)
            """)
        assert "sentinel-discipline" not in rules_of(vs)


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------

class TestDtypeDiscipline:
    def test_dtypeless_ctor_in_engine_module(self):
        vs = run("""
            import numpy as np

            def table(n):
                return np.zeros((n, n))
            """, config=SNIPPET_ENGINE)
        assert "dtype-discipline" in rules_of(vs)

    def test_dtypeless_jnp_ctor_in_engine_module(self):
        vs = run("""
            import jax.numpy as jnp

            def table(n):
                return jnp.zeros((n, n))
            """, config=SNIPPET_ENGINE)
        assert any(v.rule == "dtype-discipline" and "float32" in v.message
                   for v in vs)

    def test_dtyped_ctor_is_clean(self):
        vs = run("""
            import numpy as np

            def table(n):
                a = np.zeros((n, n), dtype=np.float64)
                b = np.full((n, n), 0.0, np.float64)
                return a, b
            """, config=SNIPPET_ENGINE)
        assert "dtype-discipline" not in rules_of(vs)

    def test_ctor_outside_engine_modules_not_flagged(self):
        vs = run("""
            import numpy as np

            def table(n):
                return np.zeros((n, n))
            """)
        assert "dtype-discipline" not in rules_of(vs)

    def test_f32_in_migration_path(self):
        # bit-identity functions are matched by name in any module.
        vs = run("""
            import numpy as np

            def migrate_silo_state(state, idx):
                return state.astype(np.float32)
            """)
        assert any(v.rule == "dtype-discipline"
                   and "bit-identity" in v.message for v in vs)

    def test_f32_elsewhere_is_clean(self):
        vs = run("""
            import numpy as np

            def quantize_for_wire(x):
                return x.astype(np.float32)
            """)
        assert "dtype-discipline" not in rules_of(vs)


# ---------------------------------------------------------------------------
# engine-contract
# ---------------------------------------------------------------------------

class TestEngineContract:
    def test_missing_contract_flagged(self):
        vs = run("""
            def batched_frobnicate(W):
                return W
            """, config=SNIPPET_ENGINE)
        assert any(v.rule == "engine-contract"
                   and "batched_frobnicate" in v.message for v in vs)

    def test_decorated_function_is_clean(self):
        vs = run("""
            from repro.analysis.contracts import contract

            @contract("[B,N,N]", ret="[B]")
            def batched_frobnicate(W):
                return W
            """, config=SNIPPET_ENGINE)
        assert "engine-contract" not in rules_of(vs)

    def test_private_and_nonengine_functions_exempt(self):
        src = """
            def _helper(W):
                return W
            """
        assert "engine-contract" not in rules_of(
            run(src, config=SNIPPET_ENGINE))
        assert "engine-contract" not in rules_of(
            run("def batched_foo(W):\n    return W\n"))


# ---------------------------------------------------------------------------
# baseline + suppression
# ---------------------------------------------------------------------------

class TestBaselineAndSuppression:
    def test_fingerprint_is_line_number_independent(self):
        src = """
            import numpy as np

            def sample(n):
                return np.random.rand(n)
            """
        shifted = "\n\n\n" + textwrap.dedent(src)
        fp1 = {v.fingerprint() for v in run(src)}
        fp2 = {v.fingerprint()
               for v in lint_source(shifted, path="snippet.py")}
        assert fp1 and fp1 == fp2

    def test_baseline_roundtrip(self, tmp_path):
        vs = run("""
            import numpy as np
            np.random.seed(0)
            """)
        assert vs
        path = str(tmp_path / "baseline.txt")
        write_baseline(path, vs)
        assert load_baseline(path) == {v.fingerprint() for v in vs}
        assert load_baseline(str(tmp_path / "absent.txt")) == set()

    def test_inline_suppression_by_rule(self):
        vs = run("""
            import numpy as np
            np.random.seed(0)  # repro-lint: ignore[rng-discipline]
            """)
        assert "rng-discipline" not in rules_of(vs)

    def test_inline_suppression_all_rules(self):
        vs = run("""
            import numpy as np
            np.random.seed(0)  # repro-lint: ignore
            """)
        assert "rng-discipline" not in rules_of(vs)

    def test_wrong_rule_suppression_does_not_hide(self):
        vs = run("""
            import numpy as np
            np.random.seed(0)  # repro-lint: ignore[trace-safety]
            """)
        assert "rng-discipline" in rules_of(vs)

    def test_syntax_error_reported_not_raised(self):
        vs = run("def broken(:\n")
        assert any(v.rule == "parse" for v in vs)


# ---------------------------------------------------------------------------
# @contract decorator semantics
# ---------------------------------------------------------------------------

@pytest.fixture
def checking_on():
    contracts.enable()
    yield
    contracts.disable()


class TestContractDecorator:
    def test_matching_call_passes(self, checking_on):
        @contract("[B,N,N]", ret="[B]")
        def f(W):
            return np.zeros(W.shape[0])

        assert f(np.zeros((3, 4, 4))).shape == (3,)

    def test_rank_mismatch_raises(self, checking_on):
        @contract("[B,N,N]")
        def f(W):
            return W

        with pytest.raises(ContractError, match="argument 'W'"):
            f(np.zeros((3, 4)))

    def test_dim_binding_across_arguments(self, checking_on):
        @contract("[B,N,N]", "[B,N]")
        def f(W, t0):
            return W

        f(np.zeros((2, 3, 3)), np.zeros((2, 3)))
        with pytest.raises(ContractError, match="t0"):
            f(np.zeros((2, 3, 3)), np.zeros((2, 4)))

    def test_return_contract_checked(self, checking_on):
        @contract("[B,N,N]", ret="[B]")
        def f(W):
            return np.zeros(W.shape[0] + 1)

        with pytest.raises(ContractError, match="return value"):
            f(np.zeros((3, 4, 4)))

    def test_alternation(self, checking_on):
        @contract("[B,N,N]|[N,N]")
        def f(W):
            return W

        f(np.zeros((2, 3, 3)))
        f(np.zeros((3, 3)))
        with pytest.raises(ContractError):
            f(np.zeros((3,)))

    def test_optional_spec_skips_none(self, checking_on):
        @contract("[B,N,N]", "*[B,N]")
        def f(W, t0=None):
            return W

        f(np.zeros((2, 3, 3)))
        f(np.zeros((2, 3, 3)), np.zeros((2, 3)))
        with pytest.raises(ContractError):
            f(np.zeros((2, 3, 3)), np.zeros((9, 3)))

    def test_expression_dims(self, checking_on):
        @contract("[N,N]", "R", ret="[R+1,N]")
        def f(W, rounds):
            return np.zeros((rounds + 1, W.shape[0]))

        f(np.zeros((4, 4)), 7)

        @contract("[N,N]", "R", ret="[R+1,N]")
        def g(W, rounds):
            return np.zeros((rounds + 2, W.shape[0]))

        with pytest.raises(ContractError):
            g(np.zeros((4, 4)), 7)

    def test_seqlen_and_scalar_specs(self, checking_on):
        @contract("#E", "N")
        def f(edges, n):
            return len(edges), n

        f([(0, 1), (1, 0)], 2)
        with pytest.raises(ContractError, match="static Python int"):
            f([(0, 1)], np.zeros((2, 2)))

    def test_edgebatch_spec(self, checking_on):
        @contract("eb[B,E,N]", ret="[B]")
        def f(eb):
            return np.zeros(eb.src.shape[0])

        eb = SimpleNamespace(src=np.zeros((2, 5), dtype=np.int32),
                             dst=np.zeros((2, 5), dtype=np.int32),
                             w=np.zeros((2, 5)), num_nodes=4)
        f(eb)
        eb_bad = SimpleNamespace(src=np.zeros((2, 5), dtype=np.int32),
                                 dst=np.zeros((2, 6), dtype=np.int32),
                                 w=np.zeros((2, 5)), num_nodes=4)
        with pytest.raises(ContractError, match="disagree"):
            f(eb_bad)

    def test_edgebatch_expression_uses_num_nodes(self, checking_on):
        # N binds from num_nodes before the E+N edge-count expression.
        @contract("N", ret="eb[B,E+N,N]")
        def f(n):
            return SimpleNamespace(src=np.zeros((1, 7), dtype=np.int32),
                                   dst=np.zeros((1, 7), dtype=np.int32),
                                   w=np.zeros((1, 7)), num_nodes=n)

        contracts.enable()
        with pytest.raises(ContractError):
            f(3)  # E would need to be 4 == 7 - 3, but E is unbound: ok
        # A consistent case: E bound by an input edge batch.

        @contract("eb[B,E,N]", ret="eb[B,E+N,N]")
        def pad(eb):
            b, e = eb.src.shape
            n = eb.num_nodes
            z = np.zeros((b, e + n), dtype=np.int32)
            return SimpleNamespace(src=z, dst=z, w=np.zeros((b, e + n)),
                                   num_nodes=n)

        eb = SimpleNamespace(src=np.zeros((2, 5), dtype=np.int32),
                             dst=np.zeros((2, 5), dtype=np.int32),
                             w=np.zeros((2, 5)), num_nodes=4)
        out = pad(eb)
        assert out.src.shape == (2, 9)

    def test_disabled_mode_skips_checks(self):
        contracts.disable()
        try:
            @contract("[B,N,N]")
            def f(W):
                return W

            # wrong rank sails through when checking is off
            assert f(np.zeros((3,))).shape == (3,)
        finally:
            contracts.disable()

    def test_bad_spec_fails_at_decoration_time(self):
        with pytest.raises(ValueError):
            @contract("[B,N,N")
            def f(W):
                return W

    def test_real_engine_entry_point_enforced(self, checking_on):
        from repro.core.maxplus_vec import batched_cycle_time

        with pytest.raises(ContractError):
            batched_cycle_time(np.zeros((2, 3, 4)))  # not square


# ---------------------------------------------------------------------------
# obs-purity
# ---------------------------------------------------------------------------

class TestObsPurity:
    def test_span_call_inside_jitted_body(self):
        vs = run("""
            import jax
            from repro.obs.spans import span

            @jax.jit
            def f(x):
                with span("inside"):
                    return x + 1
            """)
        assert any(v.rule == "obs-purity" and "host effects" in v.message
                   for v in vs)

    def test_metrics_call_inside_scan_body(self):
        vs = run("""
            from jax import lax
            from repro.obs import metrics as obs_metrics

            def body(carry, x):
                obs_metrics.counter("steps").inc()
                return carry + x, carry

            def roll(xs):
                return lax.scan(body, 0.0, xs)
            """)
        assert "obs-purity" in rules_of(vs)

    def test_lazy_obs_import_inside_traced_body(self):
        vs = run("""
            import jax

            @jax.jit
            def f(x):
                from repro.obs.events import FlightRecorder
                return x
            """)
        assert any(v.rule == "obs-purity" and "lazy import" in v.message
                   for v in vs)

    def test_span_decorator_on_traced_function(self):
        vs = run("""
            import jax
            from repro.obs.spans import span_fn

            @jax.jit
            @span_fn("engine.bad_jax")
            def f_jax(x):
                return x + 1
            """)
        assert any(v.rule == "obs-purity" and "decorate the host-level"
                   in v.message for v in vs)

    def test_host_level_span_decorator_is_clean(self):
        vs = run("""
            import jax
            from repro.obs.spans import span, span_fn

            @jax.jit
            def kernel_jax(x):
                return x * 2

            @span_fn("engine.entry")
            def entry(x):
                with span("engine.dispatch"):
                    return kernel_jax(x)
            """)
        assert "obs-purity" not in rules_of(vs)

    def test_relative_obs_import_is_recognized(self):
        vs = run("""
            import jax
            from ..obs.spans import span

            @jax.jit
            def f(x):
                with span("inside"):
                    return x
            """)
        assert "obs-purity" in rules_of(vs)

    def test_instrumented_engine_modules_stay_clean(self):
        import pathlib
        root = pathlib.Path(__file__).resolve().parent.parent
        for rel in ("src/repro/core/maxplus_vec.py",
                    "src/repro/core/maxplus_sparse.py",
                    "src/repro/core/topologies.py",
                    "src/repro/dynamics/controller.py"):
            src = (root / rel).read_text()
            vs = lint_source(src, path=rel)
            assert not [v for v in vs if v.rule == "obs-purity"], rel
