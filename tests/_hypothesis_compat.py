"""Hypothesis compatibility layer for the test suite.

When the real ``hypothesis`` package is installed it is re-exported
unchanged.  When it is missing (the CI container does not ship it), a
minimal fallback degrades ``@given`` to a *deterministic* sample sweep:
each example draws from a ``random.Random`` seeded by the test's
qualified name and the example index, so failures are reproducible and
runs are hermetic.

Only the API surface the suite actually uses is emulated:

    given, settings, strategies.{integers, floats, lists, sampled_from,
    data, booleans, tuples}

Shrinking, targeted search, and the database are intentionally absent —
this is a degraded mode whose job is to keep the property tests running
(and meaningful) without the dependency, not to replace Hypothesis.
"""

from __future__ import annotations

import zlib
from typing import Any, Callable, List, Optional, Sequence

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random as _random

    _DEFAULT_MAX_EXAMPLES = 15
    # Deterministic sweeps explore less per example than Hypothesis'
    # guided search would; cap the sweep so degraded mode stays fast.
    _MAX_EXAMPLES_CAP = 25

    class _Strategy:
        def __init__(self, sample: Callable[[_random.Random], Any]):
            self._sample = sample

        def sample(self, rng: _random.Random) -> Any:
            return self._sample(rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: None)

    class _DataObject:
        """Stand-in for hypothesis' interactive ``data`` fixture."""

        def __init__(self, rng: _random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy, label: Optional[str] = None) -> Any:
            return strategy.sample(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements: Sequence[Any]) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def lists(
            elements: _Strategy, *, min_size: int = 0, max_size: int = 10
        ) -> _Strategy:
            def sample(rng: _random.Random) -> List[Any]:
                size = rng.randint(min_size, max_size)
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(sample)

        @staticmethod
        def tuples(*parts: _Strategy) -> _Strategy:
            return _Strategy(lambda rng: tuple(p.sample(rng) for p in parts))

        @staticmethod
        def data() -> _DataStrategy:
            return _DataStrategy()

    strategies = _Strategies()

    def given(*strats: _Strategy):
        def decorate(fn):
            def wrapper():
                seed_base = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode()
                )
                n = min(
                    getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES),
                    _MAX_EXAMPLES_CAP,
                )
                for idx in range(n):
                    rng = _random.Random(seed_base + idx)
                    args = [
                        _DataObject(rng) if isinstance(s, _DataStrategy) else s.sample(rng)
                        for s in strats
                    ]
                    try:
                        fn(*args)
                    except Exception as exc:  # reattach the failing example
                        raise AssertionError(
                            f"falsifying example #{idx} of {fn.__qualname__}: "
                            f"args={args!r}"
                        ) from exc

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate

    def settings(*, max_examples: Optional[int] = None, **_ignored):
        """Accepts (and mostly ignores) hypothesis settings kwargs."""

        def decorate(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn

        return decorate
