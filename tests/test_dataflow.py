"""Unit tests for repro.analysis.dataflow: CFG shape, reaching
definitions / def-use chains, and host-origin inference."""

import ast
import textwrap

import pytest

from repro.analysis.dataflow import (CFG, Entry, analyze_function,
                                     assigned_names, names_loaded,
                                     propagate, reaching_definitions)


def fn_of(src: str) -> ast.AST:
    tree = ast.parse(textwrap.dedent(src))
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return node
    raise AssertionError("no function in snippet")


def stmt_at(fn: ast.AST, line_frag: str, src: str) -> ast.stmt:
    """The CFG statement whose source line contains ``line_frag``."""
    lines = textwrap.dedent(src).splitlines()
    cfg = CFG(fn)
    for stmt in cfg.statements():
        text = lines[stmt.lineno - 1]
        if line_frag in text:
            return stmt
    raise AssertionError(f"no statement matching {line_frag!r}")


# ---------------------------------------------------------------------------
# CFG construction
# ---------------------------------------------------------------------------

class TestCFG:
    def test_straightline(self):
        fn = fn_of("""
            def f(x):
                a = 1
                b = a + x
                return b
            """)
        cfg = CFG(fn)
        stmts = cfg.statements()
        assert len(stmts) == 3
        ret = stmts[-1]
        assert cfg.exit in cfg.succs[ret]

    def test_if_joins(self):
        src = """
            def f(x):
                if x:
                    a = 1
                else:
                    a = 2
                return a
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        ret = stmt_at(fn, "return a", src)
        # both branch assignments are predecessors of the return
        preds = cfg.preds[ret]
        assert len([p for p in preds if isinstance(p, ast.Assign)]) == 2

    def test_loop_back_edge(self):
        src = """
            def f(xs):
                total = 0
                for x in xs:
                    total = total + x
                return total
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        loop = stmt_at(fn, "for x in xs", src)
        body = stmt_at(fn, "total = total + x", src)
        assert loop in cfg.succs[body]      # back edge
        ret = stmt_at(fn, "return total", src)
        assert ret in cfg.succs[loop]       # zero-iteration exit

    def test_while_break_reaches_after(self):
        src = """
            def f(x):
                while x:
                    if x > 3:
                        break
                    x = x - 1
                return x
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        brk = stmt_at(fn, "break", src)
        ret = stmt_at(fn, "return x", src)
        assert ret in cfg.succs[brk]

    def test_continue_targets_loop_header(self):
        src = """
            def f(xs):
                for x in xs:
                    if x < 0:
                        continue
                    use(x)
                return xs
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        cont = stmt_at(fn, "continue", src)
        loop = stmt_at(fn, "for x in xs", src)
        assert cfg.succs[cont] == {loop}

    def test_return_is_terminal(self):
        src = """
            def f(x):
                if x:
                    return 1
                return 2
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        r1 = stmt_at(fn, "return 1", src)
        assert cfg.succs[r1] == {cfg.exit}

    def test_try_except_edges(self):
        src = """
            def f(x):
                try:
                    a = risky(x)
                    b = a + 1
                except ValueError:
                    b = 0
                return b
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        handler_assign = stmt_at(fn, "b = 0", src)
        risky = stmt_at(fn, "a = risky(x)", src)
        # any try-body statement may raise into the handler
        assert handler_assign in cfg.succs[risky]
        ret = stmt_at(fn, "return b", src)
        assert ret in cfg.succs[handler_assign]

    def test_finally_on_all_paths(self):
        src = """
            def f(x):
                try:
                    a = risky(x)
                except ValueError:
                    a = 0
                finally:
                    log(a)
                return a
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        fin = stmt_at(fn, "log(a)", src)
        ret = stmt_at(fn, "return a", src)
        assert ret in cfg.succs[fin]
        # both the body exit and the handler exit flow through finally
        assert len(cfg.preds[fin]) >= 2

    def test_nested_def_is_opaque(self):
        src = """
            def f(x):
                def g(y):
                    return y + 1
                return g(x)
            """
        fn = fn_of(src)
        cfg = CFG(fn)
        # the inner return belongs to g's CFG, not f's
        inner_returns = [s for s in cfg.statements()
                         if isinstance(s, ast.Return)
                         and "y + 1" in ast.unparse(s)]
        assert inner_returns == []


# ---------------------------------------------------------------------------
# assigned/loaded names
# ---------------------------------------------------------------------------

class TestNames:
    def test_tuple_unpack(self):
        stmt = ast.parse("a, (b, c) = f()").body[0]
        assert assigned_names(stmt) == {"a", "b", "c"}

    def test_walrus(self):
        stmt = ast.parse("y = (n := len(xs)) + 1").body[0]
        assert assigned_names(stmt) == {"y", "n"}

    def test_for_target(self):
        stmt = ast.parse("for k, v in d.items():\n    pass").body[0]
        assert assigned_names(stmt) == {"k", "v"}

    def test_comprehension_locals_not_loaded(self):
        stmt = ast.parse("out = [x * s for x in xs]").body[0]
        loaded = names_loaded(stmt)
        assert "x" not in loaded
        assert {"xs", "s"} <= loaded

    def test_augassign_reads_target(self):
        stmt = ast.parse("total += x").body[0]
        assert "total" in names_loaded(stmt)


# ---------------------------------------------------------------------------
# reaching definitions / def-use
# ---------------------------------------------------------------------------

class TestReachingDefs:
    def test_kill_on_rebind(self):
        src = """
            def f(p):
                a = 1
                a = 2
                return a
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        ret = stmt_at(fn, "return a", src)
        defs = an.defs_of("a", ret)
        assert len(defs) == 1
        assert "2" in ast.unparse(next(iter(defs)))

    def test_branch_defs_merge(self):
        src = """
            def f(p):
                if p:
                    a = 1
                else:
                    a = 2
                return a
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        ret = stmt_at(fn, "return a", src)
        assert len(an.defs_of("a", ret)) == 2

    def test_loop_carried_def_reaches_header(self):
        src = """
            def f(xs):
                total = 0
                for x in xs:
                    total = total + x
                return total
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        body = stmt_at(fn, "total = total + x", src)
        defs = an.defs_of("total", body)
        # both the init and the loop-carried def reach the body
        assert len(defs) == 2

    def test_param_defined_at_entry(self):
        src = """
            def f(p):
                return p
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        ret = stmt_at(fn, "return p", src)
        defs = an.defs_of("p", ret)
        assert len(defs) == 1
        assert isinstance(next(iter(defs)), Entry)

    def test_except_handler_sees_partial_defs(self):
        src = """
            def f(x):
                a = 0
                try:
                    a = risky(x)
                except ValueError:
                    b = a
                return a
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        handler = stmt_at(fn, "b = a", src)
        # the raise may happen before OR after `a = risky(x)` ran
        assert len(an.defs_of("a", handler)) == 2

    def test_chains_cover_all_loads(self):
        src = """
            def f(p):
                a = p + 1
                return a
            """
        fn = fn_of(src)
        an = analyze_function(fn)
        chains = an.chains()
        keys = {var for (_, var) in chains}
        assert {"p", "a"} <= keys


# ---------------------------------------------------------------------------
# host-origin inference
# ---------------------------------------------------------------------------

def host_of(src: str, frag: str) -> bool:
    """host_only() of the first call whose source contains ``frag``."""
    fn = fn_of(src)
    an = analyze_function(fn)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and frag in ast.unparse(node):
            return an.host_only(node)
    raise AssertionError(f"no call matching {frag!r}")


class TestHostOrigin:
    def test_rng_scalar_is_host(self):
        assert host_of("""
            def f(seed):
                rng = np.random.default_rng(seed)
                return float(rng.uniform())
            """, "float(")

    def test_param_is_not_host(self):
        assert not host_of("""
            def f(x):
                return float(x)
            """, "float(")

    def test_unknown_call_is_not_host(self):
        assert not host_of("""
            def f(step, x):
                y = step(x)
                return float(y)
            """, "float(y)")

    def test_np_call_chain_is_host(self):
        assert host_of("""
            def f(xs):
                a = np.array(xs, dtype=np.float64, copy=True)
                coeff = a.min()
                return float(coeff)
            """, "float(coeff)")

    def test_loop_carried_host_var_stays_host(self):
        assert host_of("""
            def f(n):
                total = 0.0
                for i in range(n):
                    total = total + 1.5
                return float(total)
            """, "float(total)")

    def test_mixed_branch_is_not_host(self):
        assert not host_of("""
            def f(p, flag):
                if flag:
                    v = 1.0
                else:
                    v = p
                return float(v)
            """, "float(v)")

    def test_comprehension_over_host_iter_is_host(self):
        assert host_of("""
            def f(n):
                xs = [i * 2 for i in range(n)]
                return sum(xs)
            """, "sum(")


# ---------------------------------------------------------------------------
# generic propagate driver
# ---------------------------------------------------------------------------

class TestPropagate:
    def test_fixpoint_over_loop(self):
        src = """
            def f(n):
                x = 0
                while x < n:
                    x = x + 1
                return x
            """
        fn = fn_of(src)
        cfg = CFG(fn)

        # abstract state: set of assignment linenos seen on some path
        def transfer(node, state):
            if isinstance(node, ast.Assign):
                return state | {node.lineno}
            return state

        def join(states):
            out = frozenset()
            for s in states:
                out |= s
            return out

        in_states = propagate(cfg, frozenset(), transfer, join)
        ret = stmt_at(fn, "return x", src)
        # both the init and the loop body assignment reach the return
        assert len(in_states[ret]) == 2
