"""Online topology re-design under a mid-training core-link failure.

Gaia underlay (11 AWS regions), iNaturalist workload.  The designed RING
overlay is throughput-optimal for the measured network; a third of the
way into training the core link its busiest hop rides on fails, traffic
re-routes the long way round, and the realized round time detaches from
the max-plus prediction.  We compare:

* **non-adaptive** — the paper's open-loop pipeline: keep the original
  overlay to the deadline;
* **adaptive**     — the online controller: detect the regression,
  re-design on the updated estimate (batched candidate scoring), hot-swap
  the gossip plan;
* **oracle**       — re-design instantly at the failure with full
  knowledge of the post-failure network (static-optimal bound).

The controller should recover >= 80% of the oracle's post-failure
throughput; it typically lands within a few percent, paying only the
detection lag.

    PYTHONPATH=src python examples/dynamic_topology.py [--workload femnist]
"""

import argparse

import repro.core as C
from repro.dynamics import (
    ControllerConfig,
    DynamicTimeline,
    OnlineTopologyController,
    active_subgraph,
    design_best_overlay,
    link_failure_scenario,
    simulate_dynamic,
)


def run_adaptive(scenario, tp, gc0, overlay, deadline_ms, seed=0):
    timeline = DynamicTimeline(scenario, tp)
    timeline.set_overlay(overlay.edges)
    controller = OnlineTopologyController(
        gc0, tp, overlay,
        config=ControllerConfig(seed=seed),
        connectivity_provider=lambda: active_subgraph(
            timeline.current_epoch().gc, timeline.current_epoch().active),
    )
    while timeline.now_ms < deadline_ms:
        redesign = controller.observe_round(timeline.step())
        if redesign is not None:
            timeline.set_overlay(redesign.overlay.edges)
            print(f"  [controller] round {redesign.round_idx} "
                  f"(t={timeline.now_ms/1e3:.1f}s): measured "
                  f"{redesign.measured_ms:.1f} ms/round >> prediction; "
                  f"re-designed -> {redesign.overlay.name} "
                  f"(tau {redesign.predicted_tau_ms:.1f} ms, "
                  f"{redesign.n_candidates} candidates scored in "
                  f"{redesign.elapsed_s*1e3:.0f} ms)")
            print(f"  [controller] new bottleneck circuit: "
                  f"{'-'.join(map(str, redesign.bottleneck))}")
    return timeline, controller


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="inaturalist", choices=list(C.WORKLOADS))
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    M, Tc = C.WORKLOADS[args.workload]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("gaia")
    gc0 = u.connectivity_graph(comp_time_ms=Tc)
    overlay = C.design_overlay("ring", gc0, tp)
    print(f"gaia x {args.workload}: designed {overlay.name}, "
          f"tau = {overlay.cycle_time_ms:.1f} ms")

    deadline_ms = args.rounds * overlay.cycle_time_ms
    t_fail_ms = deadline_ms / 3
    scenario = link_failure_scenario(
        u, Tc, t_fail_ms=t_fail_ms, overlay_edges=overlay.edges,
        horizon_ms=deadline_ms)
    failed = scenario.events[0].link
    print(f"scenario: core link {failed} "
          f"({C.GAIA_SITES[failed[0]][0]}-{C.GAIA_SITES[failed[1]][0]}) "
          f"fails at t={t_fail_ms/1e3:.1f}s; deadline {deadline_ms/1e3:.1f}s\n")

    # Non-adaptive baseline: the original overlay to the deadline.
    base = simulate_dynamic(scenario, tp, overlay.edges,
                            num_rounds=2 * args.rounds)

    # Oracle bound: static-optimal overlay for the post-failure network.
    post_gc = scenario.segments()[-1].gc
    oracle, _ = design_best_overlay(post_gc, tp, rng=None)
    print(f"post-failure: old overlay tau {base.predicted_tau_ms[-1]:.1f} ms, "
          f"static-optimal (oracle) tau {oracle.cycle_time_ms:.1f} ms")

    # Adaptive: monitor -> detect -> re-design -> hot-swap.
    timeline, controller = run_adaptive(
        scenario, tp, gc0, overlay, deadline_ms, seed=args.seed)

    window_ms = deadline_ms - t_fail_ms
    finish = timeline.round_finish_ms
    adaptive_rounds = sum(1 for f in finish[1:]
                          if t_fail_ms < f <= deadline_ms)
    base_rounds = (base.rounds_completed_by(deadline_ms)
                   - base.rounds_completed_by(t_fail_ms))
    oracle_thr = 1e3 / oracle.cycle_time_ms
    adaptive_thr = 1e3 * adaptive_rounds / window_ms
    base_thr = 1e3 * base_rounds / window_ms
    recovery = adaptive_thr / oracle_thr

    print(f"\npost-failure window ({window_ms/1e3:.1f}s):")
    print(f"  {'policy':14s} {'rounds':>7s} {'rounds/s':>9s} {'vs oracle':>10s}")
    print(f"  {'oracle':14s} {window_ms/oracle.cycle_time_ms:7.1f} "
          f"{oracle_thr:9.2f} {'100.0%':>10s}")
    print(f"  {'adaptive':14s} {adaptive_rounds:7d} {adaptive_thr:9.2f} "
          f"{100*recovery:9.1f}%")
    print(f"  {'non-adaptive':14s} {base_rounds:7d} {base_thr:9.2f} "
          f"{100*base_thr/oracle_thr:9.1f}%")
    assert recovery >= 0.80, (
        f"controller recovered only {100*recovery:.1f}% of static-optimal")
    assert adaptive_rounds > base_rounds, "adaptive did not beat non-adaptive"
    print(f"\ncontroller recovered {100*recovery:.1f}% of the static-optimal "
          f"throughput ({adaptive_rounds - base_rounds:+d} rounds vs "
          f"non-adaptive)")


if __name__ == "__main__":
    main()
