"""End-to-end driver: federated training of a GPT-style LM with DPASGD
over a designed topology, comparing STAR vs RING wall-clock estimates
via the paper's timing model while the real training runs.

Default is laptop-scale (a few M params, a few hundred steps on CPU);
``--full`` scales the model to ~100M params (slow on CPU — intended for
real accelerators).

    PYTHONPATH=src python examples/federated_training.py --steps 200
"""

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as C
from repro.fed import DPASGDConfig, init_state, make_train_step
from repro.fed.topology_runtime import plan_for_n_silos
from repro.models import ModelConfig, count_params
from repro.models.transformer import model_specs
from repro.optim import adamw
from repro.data import SyntheticLMStream, FederatedBatcher
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "star", "chain"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (accelerator recommended)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args()

    n = args.silos
    if args.full:
        cfg = ModelConfig("fed-100m", "dense", 12, 768, 12, 4, 3072, 32000,
                          n_silos=n)
        seq, bps = 256, 8
    else:
        cfg = ModelConfig("fed-small", "dense", 4, 128, 4, 2, 512, 1024,
                          n_silos=n)
        seq, bps = 64, 8
    print(f"model: {count_params(model_specs(cfg)):,} params, "
          f"{n} silos, topology={args.topology}")

    # --- paper timing model: what would this run cost on the Gaia WAN?
    M_bits = count_params(model_specs(cfg)) * 32 / 1e6
    tp = C.TrainingParams(model_size_mbits=M_bits, local_steps=args.local_steps)
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=25.0)
    star = C.star_overlay(gc, tp, center=u.load_centrality_center())
    ring = C.ring_overlay(gc, tp)
    chosen = ring if args.topology == "ring" else star
    print(f"paper timing model (Gaia, 10 Gbps access): "
          f"STAR {star.cycle_time_ms:.0f} ms/round, RING {ring.cycle_time_ms:.0f} "
          f"ms/round -> {args.steps} rounds = "
          f"{chosen.cycle_time_ms * args.steps / 1000:.1f} s on the WAN")

    # --- real DPASGD training on the host mesh
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    plan = plan_for_n_silos(args.topology, n)
    opt = adamw(3e-3)
    fed = DPASGDConfig(local_steps=args.local_steps, gossip_impl="ppermute",
                       silo_axis="data")
    step = jax.jit(make_train_step(cfg, fed, opt, plan, mesh))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    state = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(
            mesh, P(*(("data",) + (None,) * (x.ndim - 1)))))
        if getattr(x, "ndim", 0) > 0 else x, state)
    stream = SyntheticLMStream(cfg.vocab_size, seq, n_silos=n, alpha=0.3)
    data = FederatedBatcher(stream, args.local_steps, bps)
    t0 = time.time()
    first = last = None
    with jax.set_mesh(mesh):
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            if first is None:
                first = loss
            last = loss
            if i % args.eval_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"round {i:4d} loss {loss:.4f}  ({dt:.1f}s, "
                      f"{(i + 1) / dt:.2f} rounds/s)", flush=True)
    print(f"loss: {first:.4f} -> {last:.4f} over {args.steps} rounds")
    assert last < first, "training must reduce loss"
    if args.checkpoint:
        save_checkpoint(args.checkpoint, jax.device_get(state["params"]),
                        step=args.steps)
        print("checkpoint saved:", args.checkpoint)


if __name__ == "__main__":
    main()
