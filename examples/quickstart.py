"""Quickstart: design a throughput-optimal topology for a real network,
inspect its max-plus cycle time, and train a small federated model on it.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as C
from repro.fed import DPASGDConfig, init_state, make_train_step
from repro.fed.topology_runtime import plan_from_overlay
from repro.models import ModelConfig
from repro.optim import momentum
from repro.data import SyntheticLMStream, FederatedBatcher


def main():
    # ------------------------------------------------------------------
    # 1. Topology design on the Gaia (11 AWS regions) underlay
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    underlay = C.make_underlay("gaia", core_capacity_gbps=1.0,
                               access_capacity_gbps=10.0)
    gc = underlay.connectivity_graph(comp_time_ms=Tc)

    star = C.star_overlay(gc, tp, center=underlay.load_centrality_center())
    mst = C.mst_overlay(gc, tp)
    ring = C.ring_overlay(gc, tp)
    print("cycle time (ms):  STAR %.0f | MST %.0f | RING %.0f" %
          (star.cycle_time_ms, mst.cycle_time_ms, ring.cycle_time_ms))
    print("RING speedup vs STAR: %.2fx  (paper Table 3: 3.3x on Gaia)" %
          (star.cycle_time_ms / ring.cycle_time_ms))

    # the max-plus identity: simulated timeline slope == analytic tau
    tl = C.simulate_overlay(gc, tp, ring.edges, num_rounds=100)
    print("simulator slope %.1f ms vs Karp tau %.1f ms" %
          (tl.empirical_cycle_time(), ring.cycle_time_ms))

    # ------------------------------------------------------------------
    # 2. Compile the designed ring into a TPU gossip schedule and train.
    n = 4  # four silos on four host devices
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = ModelConfig("quickstart", "dense", 2, 64, 2, 2, 128, 256, n_silos=n)
    from repro.fed.topology_runtime import plan_for_n_silos

    plan = plan_for_n_silos("ring", n)
    print(f"ring gossip = {plan.num_transfers} ppermute round(s) per mix")
    opt = momentum(0.05, 0.9)
    fed = DPASGDConfig(local_steps=2, gossip_impl="ppermute", silo_axis="data")
    step = jax.jit(make_train_step(cfg, fed, opt, plan, mesh))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    state = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(
            mesh, P(*(("data",) + (None,) * (x.ndim - 1)))))
        if getattr(x, "ndim", 0) > 0 else x, state)
    data = FederatedBatcher(SyntheticLMStream(cfg.vocab_size, 32, n_silos=n),
                            local_steps=2, batch_per_silo=4)
    with jax.set_mesh(mesh):
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, metrics = step(state, batch)
            print(f"  round {i}: loss {float(metrics['loss']):.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
