"""Batched serving demo: prefill a batch of prompts, then decode with a
KV cache — including a sliding-window (sub-quadratic) arch to show the
bounded-cache path used by ``long_500k``.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, transformer as T


def serve(arch: str, batch: int = 4, prompt: int = 48, gen: int = 12):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, T.model_specs(cfg))
    prompts = jax.random.randint(key, (batch, prompt), 0, cfg.vocab_size)
    extras = {}
    if cfg.is_encdec:
        extras["enc_frames"] = jnp.ones((batch, cfg.encoder.seq_len, 128),
                                        jnp.float32)
    max_len = prompt + gen + 8
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: T.prefill(p, cfg, t, max_len, cache_dtype=jnp.float32,
                               **extras))(params, prompts)
    decode = jax.jit(lambda p, tok, c, pos: T.decode_step(p, cfg, tok, c, pos))
    tok = logits.argmax(-1).astype(jnp.int32)
    toks = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(prompt + i))
        tok = logits.argmax(-1).astype(jnp.int32)
        toks.append(tok)
    assert bool(jnp.isfinite(logits).all())
    out = jnp.stack(toks, 1)
    window = cfg.sliding_window
    print(f"{arch:22s} window={str(window):>5s} "
          f"gen[0]={list(map(int, out[0][:8]))} ({time.time()-t0:.1f}s)")


def main():
    for arch in ("internlm2-1.8b", "h2o-danube-1.8b", "xlstm-350m",
                 "hymba-1.5b", "deepseek-v2-lite-16b"):
        serve(arch)
    print("serving demo OK")


if __name__ == "__main__":
    main()
