"""Topology design deep-dive: all five networks of the paper, all five
overlay designers, with throughput, critical circuit, consensus spectral
gap, and the predicted TPU gossip schedule cost for each design.

    PYTHONPATH=src python examples/topology_design.py [--workload femnist]
"""

import argparse

import numpy as np

import repro.core as C
from repro.core.delays import overlay_delay_digraph
from repro.core.maxplus import critical_circuit
from repro.fed.topology_runtime import plan_from_overlay


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="inaturalist",
                    choices=list(C.WORKLOADS))
    ap.add_argument("--access-gbps", type=float, default=10.0)
    args = ap.parse_args()
    M, Tc = C.WORKLOADS[args.workload]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    print(f"workload={args.workload}: M={M} Mbit, T_c={Tc} ms\n")
    for net in C.NETWORK_NAMES:
        u = C.make_underlay(net, access_capacity_gbps=args.access_gbps)
        gc = u.connectivity_graph(comp_time_ms=Tc)
        print(f"== {net} ({u.num_silos} silos, {u.num_core_links} core links)")
        for kind in ("star", "mst", "delta_mbst", "ring", "ring_2opt"):
            if kind == "star":
                ov = C.star_overlay(gc, tp, center=u.load_centrality_center())
            else:
                ov = C.design_overlay(kind, gc, tp)
            plan = plan_from_overlay(ov, gc.num_silos)
            tau, circ = critical_circuit(
                overlay_delay_digraph(gc, tp, ov.edges))
            gap = C.spectral_gap(plan.matrix)
            print(f"  {kind:10s} tau={ov.cycle_time_ms:8.1f} ms "
                  f"throughput={1000.0/ov.cycle_time_ms:6.2f} rounds/s "
                  f"gossip_transfers={plan.num_transfers:3d} "
                  f"spectral_gap={gap:.3f} "
                  f"critical_circuit_len={max(len(circ)-1, 0)}")
        print()


if __name__ == "__main__":
    main()
