"""Structured JSONL flight recorder for the control loop.

Every consequential decision of the closed loop — a regression
detected, candidates priced, a plan chosen and hot-swapped, a
membership change, a dynamics epoch transition — is appended to a
trace file as one JSON object per line.  The trace is the *measured
event stream* the ROADMAP's sim-to-real direction asks for: it can be
replayed, diffed against another run, and rendered into a timeline /
bottleneck-attribution report (:mod:`repro.obs.report`,
``scripts/obs_report.py``).

Record envelope (every line)::

    {"v": <schema version>, "seq": <0,1,2,...>, "t_s": <seconds since
     run start>, "kind": <record kind>, ...payload...}

Record kinds and their required payload fields are declared in
:data:`SCHEMA`; extra fields are allowed (forward compatibility), and
missing required fields fail both at emission time and in
:func:`validate_trace` (the ``obs_report.py --check`` CI gate).  The
schema version moves only on *breaking* changes — removing or renaming
a required field, changing a field's meaning; adding record kinds or
optional fields keeps the version (a reader of version N reads any
trace of version N).  The taxonomy below is mirrored in
``docs/architecture.md`` and cross-checked by the docs gate.

This module is stdlib-only by design: it must be importable from
anywhere in the tree (including ``repro.core``) without dependency
cycles or jax imports.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time
from typing import Any, Dict, IO, List, Optional, Sequence, Tuple

from . import metrics as _metrics
from . import spans as _spans

__all__ = [
    "FlightRecorder",
    "SCHEMA",
    "TRACE_SCHEMA_VERSION",
    "read_trace",
    "run_metadata",
    "validate_record",
    "validate_trace",
]

#: Bump only on breaking changes to required fields (see module doc).
TRACE_SCHEMA_VERSION = 1

#: kind -> required payload fields (the envelope is implicit).
SCHEMA: Dict[str, Tuple[str, ...]] = {
    # run lifecycle
    "run_start": ("meta",),
    "run_end": ("metrics", "spans", "summary"),
    # dynamics: the network the loop is reacting to
    "epoch": ("index", "t_start_ms", "active"),
    # training loop: periodic per-round sample (cadence: --metrics-interval)
    "round": ("step", "duration_ms", "predicted_window_ms",
              "measured_window_ms", "drift"),
    # controller decisions
    "regression": ("round_idx", "measured_ms", "expected_window_ms",
                   "drift", "strikes"),
    "redesign": ("round_idx", "winner", "name", "predicted_tau_ms",
                 "measured_ms", "expected_window_ms", "drift",
                 "n_candidates", "elapsed_s", "bottleneck",
                 "bottleneck_names", "membership"),
    "membership": ("step", "version", "n_before", "n_after", "left",
                   "joined"),
    # slot hot-swaps (plan / schedule / membership versions)
    "swap": ("slot", "version", "label"),
    # periodic metrics snapshot
    "metrics": ("snapshot",),
}

_ENVELOPE = ("v", "seq", "t_s", "kind")


def _jsonable(o: Any) -> Any:
    """JSON fallback for numpy scalars/arrays, tuples-of, sets, paths."""
    if hasattr(o, "tolist"):  # numpy scalar or array
        return o.tolist()
    if isinstance(o, (set, frozenset)):
        return sorted(o)
    return str(o)


def _git_rev(root: Optional[str] = None) -> str:
    root = root or os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))))
    try:
        out = subprocess.run(
            ["git", "-C", root, "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            rev = out.stdout.strip()
            dirty = subprocess.run(
                ["git", "-C", root, "status", "--porcelain"],
                capture_output=True, text=True, timeout=5)
            if dirty.returncode == 0 and dirty.stdout.strip():
                rev += "-dirty"
            return rev
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _jax_version() -> str:
    jax = sys.modules.get("jax")
    if jax is not None:
        return getattr(jax, "__version__", "unknown")
    try:  # metadata lookup: no import side effects
        from importlib.metadata import version

        return version("jax")
    except Exception:
        return "unknown"


def _device_kind() -> str:
    """Backend platform of the default jax device — *only* if jax is
    already imported (metadata collection must never force an XLA
    client into existence)."""
    jax = sys.modules.get("jax")
    if jax is None:
        return "uninitialized"
    try:
        return jax.devices()[0].platform
    except Exception:
        return "unknown"


def run_metadata(extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Provenance stamp shared by traces and ``BENCH_*.json`` files:
    schema version, git rev (``-dirty`` suffixed), jax version, device
    kind, python/platform, argv, wall time."""
    meta: Dict[str, Any] = {
        "schema_version": TRACE_SCHEMA_VERSION,
        "git_rev": _git_rev(),
        "jax_version": _jax_version(),
        "device_kind": _device_kind(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "time_unix": time.time(),
    }
    if extra:
        meta.update(extra)
    return meta


class FlightRecorder:
    """Append-only JSONL trace writer.

    Opens ``path``, immediately writes the ``run_start`` record (with
    :func:`run_metadata` plus any caller ``meta``), then accepts
    :meth:`emit` calls until :meth:`close` writes ``run_end`` with the
    final metrics snapshot and span summary.  Each line is flushed as
    written: a crashed run leaves a readable (if ``run_end``-less)
    trace — that is the "flight recorder" property.

    ``silo_names`` (label -> human name, e.g. Gaia site names) is
    stored in the run metadata so reports can attribute bottleneck
    circuits to sites rather than integer labels.
    """

    def __init__(self, path: str, *,
                 meta: Optional[Dict[str, Any]] = None,
                 silo_names: Optional[Sequence[str]] = None):
        self.path = path
        self._fh: Optional[IO[str]] = open(path, "w", encoding="utf-8")
        self._seq = 0
        self._t0 = time.time()
        m = run_metadata(meta)
        if silo_names is not None:
            m["silo_names"] = [str(s) for s in silo_names]
        self.silo_names = m.get("silo_names")
        self.emit("run_start", meta=m)

    # -- core ----------------------------------------------------------

    def emit(self, kind: str, **payload: Any) -> Dict[str, Any]:
        """Append one record.  Unknown kinds and missing required
        fields raise immediately — a trace that validates at write time
        validates at read time."""
        if self._fh is None:
            raise ValueError(f"FlightRecorder({self.path}) is closed")
        required = SCHEMA.get(kind)
        if required is None:
            raise ValueError(f"unknown trace record kind {kind!r}; "
                             f"known: {sorted(SCHEMA)}")
        missing = [k for k in required if k not in payload]
        if missing:
            raise ValueError(f"{kind} record missing required "
                             f"field(s) {missing}")
        rec: Dict[str, Any] = {
            "v": TRACE_SCHEMA_VERSION,
            "seq": self._seq,
            "t_s": round(time.time() - self._t0, 6),
            "kind": kind,
        }
        rec.update(payload)
        self._fh.write(json.dumps(rec, default=_jsonable) + "\n")
        self._fh.flush()
        self._seq += 1
        return rec

    def close(self, **summary: Any) -> None:
        """Write ``run_end`` (metrics snapshot + span summary + caller
        summary fields) and close the file.  Idempotent."""
        if self._fh is None:
            return
        self.emit("run_end", metrics=_metrics.snapshot(),
                  spans=_spans.summary(), summary=summary)
        self._fh.close()
        self._fh = None

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.close()
        return False


# ---------------------------------------------------------------------------
# Readers / validators
# ---------------------------------------------------------------------------

def read_trace(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace into a list of record dicts (no validation)."""
    out: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_record(rec: Dict[str, Any]) -> List[str]:
    """Schema problems of one record (empty list == valid)."""
    problems: List[str] = []
    for k in _ENVELOPE:
        if k not in rec:
            problems.append(f"missing envelope field {k!r}")
    kind = rec.get("kind")
    if kind is not None:
        required = SCHEMA.get(kind)
        if required is None:
            problems.append(f"unknown record kind {kind!r}")
        else:
            for k in required:
                if k not in rec:
                    problems.append(f"{kind} record missing field {k!r}")
    v = rec.get("v")
    if v is not None and v > TRACE_SCHEMA_VERSION:
        problems.append(f"schema version {v} newer than reader "
                        f"({TRACE_SCHEMA_VERSION})")
    return problems


def validate_trace(path: str) -> Tuple[List[Dict[str, Any]], List[str]]:
    """(records, problems) for a whole trace file.

    Beyond per-record schema checks: the first record must be
    ``run_start`` carrying run metadata, and ``seq`` must count
    contiguously from 0 (a gap means lost records)."""
    problems: List[str] = []
    try:
        records = read_trace(path)
    except (OSError, json.JSONDecodeError) as exc:
        return [], [f"unreadable trace: {exc}"]
    if not records:
        return [], ["empty trace"]
    if records[0].get("kind") != "run_start":
        problems.append("first record is not run_start")
    elif not isinstance(records[0].get("meta"), dict):
        problems.append("run_start carries no metadata dict")
    for i, rec in enumerate(records):
        for p in validate_record(rec):
            problems.append(f"record {i}: {p}")
        if rec.get("seq") != i:
            problems.append(f"record {i}: seq {rec.get('seq')!r} "
                            f"(expected {i})")
    return records, problems
