"""Nested wall-clock spans with a near-zero-cost disabled path.

The paper's contribution is making round time *measurable* (Eq. 3/4);
this module makes the reproduction's own runtime measurable the same
way: every engine entry point, designer call, controller actuation and
train step can open a :func:`span`, and the resulting tree of timed
intervals answers "where did this round's wall clock go?" without a
profiler attached.

Design constraints (enforced by ``tests/test_obs.py`` and the
``obs-purity`` lint rule):

* **Default off, near-zero cost.**  ``span()`` with tracing disabled
  returns a shared no-op context manager — one module-global flag read
  and no allocation.  :func:`span_fn` wrappers fall through to the
  wrapped function on the same flag.  Tier-1 runs with observability
  disabled must not measurably slow down.
* **Trace-safe by construction.**  Spans read ``time.perf_counter()``
  — a host clock — so they must never execute inside jax-traced code.
  They instrument the *host-level* entry points (the numpy engines, the
  Python wrappers around jitted searches, the training loop), never
  scan/jit bodies.  A span around a jitted call measures dispatch +
  device time only when the callee blocks; that caveat is the caller's
  to document, not this module's to hide.
* **Thread-local nesting.**  The active span stack is per-thread, so
  concurrent controllers (the multi-tenant direction in ROADMAP.md)
  cannot corrupt each other's parentage.

Aggregation is always on while enabled: finished spans fold into a
process-local ``{name: (count, total_s, max_s)}`` table read by
:func:`summary` (what ``benchmarks/run.py`` writes next to the
``BENCH_*.json`` metrics and the flight recorder embeds in its
``run_end`` record).  The full span stream (with parent/depth) is kept
in a bounded ring for tests and ad-hoc inspection via
:func:`pop_finished`.
"""

from __future__ import annotations

import functools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

__all__ = [
    "Span",
    "SpanRecord",
    "disable",
    "enable",
    "enabled",
    "pop_finished",
    "reset",
    "span",
    "span_fn",
    "summary",
]


class _State:
    __slots__ = ("enabled", "capture")

    def __init__(self) -> None:
        self.enabled = False
        self.capture = True


_STATE = _State()
_TLS = threading.local()
_LOCK = threading.Lock()
# name -> [count, total_s, max_s]; folded under _LOCK on span exit.
_AGG: Dict[str, List[float]] = {}
_CAPTURE_MAX = 4096
_FINISHED: Deque["SpanRecord"] = deque(maxlen=_CAPTURE_MAX)


@dataclass(frozen=True)
class SpanRecord:
    """One finished span, as folded into the capture ring."""

    name: str
    parent: Optional[str]
    depth: int
    t_start_s: float  # perf_counter timestamp at entry
    duration_s: float
    attrs: Dict[str, Any] = field(default_factory=dict)


def _stack() -> List["Span"]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


class _NoopSpan:
    """Shared disabled-path span: no allocation, no clock read."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass


_NOOP = _NoopSpan()


class Span:
    """A live (enabled-path) span.  Use via :func:`span`, not directly."""

    __slots__ = ("name", "attrs", "parent", "depth", "_t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.parent: Optional[str] = None
        self.depth = 0
        self._t0 = 0.0

    def set(self, **attrs: Any) -> None:
        """Attach attributes to the span (recorded at exit)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        stack = _stack()
        if stack:
            self.parent = stack[-1].name
            self.depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        dur = time.perf_counter() - self._t0
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # misnested exit: drop down to this span
            while stack and stack.pop() is not self:
                pass
        with _LOCK:
            agg = _AGG.get(self.name)
            if agg is None:
                _AGG[self.name] = [1.0, dur, dur]
            else:
                agg[0] += 1.0
                agg[1] += dur
                if dur > agg[2]:
                    agg[2] = dur
            if _STATE.capture:
                _FINISHED.append(
                    SpanRecord(
                        name=self.name,
                        parent=self.parent,
                        depth=self.depth,
                        t_start_s=self._t0,
                        duration_s=dur,
                        attrs=dict(self.attrs),
                    )
                )
        return False


def span(name: str, **attrs: Any):
    """Open a named span: ``with span("engine.karp", batch=B): ...``.

    Disabled (the default) this returns a shared no-op context manager;
    the whole call costs one flag read."""
    if not _STATE.enabled:
        return _NOOP
    return Span(name, attrs)


def span_fn(name: str) -> Callable[[Callable], Callable]:
    """Decorator form: time every call of the wrapped function under
    ``name``.  The disabled path is a single flag check before a plain
    call — safe to leave on engine entry points permanently."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any):
            if not _STATE.enabled:
                return fn(*args, **kwargs)
            with Span(name, {}):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def enable(capture: bool = True) -> None:
    """Turn span recording on.  ``capture=False`` keeps only the
    aggregate table (skips the per-span ring — for long runs)."""
    _STATE.capture = capture
    _STATE.enabled = True


def disable() -> None:
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


def reset() -> None:
    """Clear the aggregate table and the capture ring (not the flag)."""
    with _LOCK:
        _AGG.clear()
        _FINISHED.clear()


def summary() -> Dict[str, Dict[str, float]]:
    """``{name: {count, total_s, max_s, mean_s}}`` for all finished
    spans since the last :func:`reset`."""
    with _LOCK:
        return {
            name: {
                "count": int(c),
                "total_s": t,
                "max_s": m,
                "mean_s": t / c if c else 0.0,
            }
            for name, (c, t, m) in sorted(_AGG.items())
        }


def pop_finished() -> List[SpanRecord]:
    """Drain and return the captured span ring (oldest first)."""
    with _LOCK:
        out = list(_FINISHED)
        _FINISHED.clear()
    return out
