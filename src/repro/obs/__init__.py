"""Observability for the control loop: spans, metrics, events, logs.

The closed loop (engines → designer → controller → hot-swap → training)
detects regressions, prices candidates and swaps plans with — before
this package — no externally consumable record of what it saw, decided
or cost.  ``repro.obs`` is that record, in four trace-safe layers:

* :mod:`~repro.obs.spans`   — nested wall-clock spans over *host-level*
  entry points (engine calls, designer searches, redesigns, train
  steps).  Default off; the disabled path is one flag read.
* :mod:`~repro.obs.metrics` — process-local counters / gauges /
  histograms (redesign count & latency, candidate throughput, slot
  versions, recompiles, predicted-vs-measured drift, h→d bytes).
* :mod:`~repro.obs.events`  — the JSONL flight recorder: every
  controller decision, epoch transition, membership change and
  hot-swap as one schema-versioned record; replayable as a measured
  event stream (``train.py --trace-out``).
* :mod:`~repro.obs.log`     — structured progress logging (stderr human
  format + optional JSONL) replacing ad-hoc ``print``.

:mod:`~repro.obs.report` renders a trace into a timeline and a
bottleneck-attribution table and diffs two traces
(``scripts/obs_report.py``).  The package is stdlib-only and imports
nothing from ``repro`` — so any module (including ``repro.core``) can
instrument itself without dependency cycles.  The ``obs-purity`` lint
rule keeps that instrumentation out of jax-traced bodies.
"""

from .spans import (
    Span,
    SpanRecord,
    disable,
    enable,
    enabled,
    pop_finished,
    span,
    span_fn,
    summary,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    REGISTRY,
    counter,
    gauge,
    histogram,
)
from .events import (
    FlightRecorder,
    SCHEMA,
    TRACE_SCHEMA_VERSION,
    read_trace,
    run_metadata,
    validate_record,
    validate_trace,
)
from .log import StructuredLogger, get_logger, set_global_jsonl

__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "SCHEMA",
    "Span",
    "SpanRecord",
    "StructuredLogger",
    "TRACE_SCHEMA_VERSION",
    "counter",
    "disable",
    "enable",
    "enabled",
    "gauge",
    "get_logger",
    "histogram",
    "pop_finished",
    "read_trace",
    "run_metadata",
    "set_global_jsonl",
    "span",
    "span_fn",
    "summary",
    "validate_record",
    "validate_trace",
]
