"""Process-local metrics registry: counters, gauges, histograms.

One flat namespace of named metrics, read out as a JSON-able snapshot
(embedded in the flight recorder's ``metrics``/``run_end`` records and
asserted in tests).  This is deliberately *not* a Prometheus client:
the controller/training loop is single-process, the consumers are the
trace report and the test suite, and the whole point is zero external
dependencies.

Metric kinds:

* :class:`Counter`   — monotonically increasing (redesign count,
  recompile count, host→device bytes, rounds observed);
* :class:`Gauge`     — last-write-wins scalar (slot versions, current
  predicted τ, predicted-vs-measured drift);
* :class:`Histogram` — summary statistics over observed values
  (redesign latency, per-round duration, candidate throughput), with
  count/sum/min/max plus percentile estimates over a bounded ring of
  the most recent observations.

All update paths are O(1), allocation-free after the first observation,
and guarded by one registry lock only at metric *creation*; updates
rely on CPython attribute-assignment atomicity, which is sufficient for
the single-writer control loop (and harmless for concurrent readers —
a snapshot may be one observation stale, never torn across a metric).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Union

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
]


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> float:
        return self.value


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Streaming summary + bounded reservoir of recent observations.

    Percentiles are computed over the last ``sample_max`` observations
    (a sliding window, not a uniform reservoir) — the control loop cares
    about *recent* round-time behaviour, and the exact stream is in the
    flight recorder anyway."""

    __slots__ = ("name", "count", "sum", "min", "max", "_sample",
                 "_sample_max", "_i")

    def __init__(self, name: str, sample_max: int = 512):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._sample: List[float] = []
        self._sample_max = sample_max
        self._i = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._sample) < self._sample_max:
            self._sample.append(v)
        else:  # overwrite oldest: ring over the most recent window
            self._sample[self._i] = v
            self._i = (self._i + 1) % self._sample_max

    def quantile(self, q: float) -> float:
        if not self._sample:
            return float("nan")
        s = sorted(self._sample)
        k = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
        return s[k]

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
        }


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named metrics with get-or-create semantics.

    ``counter("a.b")`` returns the same object on every call; asking
    for an existing name with a different kind raises — a metric's
    meaning must not silently change across call sites."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, "
                    f"requested as {cls.__name__}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, "
                    f"requested as {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)  # type: ignore[return-value]

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)  # type: ignore[return-value]

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)  # type: ignore[return-value]

    def snapshot(self) -> Dict[str, object]:
        """JSON-able ``{name: value-or-summary}`` of every metric."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        """Drop every registered metric (tests; run boundaries)."""
        with self._lock:
            self._metrics.clear()


#: The process-local default registry used by all instrumentation.
REGISTRY = MetricsRegistry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset
