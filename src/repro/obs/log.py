"""Structured logging: human-readable stderr lines + optional JSONL.

Replaces ad-hoc ``print`` progress output in the launchers and the
controller.  Each call names an *event* and attaches key=value fields;
the human rendering is one aligned line on stderr, the structured
rendering (when a JSONL path is configured) is one JSON object per
line sharing the field names — grep-able and machine-joinable with the
flight-recorder trace.

Deliberately *not* stdlib ``logging``: no handler graphs, no global
config mutation from library code, no formatter classes.  A logger is
a named object with a level, a stream and an optional JSONL sink.

The acceptance-test contract: the training launcher's load-bearing
stdout lines (step loss, re-design, membership rebuild, dynamic
summary) stay as plain ``print`` to stdout — subprocess tests grep
them — while secondary progress (notes, checkpoints, masked-consensus
events) flows through here to stderr.
"""

from __future__ import annotations

import json
import sys
import threading
import time
from typing import Any, Dict, IO, Optional

__all__ = ["StructuredLogger", "get_logger", "set_global_jsonl"]

_LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}


class StructuredLogger:
    """One named logger.  See module docstring."""

    def __init__(self, name: str, *, level: str = "info",
                 stream: Optional[IO[str]] = None,
                 jsonl_path: Optional[str] = None):
        self.name = name
        self.level = level
        self._stream = stream
        self._jsonl_path = jsonl_path
        self._jsonl_fh: Optional[IO[str]] = None
        self._lock = threading.Lock()

    # -- config --------------------------------------------------------

    @property
    def stream(self) -> IO[str]:
        return self._stream if self._stream is not None else sys.stderr

    def set_jsonl(self, path: Optional[str]) -> None:
        """Attach (or detach, with None) a JSONL sink."""
        with self._lock:
            if self._jsonl_fh is not None:
                self._jsonl_fh.close()
                self._jsonl_fh = None
            self._jsonl_path = path

    # -- emission ------------------------------------------------------

    def log(self, level: str, event: str, msg: str = "",
            **fields: Any) -> None:
        if _LEVELS.get(level, 20) < _LEVELS.get(self.level, 20):
            return
        parts = [f"[{self.name}] {event}"]
        if msg:
            parts.append(msg)
        parts.extend(f"{k}={_fmt(v)}" for k, v in fields.items())
        line = " ".join(parts)
        with self._lock:
            print(line, file=self.stream, flush=True)
            if self._jsonl_path is not None:
                if self._jsonl_fh is None:
                    self._jsonl_fh = open(self._jsonl_path, "a",
                                          encoding="utf-8")
                rec = {"t_unix": time.time(), "logger": self.name,
                       "level": level, "event": event}
                if msg:
                    rec["msg"] = msg
                rec.update(fields)
                self._jsonl_fh.write(
                    json.dumps(rec, default=_default) + "\n")
                self._jsonl_fh.flush()

    def debug(self, event: str, msg: str = "", **fields: Any) -> None:
        self.log("debug", event, msg, **fields)

    def info(self, event: str, msg: str = "", **fields: Any) -> None:
        self.log("info", event, msg, **fields)

    def warn(self, event: str, msg: str = "", **fields: Any) -> None:
        self.log("warn", event, msg, **fields)

    def error(self, event: str, msg: str = "", **fields: Any) -> None:
        self.log("error", event, msg, **fields)


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _default(o: Any) -> Any:
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


_REGISTRY: Dict[str, StructuredLogger] = {}
_REGISTRY_LOCK = threading.Lock()


def get_logger(name: str, **kwargs: Any) -> StructuredLogger:
    """Get-or-create the named logger (kwargs apply on first creation)."""
    lg = _REGISTRY.get(name)
    if lg is None:
        with _REGISTRY_LOCK:
            lg = _REGISTRY.get(name)
            if lg is None:
                lg = _REGISTRY[name] = StructuredLogger(name, **kwargs)
    return lg


def set_global_jsonl(path: Optional[str]) -> None:
    """Route every existing logger's structured stream to ``path``."""
    with _REGISTRY_LOCK:
        for lg in _REGISTRY.values():
            lg.set_jsonl(path)
