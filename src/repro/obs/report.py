"""Render a flight-recorder trace: timeline, bottlenecks, diffs.

Consumes the JSONL traces written by :class:`repro.obs.events.FlightRecorder`
(``train.py --trace-out``) and answers the questions the paper makes
answerable for the *network* — where does round time go, what is the
critical circuit — for the *run itself*:

* :func:`render_timeline`    — epochs × redesigns × round-time profile:
  when the network changed, when the controller noticed, what it chose,
  and how the realized round time moved between actuations;
* :func:`render_bottlenecks` — bottleneck attribution: the critical
  circuits the controller blamed, by silo name, with the τ they priced;
* :func:`diff_traces`        — two runs side by side (record counts,
  redesign behaviour, round-time deltas) — the regression-hunting view;
* :func:`check_trace`        — schema validation (the CI gate behind
  ``scripts/obs_report.py --check``).

Everything returns plain strings; the CLI just prints them.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .events import read_trace, validate_trace

__all__ = [
    "Trace",
    "check_trace",
    "diff_traces",
    "load_trace",
    "render_bottlenecks",
    "render_report",
    "render_timeline",
]


class Trace:
    """A parsed trace with a by-kind index and the run metadata."""

    def __init__(self, records: List[Dict[str, Any]], path: str = ""):
        self.path = path
        self.records = records
        self.by_kind: Dict[str, List[Dict[str, Any]]] = {}
        for rec in records:
            self.by_kind.setdefault(rec.get("kind", "?"), []).append(rec)
        starts = self.by_kind.get("run_start", [])
        self.meta: Dict[str, Any] = starts[0].get("meta", {}) if starts else {}

    @property
    def silo_names(self) -> Optional[List[str]]:
        names = self.meta.get("silo_names")
        return list(names) if names else None

    def kind(self, kind: str) -> List[Dict[str, Any]]:
        return self.by_kind.get(kind, [])


def load_trace(path: str) -> Trace:
    return Trace(read_trace(path), path=path)


def check_trace(path: str) -> Tuple[bool, List[str]]:
    """(ok, human lines).  ok is False on any schema problem."""
    records, problems = validate_trace(path)
    lines = [f"{path}: {len(records)} record(s), "
             f"{len(problems)} problem(s)"]
    lines.extend(f"  {p}" for p in problems)
    if not problems:
        kinds = {}
        for rec in records:
            kinds[rec["kind"]] = kinds.get(rec["kind"], 0) + 1
        lines.append("  " + ", ".join(f"{k}={n}"
                                      for k, n in sorted(kinds.items())))
    return not problems, lines


# ---------------------------------------------------------------------------
# Rendering helpers
# ---------------------------------------------------------------------------

def _name_of(trace: Trace, label: Any) -> str:
    names = trace.silo_names
    try:
        i = int(label)
    except (TypeError, ValueError):
        return str(label)
    if names and 0 <= i < len(names):
        return names[i]
    return str(label)


def _circuit_str(trace: Trace, rec: Dict[str, Any]) -> str:
    names = rec.get("bottleneck_names") or [
        _name_of(trace, s) for s in rec.get("bottleneck", ())]
    return "-".join(str(n) for n in names) if names else "(none)"


def _fmt_ms(v: Any) -> str:
    return f"{v:8.1f}" if isinstance(v, (int, float)) else f"{'—':>8s}"


def _table(headers: Sequence[str], rows: Sequence[Sequence[str]]) -> str:
    widths = [len(h) for h in headers]
    for row in rows:
        for i, cell in enumerate(row):
            widths[i] = max(widths[i], len(cell))
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)).rstrip()]
    out.append("  ".join("-" * w for w in widths))
    for row in rows:
        out.append("  ".join(c.ljust(w)
                             for c, w in zip(row, widths)).rstrip())
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Timeline
# ---------------------------------------------------------------------------

def render_timeline(trace: Trace) -> str:
    """Epochs × redesigns × round-time profile, as three stacked tables."""
    meta = trace.meta
    lines: List[str] = []
    lines.append(
        f"run: rev={meta.get('git_rev', '?')} "
        f"jax={meta.get('jax_version', '?')} "
        f"device={meta.get('device_kind', '?')} "
        f"schema=v{meta.get('schema_version', '?')}")
    argv = meta.get("argv")
    if argv:
        lines.append("cmd: " + " ".join(str(a) for a in argv))

    epochs = trace.kind("epoch")
    if epochs:
        lines.append("")
        lines.append("network epochs:")
        rows = []
        for e in epochs:
            active = e.get("active", [])
            rows.append([
                str(e.get("index", "?")),
                f"{e.get('t_start_ms', 0) / 1e3:9.1f}",
                str(len(active)),
                ",".join(_name_of(trace, s) for s in active[:8])
                + ("…" if len(active) > 8 else ""),
            ])
        lines.append(_table(["epoch", "t_start_s", "n_act", "active"], rows))

    redesigns = trace.kind("redesign")
    if redesigns:
        lines.append("")
        lines.append("controller actuations:")
        rows = []
        for r in redesigns:
            drift = r.get("drift")
            rows.append([
                str(r.get("round_idx", "?")),
                str(r.get("winner", "?")),
                str(r.get("name", "?")),
                _fmt_ms(r.get("measured_ms")).strip(),
                _fmt_ms(r.get("predicted_tau_ms")).strip(),
                f"{drift:.3f}" if isinstance(drift, (int, float)) else "—",
                str(r.get("n_candidates", "?")),
                f"{1e3 * r.get('elapsed_s', 0):.0f}",
                "yes" if r.get("membership") else "",
            ])
        lines.append(_table(
            ["round", "winner", "plan", "meas_ms", "pred_ms", "drift",
             "cands", "design_ms", "churn"], rows))

    rounds = trace.kind("round")
    if rounds:
        lines.append("")
        lines.append("round-time profile (between actuations):")
        bounds = sorted(r.get("round_idx", 0) for r in redesigns)
        segments: Dict[int, List[Dict[str, Any]]] = {}
        for rec in rounds:
            step = rec.get("step", 0)
            seg = sum(1 for b in bounds if step >= b)
            segments.setdefault(seg, []).append(rec)
        rows = []
        for seg in sorted(segments):
            recs = segments[seg]
            durs = [r["duration_ms"] for r in recs
                    if isinstance(r.get("duration_ms"), (int, float))]
            drifts = [r["drift"] for r in recs
                      if isinstance(r.get("drift"), (int, float))]
            rows.append([
                f"{seg}",
                f"{recs[0].get('step', '?')}..{recs[-1].get('step', '?')}",
                str(len(recs)),
                f"{sum(durs) / len(durs):.1f}" if durs else "—",
                f"{max(durs):.1f}" if durs else "—",
                f"{max(drifts):.3f}" if drifts else "—",
            ])
        lines.append(_table(
            ["segment", "steps", "samples", "mean_ms", "max_ms",
             "max_drift"], rows))

    ends = trace.kind("run_end")
    if ends:
        spans = ends[-1].get("spans") or {}
        if spans:
            lines.append("")
            lines.append("span summary (host wall clock):")
            rows = [[name, str(s.get("count", 0)),
                     f"{1e3 * s.get('total_s', 0):.1f}",
                     f"{1e3 * s.get('mean_s', 0):.2f}",
                     f"{1e3 * s.get('max_s', 0):.2f}"]
                    for name, s in sorted(spans.items())]
            lines.append(_table(
                ["span", "count", "total_ms", "mean_ms", "max_ms"], rows))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Bottleneck attribution
# ---------------------------------------------------------------------------

def render_bottlenecks(trace: Trace) -> str:
    """Critical circuits the controller blamed, aggregated by circuit."""
    redesigns = trace.kind("redesign")
    if not redesigns:
        return "bottleneck attribution: no redesign records"
    agg: Dict[str, Dict[str, Any]] = {}
    for r in redesigns:
        circ = _circuit_str(trace, r)
        slot = agg.setdefault(circ, {"count": 0, "taus": [],
                                     "rounds": [], "plans": set()})
        slot["count"] += 1
        tau = r.get("predicted_tau_ms")
        if isinstance(tau, (int, float)):
            slot["taus"].append(tau)
        slot["rounds"].append(r.get("round_idx"))
        slot["plans"].add(str(r.get("name")))
    rows = []
    for circ, s in sorted(agg.items(), key=lambda kv: -kv[1]["count"]):
        taus = s["taus"]
        rows.append([
            circ,
            str(s["count"]),
            f"{min(taus):.1f}" if taus else "—",
            ",".join(str(r) for r in s["rounds"]),
            ",".join(sorted(s["plans"])),
        ])
    return ("bottleneck attribution (critical circuits of chosen "
            "plans):\n" + _table(
                ["circuit", "hits", "tau_ms", "rounds", "plans"], rows))


def render_report(trace: Trace) -> str:
    return render_timeline(trace) + "\n\n" + render_bottlenecks(trace)


# ---------------------------------------------------------------------------
# Diff
# ---------------------------------------------------------------------------

def _round_stats(trace: Trace) -> Tuple[int, float]:
    rounds = trace.kind("round")
    durs = [r["duration_ms"] for r in rounds
            if isinstance(r.get("duration_ms"), (int, float))]
    return len(durs), (sum(durs) / len(durs) if durs else float("nan"))


def diff_traces(a: Trace, b: Trace) -> str:
    """Two runs side by side: counts per record kind, redesign
    behaviour, mean round time, final predicted τ."""
    lines = [f"diff: A={a.path or '<a>'}  B={b.path or '<b>'}"]
    rows = []
    for kind in sorted(set(a.by_kind) | set(b.by_kind)):
        na, nb = len(a.kind(kind)), len(b.kind(kind))
        rows.append([kind, str(na), str(nb),
                     "" if na == nb else f"{nb - na:+d}"])
    lines.append(_table(["kind", "A", "B", "delta"], rows))

    def final_tau(t: Trace) -> Optional[float]:
        rd = t.kind("redesign")
        if rd and isinstance(rd[-1].get("predicted_tau_ms"), (int, float)):
            return rd[-1]["predicted_tau_ms"]
        return None

    na, ma = _round_stats(a)
    nb, mb = _round_stats(b)
    rows = []
    if na and nb:
        rows.append(["mean round ms", f"{ma:.1f}", f"{mb:.1f}",
                     f"{mb - ma:+.1f}"])
    ta, tb = final_tau(a), final_tau(b)
    if ta is not None and tb is not None:
        rows.append(["final predicted tau ms", f"{ta:.1f}", f"{tb:.1f}",
                     f"{tb - ta:+.1f}"])
    ca = [_circuit_str(a, r) for r in a.kind("redesign")]
    cb = [_circuit_str(b, r) for r in b.kind("redesign")]
    if ca or cb:
        rows.append(["bottleneck circuits", ";".join(ca) or "—",
                     ";".join(cb) or "—",
                     "same" if ca == cb else "DIFFER"])
    if rows:
        lines.append("")
        lines.append(_table(["metric", "A", "B", "delta"], rows))
    if a.by_kind == b.by_kind and ca == cb:
        lines.append("")
        lines.append("traces are structurally identical")
    return "\n".join(lines)
