"""Pallas TPU kernel for the max-plus segment reduction — the design
loop's hot spot.

One Karp/timing-recursion step over an edge batch is

    nxt[b, v] = max over arcs (u -> v) in graph b of cur[b, u] + w[b, e]

i.e. a gather (``cur[b, src]``), an add, and a *per-destination segment
max*.  ``jax.ops.segment_max`` lowers that reduction to a scatter-max,
which XLA:CPU executes as a serial loop over E and XLA:TPU does not
vectorise either — BENCH_sparse_search.json shows the jitted sparse
path losing to host numpy by ~6x at N=1024 purely on this op.

The kernel here re-states the reduction as a dense one-hot max so it
runs on the TPU VPU at full lane width: each grid step loads a tile of
``block`` edge values + their int32 segment ids into VMEM, compares the
ids against a ``[block, n_block]`` iota of segment indices, and folds a
masked max over the tile into the output block.  Work is O(E * S)
instead of O(E), but every op is a dense vector op — for the segment
counts the design loop cares about (S = N <= a few thousand) that is a
large net win over serial scatter, and VMEM stays bounded at
``block * n_block`` elements regardless of problem size.

Numerics: ``max`` is associative, commutative, and exact in floating
point, and empty segments come out as the same ``-inf`` identity that
``jax.ops.segment_max`` uses for floats — the kernel is **bit-identical**
to ``jax.ops.segment_max`` for any float input without NaNs (CI smoke
asserts this in interpret mode; tier-1 tests assert it too).

Dispatch: the kernel only *wins* when compiled via Mosaic, so
:func:`select_segment_max_impl` returns ``"pallas"`` strictly on TPU
backends.  On CPU it picks the degree-padded dense-gather formulation
(``"padded"``, implemented in ``core.maxplus_sparse``) when the caller
can bound the in-degree statically, else plain ``"xla"`` — the losing
interpret-mode path is never auto-selected.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..analysis.contracts import contract
from ..core.maxplus_vec import NEG_INF
from ._interpret import interpret_default, resolve_interpret

__all__ = [
    "segment_max_pallas",
    "edge_segment_max_pallas",
    "segment_max",
    "select_segment_max_impl",
]


def _segmax_kernel(v_ref, i_ref, o_ref, *, n_block: int):
    # v_ref: [1, block] values; i_ref: [1, block] int32 segment ids;
    # o_ref: [1, n_block] running max for segment tile program_id(1).
    # Grid is (B, S_tiles, E_tiles) with the edge axis innermost, so the
    # output block stays resident in VMEM while edge tiles stream by.
    e_pid = pl.program_id(2)

    @pl.when(e_pid == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, NEG_INF, o_ref.dtype)

    vals = v_ref[0, :]
    ids = i_ref[0, :]
    seg0 = pl.program_id(1) * n_block
    seg = jax.lax.broadcasted_iota(
        jnp.int32, (vals.shape[0], n_block), 1) + seg0
    hit = ids[:, None] == seg
    neg = jnp.full((), NEG_INF, vals.dtype)
    cand = jnp.max(jnp.where(hit, vals[:, None], neg), axis=0)
    o_ref[0, :] = jnp.maximum(o_ref[0, :], cand)


@contract("[B,E]", "[B,E]", "S", ret="[B,S]")
def edge_segment_max_pallas(
    vals: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    *,
    block: int = 512,
    n_block: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Row-wise segment max over an edge batch: ``out[b, s] = max vals[b, e]
    over e with seg_ids[b, e] == s`` (``-inf`` where the segment is empty).

    Bit-identical to ``jax.vmap`` of ``jax.ops.segment_max`` for float
    inputs.  ``num_segments`` must be static; ids outside
    ``[0, num_segments)`` are dropped, matching ``segment_max``'s
    out-of-bounds scatter semantics.
    """
    interpret = resolve_interpret(interpret)
    vals = jnp.asarray(vals)
    if not jnp.issubdtype(vals.dtype, jnp.floating):
        raise TypeError(
            f"edge_segment_max_pallas needs a float dtype (the -inf "
            f"identity is float-only); got {vals.dtype}")
    seg_ids = jnp.asarray(seg_ids, dtype=jnp.int32)
    B, E = vals.shape
    S = int(num_segments)
    block = min(block, max(E, 1))
    n_block = min(n_block, max(S, 1))
    e_pad = (-E) % block
    if e_pad:
        # Padding ids are -1: they match no segment tile and fold away.
        vals = jnp.pad(vals, ((0, 0), (0, e_pad)),
                       constant_values=NEG_INF)
        seg_ids = jnp.pad(seg_ids, ((0, 0), (0, e_pad)),
                          constant_values=-1)
    s_pad = (-S) % n_block
    Sp = S + s_pad
    grid = (B, Sp // n_block, (E + e_pad) // block)
    out = pl.pallas_call(
        functools.partial(_segmax_kernel, n_block=n_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block), lambda b, j, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, n_block), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, Sp), vals.dtype),
        interpret=interpret,
    )(vals, seg_ids)
    return out[:, :S]


@contract("[M]", "[M]", "S", ret="[S]")
def segment_max_pallas(
    vals: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    *,
    block: int = 512,
    n_block: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flat drop-in for ``jax.ops.segment_max`` (float input, static
    ``num_segments``), bit-identical on any NaN-free float input."""
    out = edge_segment_max_pallas(
        vals[None, :], seg_ids[None, :], num_segments,
        block=block, n_block=n_block, interpret=interpret)
    return out[0]


@contract()
def select_segment_max_impl(kernel: str = "auto", *,
                            padded: bool = False) -> str:
    """Resolve a segment-max implementation name for the hot recursions.

    ======== ==========================================================
    auto     ``"pallas"`` on compiled-TPU backends; else ``"padded"``
             when the caller supplies a static in-degree bound, else
             ``"xla"``.  Interpret-mode Pallas is never auto-selected —
             it cannot beat either alternative.
    xla      ``jax.ops.segment_max`` (scatter-max lowering).
    padded   degree-padded ``[B, N, D]`` gather + dense max (CPU
             winner; needs ``max_in_degree``).
    pallas   the kernel above (forced; interpret fallback off-TPU).
    ======== ==========================================================
    """
    if kernel != "auto":
        if kernel not in ("xla", "padded", "pallas"):
            raise ValueError(f"unknown segment-max impl {kernel!r}")
        return kernel
    if not interpret_default():
        return "pallas"
    return "padded" if padded else "xla"


@contract("[M]", "[M]", "S", ret="[S]")
def segment_max(
    vals: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    *,
    impl: str = "xla",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flat segment max routed through the chosen implementation
    (``"xla"`` or ``"pallas"``; the ``"padded"`` layout lives in
    ``core.maxplus_sparse`` because it needs the edge structure)."""
    if impl == "pallas":
        return segment_max_pallas(
            vals, seg_ids, num_segments, interpret=interpret)
    if impl == "xla":
        return jax.ops.segment_max(
            vals, seg_ids, num_segments=int(num_segments))
    raise ValueError(
        f"segment_max impl {impl!r} not routable here (padded needs "
        f"edge structure; use batched_cycle_time_sparse_jax)")
