"""Pure-jnp oracles for every Pallas kernel (the ground truth used by the
allclose test sweeps)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, S, K, G, hd]
    k: jax.Array,  # [B, T, K, hd]
    v: jax.Array,  # [B, T, K, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Naive O(S*T) softmax attention with causal/window masking."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    s = jnp.einsum("bskgd,btkd->bskgt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = jnp.arange(S)
    kv_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gossip_mix_ref(neighbor_blocks: jax.Array, weights: jax.Array) -> jax.Array:
    """out = sum_k weights[k] * neighbor_blocks[k]."""
    acc = jnp.einsum("k,kn->n", weights.astype(jnp.float32),
                     neighbor_blocks.astype(jnp.float32))
    return acc.astype(neighbor_blocks.dtype)


def mlstm_scan_ref(q, k, v, log_i, log_f) -> jax.Array:
    """Per-token sequential recurrence (the mathematical definition):

        S_t = f_t S_{t-1} + i_t k_t v_t^T ;  h_t = q_t . S_t
    """
    B, S, H, hd = q.shape

    def step(state, t_in):
        qt, kt, vt, it, ft = t_in  # [B,H,hd] x3, [B,H] x2
        state = ft[..., None, None] * state + it[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kt, vt
        )
        h = jnp.einsum("bhd,bhde->bhe", qt, state)
        return state, h

    init = jnp.zeros((B, H, hd, hd), jnp.float32)
    seq = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        jnp.exp(log_i.transpose(1, 0, 2).astype(jnp.float32)),
        jnp.exp(log_f.transpose(1, 0, 2).astype(jnp.float32)),
    )
    _, hs = jax.lax.scan(step, init, seq)
    return hs.transpose(1, 0, 2, 3).astype(q.dtype)
