"""Pallas TPU kernel for the DPASGD gossip mix — the paper's technique
hot-spot.

After the topology-scheduled ``ppermute`` transfers land, every silo
holds a stack of K neighbour parameter blocks plus its own, and must
compute the consensus combination

    out = sum_k  lambda_k * neighbors[k]        (w_i <- sum_j A_ij w_j)

This is a purely memory-bound fused multiply-add over K streams.  The
kernel tiles the flattened parameter vector into VMEM chunks (lane-dim
multiple of 128) and performs the K-way weighted accumulation in fp32
without K round-trips to HBM — one read per neighbour block, one write.

Roofline: bytes = (K+1) * chunk * dtype_size, FLOPs = 2K * chunk
=> arithmetic intensity ~ 2/dtype_size FLOP/byte: firmly memory-bound,
which is why fusing the K streams (vs K separate axpy's that each re-read
the accumulator) cuts HBM traffic by ~2x for K>=2.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._interpret import resolve_interpret


def _mix_kernel(w_ref, lam_ref, o_ref, *, n_neighbors: int):
    # w_ref: [K, block]; lam_ref: [K] (SMEM); o_ref: [block]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for k in range(n_neighbors):
        acc = acc + lam_ref[k] * w_ref[k, :].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_mix_pallas(
    neighbor_blocks: jax.Array,  # [K, N] — own params at k=0 by convention
    weights: jax.Array,          # [K] fp32 mixing coefficients
    *,
    block: int = 65536,
    interpret: Optional[bool] = None,  # None = compiled on TPU, interpret on CPU
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    K, N = neighbor_blocks.shape
    assert weights.shape == (K,)
    pad = (-N) % block
    if pad:
        neighbor_blocks = jnp.pad(neighbor_blocks, ((0, 0), (0, pad)))
    Np = N + pad
    grid = (Np // block,)
    out = pl.pallas_call(
        functools.partial(_mix_kernel, n_neighbors=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), neighbor_blocks.dtype),
        interpret=interpret,
    )(neighbor_blocks, weights.astype(jnp.float32))
    return out[:N]
