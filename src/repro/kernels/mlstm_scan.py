"""Pallas TPU kernel for the chunkwise-parallel mLSTM / gated linear
attention scan (xLSTM, Hymba recurrent hot-spot).

Grid = (batch, head); each program walks the sequence chunk by chunk,
holding the [hd, hd] recurrent state in VMEM scratch.  Per chunk it does
three MXU matmuls (intra-chunk attention, inter-chunk query*state, state
update) on (chunk, hd) tiles — the matmul-form recurrence that makes
linear-attention states TPU-friendly (vs. a per-token scan which would
be VPU-bound and sequence-length latency-bound).

Contract identical to ``repro.models.ssm.mlstm_chunked_ref``:

    S_t = f_t * S_{t-1} + i_t * k_t v_t^T ;   h_t = q_t . S_t
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref, state_ref,
                  *, chunk: int, seq_len: int, head_dim: int):
    n_chunks = seq_len // chunk

    @pl.when(pl.program_id(2) == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    ci = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)      # [chunk, hd]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    li = li_ref[...].astype(jnp.float32)    # [chunk]
    lf = lf_ref[...].astype(jnp.float32)
    g = jnp.cumsum(lf)                      # cumulative log-forget in chunk
    g_total = g[-1]

    state = state_ref[...].astype(jnp.float32)  # [hd, hd]
    # inter-chunk: h_inter = (q * exp(g)) @ S
    h_inter = jax.lax.dot(q * jnp.exp(g)[:, None], state,
                          preferred_element_type=jnp.float32)
    # intra-chunk: att[c,t] = (q k^T)[c,t] * exp(g[c]-g[t]+li[t]) * causal
    att = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    rel = g[:, None] - g[None, :] + li[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(rel), 0.0)
    h_intra = jax.lax.dot(att * decay, v, preferred_element_type=jnp.float32)
    o_ref[...] = (h_inter + h_intra).astype(o_ref.dtype)
    # state update: S' = exp(g_total) S + (k * exp(g_total - g + li))^T @ v
    k_dec = k * jnp.exp(g_total - g + li)[:, None]
    state_ref[...] = jnp.exp(g_total) * state + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def mlstm_scan_pallas(
    q: jax.Array,       # [B, S, H, hd]
    k: jax.Array,
    v: jax.Array,
    log_i: jax.Array,   # [B, S, H]
    log_f: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, seq_len=S, head_dim=hd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((None, chunk, None), lambda b, h, c: (b, c, h)),
        ],
        out_specs=pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[pltpu_vmem((hd, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_i, log_f)


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation — works both on TPU and in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except ImportError:  # pragma: no cover
        return pl.MemoryRef(shape, dtype)
