"""Shared interpret-mode resolution for the Pallas kernels.

``interpret=None`` everywhere means *auto*: lower via Mosaic when the
default backend is a TPU, fall back to the Pallas interpreter otherwise
(this CPU container).  ``REPRO_PALLAS_COMPILE=1`` forces compilation
regardless of backend (useful under ``jax.experimental`` CPU lowering or
when the backend probe is wrong).
"""

from __future__ import annotations

import os
from typing import Optional

import jax


def interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Explicit True/False wins; None auto-detects."""
    if interpret is None:
        return interpret_default()
    return bool(interpret)
