"""Pallas TPU flash attention (causal + sliding window, GQA).

TPU mapping: grid = (batch, kv_head, q_blocks); each program streams KV
blocks of shape (block_kv, head_dim) through VMEM while keeping a
(block_q, head_dim) query tile and fp32 accumulators resident.  Block
shapes are multiples of 128 to align with the MXU systolic array; the
online-softmax recurrence avoids materializing the S^2 score matrix in
HBM (memory term: O(S * block_kv) per core instead of O(S^2)).

Validated in interpret mode against ``repro.kernels.ref.attention_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [block_q, G, hd]
    k_ref,  # [T, hd]      (full KV stripe for this (b, kv_head))
    v_ref,  # [T, hd]
    o_ref,  # [block_q, G, hd]
    *,
    block_q: int,
    block_kv: int,
    seq_len_kv: int,
    causal: bool,
    window: Optional[int],
    q_offset_blocks: bool,
):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)  # [bq, G, hd]
    G, hd = q.shape[1], q.shape[2]
    scale = hd ** -0.5
    q = q * scale
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kv = seq_len_kv // block_kv

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(ki * block_kv, block_kv), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * block_kv, block_kv), :].astype(jnp.float32)
        kv_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
        s = jax.lax.dot_general(
            q.reshape(block_q * G, hd), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(block_q, G, block_kv)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jax.lax.dot_general(
            p.reshape(block_q * G, block_kv), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(block_q, G, hd)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, G), jnp.float32)
    a0 = jnp.zeros((block_q, G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kv, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, S, K, G, hd]
    k: jax.Array,  # [B, T, K, hd]
    v: jax.Array,  # [B, T, K, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    assert S % block_q == 0, (S, block_q)
    assert T % block_kv == 0, (T, block_kv)
    grid = (B, K, S // block_q)

    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_kv=block_kv,
        seq_len_kv=T,
        causal=causal,
        window=window,
        q_offset_blocks=False,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, G, hd), lambda b, h, i: (b, i, h, 0, 0)),
            pl.BlockSpec((None, T, None, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((None, T, None, hd), lambda b, h, i: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, G, hd), lambda b, h, i: (b, i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, K, G, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
