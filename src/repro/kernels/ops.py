"""Jitted public wrappers around the Pallas kernels.

Interpret mode is auto-detected: compiled via Mosaic on TPU, Pallas
interpreter on CPU (this container).  ``REPRO_PALLAS_COMPILE=1`` forces
compilation; ``gossip_mix`` also takes an explicit ``interpret`` flag.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ._interpret import interpret_default as _interpret_default, resolve_interpret
from .flash_attention import flash_attention_pallas
from .gossip_mix import gossip_mix_pallas
from .mlstm_scan import mlstm_scan_pallas
from .segment_max import edge_segment_max_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(
    q: jax.Array,  # [B, S, K, G, hd]
    k: jax.Array,
    v: jax.Array,
    q_pos=None,   # accepted for API parity with the chunked reference
    kv_pos=None,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=_interpret_default(),
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gossip_mix(neighbor_blocks: jax.Array, weights: jax.Array, *,
               block: int = 65536, interpret: Optional[bool] = None):
    return gossip_mix_pallas(neighbor_blocks, weights, block=block,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, log_i, log_f, *, chunk: int = 128):
    return mlstm_scan_pallas(q, k, v, log_i, log_f, chunk=chunk,
                             interpret=_interpret_default())


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block", "n_block", "interpret"))
def edge_segment_max(vals: jax.Array, seg_ids: jax.Array, *,
                     num_segments: int, block: int = 512,
                     n_block: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    return edge_segment_max_pallas(
        vals, seg_ids, num_segments, block=block, n_block=n_block,
        interpret=resolve_interpret(interpret))
