"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels run in interpret mode; on TPU set
``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to lower via Mosaic.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .gossip_mix import gossip_mix_pallas
from .mlstm_scan import mlstm_scan_pallas


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(
    q: jax.Array,  # [B, S, K, G, hd]
    k: jax.Array,
    v: jax.Array,
    q_pos=None,   # accepted for API parity with the chunked reference
    kv_pos=None,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=_interpret_default(),
    )


@functools.partial(jax.jit, static_argnames=("block",))
def gossip_mix(neighbor_blocks: jax.Array, weights: jax.Array, *, block: int = 65536):
    return gossip_mix_pallas(neighbor_blocks, weights, block=block,
                             interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, log_i, log_f, *, chunk: int = 128):
    return mlstm_scan_pallas(q, k, v, log_i, log_f, chunk=chunk,
                             interpret=_interpret_default())
