"""Parameter specification system: one source of truth for shapes,
logical sharding axes, and initialization.

Every parameter leaf is described by a ``ParamSpec(shape, axes, scale)``
where ``axes`` are *logical* axis names mapped to mesh axes by a
``ShardingRules`` table.  ``init_params`` materializes arrays;
``param_pspecs`` produces the matching ``PartitionSpec`` pytree.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim
    scale: float = 0.02              # stddev of truncated-normal init; 0 -> zeros, 1.0 w/ "ones" -> ones
    init: str = "normal"             # "normal" | "zeros" | "ones"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping."""

    rules: Mapping[str, Optional[Any]]
    batch_axes: Tuple[Any, ...] = ("data",)  # axes sharding the batch dim
    silo_axis: Optional[str] = None          # mesh axis carrying silo replicas

    def mesh_axis(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical, None)


# Single-pod FSDP x TP: params 2-D sharded, batch over "data".
FSDP_TP = ShardingRules(
    rules={
        "embed": "data",     # FSDP shard dim
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ffn": None,
        "inner": "model",
        "state": None,
        "rank": None,
        "conv": None,
        "enc_seq": None,
    },
    batch_axes=("data",),
    silo_axis=None,
)

# Multi-pod DPASGD: one silo per pod; params replicated per pod slice
# (leading silo dim handled by the fed layer), FSDP over "data" inside.
FSDP_TP_PODS = ShardingRules(
    rules=dict(FSDP_TP.rules),
    batch_axes=("pod", "data"),
    silo_axis="pod",
)

# Fine-grained federation: every data-axis index is a silo (16 per pod);
# inside a silo only TP is available, so no FSDP dim.
SILO_TP = ShardingRules(
    rules={**dict(FSDP_TP.rules), "embed": None},
    batch_axes=("data",),
    silo_axis="data",
)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_spec)


def init_params(key: jax.Array, spec_tree, dtype=jnp.float32):
    """Materialize a ParamSpec pytree into arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def make(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        return (jax.random.truncated_normal(k, -2.0, 2.0, spec.shape, jnp.float32)
                * spec.scale).astype(dtype)

    return jax.tree_util.tree_unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (for .lower() without allocation)."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree)


def param_pspecs(spec_tree, rules: ShardingRules, *, silo_leading: bool = False):
    """PartitionSpec pytree matching the params.

    ``silo_leading``: params carry a leading silo-replica dimension that is
    sharded over ``rules.silo_axis``.
    """

    def to_pspec(spec: ParamSpec):
        axes = [rules.mesh_axis(a) for a in spec.axes]
        # Never map two dims to the same mesh axis.
        seen = set()
        clean = []
        for a in axes:
            if a is not None and a in seen:
                clean.append(None)
            else:
                clean.append(a)
                if a is not None:
                    seen.add(a)
        if silo_leading:
            lead = rules.silo_axis
            if lead in seen:
                lead = None
            return P(lead, *clean)
        return P(*clean)

    return tree_map_specs(to_pspec, spec_tree)


def count_params(spec_tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(spec_tree, is_leaf=_is_spec):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total
