"""Recurrent blocks: xLSTM's mLSTM (chunked gated linear attention form)
and sLSTM (scalar-memory LSTM with exponential gating), plus a
Mamba-style selective SSM head used by the Hymba hybrid block.

mLSTM training path uses the chunkwise-parallel formulation (matmul-form
intra-chunk + state carry inter-chunk) — sub-quadratic, MXU-friendly, and
the contract implemented by the Pallas ``mlstm_scan`` kernel.  Decode is a
single recurrent state update (O(1) memory — this is what makes
``long_500k`` runnable for the SSM/hybrid architectures).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): internal 2x up-projection, per-head scalar gates.


def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D = cfg.d_model
    e = cfg.ssm.expand if cfg.ssm else 2
    Di = e * D
    H = cfg.n_heads
    s = D ** -0.5
    si = Di ** -0.5
    return {
        "w_up": ParamSpec((D, 2 * Di), ("embed", "inner"), s),   # x branch + gate z
        "w_q": ParamSpec((Di, Di), ("inner", None), si),
        "w_k": ParamSpec((Di, Di), ("inner", None), si),
        "w_v": ParamSpec((Di, Di), ("inner", None), si),
        "w_if": ParamSpec((Di, 2 * H), ("inner", None), si),     # input & forget gates
        "b_if": ParamSpec((2 * H,), (None,), 0.0, init="zeros"),
        "out_ln": ParamSpec((Di,), ("inner",), 1.0, init="ones"),
        "w_down": ParamSpec((Di, D), ("inner", "embed"), si),
    }


def _mlstm_gates(p, xu, H):
    """Per-head log-space gates: log input gate, log forget gate (sigmoid)."""
    gates = xu @ p["w_if"] + p["b_if"]  # [B,S,2H]
    i_raw, f_raw = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)  # [B,S,H], <= 0
    log_i = i_raw - jax.nn.softplus(i_raw)  # stabilized log sigmoid(i)
    return log_i, log_f


def mlstm_forward(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    chunk: int = 128,
    *,
    return_state: bool = False,
):
    """Chunked gated linear attention (mLSTM without the normalizer n_t —
    we use RMS output norm instead, cf. DESIGN.md)."""
    D = cfg.d_model
    e = cfg.ssm.expand if cfg.ssm else 2
    Di = e * D
    H = cfg.n_heads
    hd = Di // H
    B, S, _ = x.shape
    up = x @ p["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)  # [B,S,Di] each
    q = (xu @ p["w_q"]).reshape(B, S, H, hd)
    k = (xu @ p["w_k"]).reshape(B, S, H, hd) * (hd ** -0.5)
    v = (xu @ p["w_v"]).reshape(B, S, H, hd)
    log_i, log_f = _mlstm_gates(p, xu, H)  # [B,S,H]

    state = None
    if cfg.use_flash_kernel and not return_state:
        from repro.kernels import ops as kops

        h = kops.mlstm_scan(q, k, v, log_i, log_f, chunk=chunk)
    else:
        h, state = mlstm_chunked_ref(q, k, v, log_i, log_f, chunk=chunk,
                                     return_state=True)

    h = h.reshape(B, S, Di)
    from .layers import rms_norm

    h = rms_norm(h, p["out_ln"], cfg.norm_eps) * jax.nn.silu(z)
    out = h @ p["w_down"]
    if return_state:
        return out, state
    return out


def mlstm_chunked_ref(q, k, v, log_i, log_f, *, chunk: int = 128,
                      return_state: bool = False, unroll: bool = False):
    """Pure-jnp chunkwise-parallel gated linear attention.

    State recurrence per head:  S_t = f_t S_{t-1} + i_t k_t v_t^T,
    output h_t = q_t . S_t.  Chunked: intra-chunk matmul with relative
    decay matrix, inter-chunk state carry.  All in fp32.
    """
    B, S, H, hd = q.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    n = S // C
    qf = q.astype(jnp.float32).reshape(B, n, C, H, hd)
    kf = k.astype(jnp.float32).reshape(B, n, C, H, hd)
    vf = v.astype(jnp.float32).reshape(B, n, C, H, hd)
    li = log_i.reshape(B, n, C, H)
    lf = log_f.reshape(B, n, C, H)
    # cumulative log forget within chunk: g[c] = sum_{t<=c} lf[t]
    g = jnp.cumsum(lf, axis=2)  # [B,n,C,H]
    g_total = g[:, :, -1]  # [B,n,H]

    def chunk_step(state, inp):
        # state: [B,H,hd,hd]
        qc, kc, vc, gc, lic, gt = inp
        # inter-chunk: h_inter[c] = (q[c] * exp(g[c])) . S
        q_dec = qc * jnp.exp(gc)[..., None]
        h_inter = jnp.einsum("bchd,bhde->bche", q_dec, state)
        # intra-chunk: decay(c, t) = exp(g[c] - g[t]) * i[t], t <= c
        att = jnp.einsum("bchd,bthd->bhct", qc, kc)
        rel = gc[:, :, None, :] - gc[:, None, :, :]  # [B,c,t,H]
        rel = rel.transpose(0, 3, 1, 2)  # [B,H,c,t]
        gate = jnp.exp(rel + lic.transpose(0, 2, 1)[:, :, None, :])
        causal = jnp.tril(jnp.ones((qc.shape[1], qc.shape[1]), jnp.float32))
        att = att * gate * causal
        h_intra = jnp.einsum("bhct,bthd->bchd", att, vc)
        # state update: S' = exp(g_total) S + sum_t exp(g_total - g[t]) i[t] k[t] v[t]^T
        k_dec = kc * jnp.exp(gt[:, None, :] - gc + lic)[..., None]
        state = jnp.exp(gt)[..., None, None] * state + jnp.einsum(
            "bthd,bthe->bhde", k_dec, vc
        )
        return state, h_inter + h_intra

    init = jnp.zeros((B, H, hd, hd), jnp.float32)
    inputs = (
        qf.transpose(1, 0, 2, 3, 4),
        kf.transpose(1, 0, 2, 3, 4),
        vf.transpose(1, 0, 2, 3, 4),
        g.transpose(1, 0, 2, 3),
        li.transpose(1, 0, 2, 3),
        g_total.transpose(1, 0, 2),
    )
    final_state, hs = jax.lax.scan(chunk_step, init, inputs,
                                   unroll=n if unroll else 1)  # [n,B,C,H,hd]
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    if return_state:
        return h.astype(q.dtype), final_state
    return h.astype(q.dtype)


def mlstm_decode(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # [B, 1, D]
    state: jax.Array,  # [B, H, hd, hd] fp32
) -> Tuple[jax.Array, jax.Array]:
    D = cfg.d_model
    e = cfg.ssm.expand if cfg.ssm else 2
    Di = e * D
    H = cfg.n_heads
    hd = Di // H
    B = x.shape[0]
    up = x @ p["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)
    q = (xu @ p["w_q"]).reshape(B, H, hd).astype(jnp.float32)
    k = ((xu @ p["w_k"]) * (hd ** -0.5)).reshape(B, H, hd).astype(jnp.float32)
    v = (xu @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, xu, H)  # [B,1,H]
    i_g = jnp.exp(log_i[:, 0])[..., None, None]
    f_g = jnp.exp(log_f[:, 0])[..., None, None]
    state = f_g * state + i_g * jnp.einsum("bhd,bhe->bhde", k, v)
    h = jnp.einsum("bhd,bhde->bhe", q, state).reshape(B, 1, Di).astype(x.dtype)
    from .layers import rms_norm

    h = rms_norm(h, p["out_ln"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ p["w_down"], state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> jax.Array:
    e = cfg.ssm.expand if cfg.ssm else 2
    Di = e * cfg.d_model
    H = cfg.n_heads
    hd = Di // H
    return jnp.zeros((batch, H, hd, hd), jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM block: scalar memory, exponential gating, block-diagonal recurrence.


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    s = D ** -0.5
    return {
        "w": ParamSpec((D, 4 * D), ("embed", "inner"), s),      # i,f,z,o pre-acts
        "r": ParamSpec((H, dh, 4 * dh), (None, None, None), dh ** -0.5),
        "b": ParamSpec((4 * D,), (None,), 0.0, init="zeros"),
        "out_ln": ParamSpec((D,), ("embed",), 1.0, init="ones"),
        "w_down": ParamSpec((D, D), ("embed", None), s),
    }


def slstm_forward(p, cfg: ModelConfig, x: jax.Array, *,
                  return_state: bool = False):
    """Sequential scan over time (inherently recurrent)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    pre = (x @ p["w"] + p["b"]).reshape(B, S, 4, H, dh)

    def step(carry, t_in):
        c, n, h, m = carry  # cell, normalizer, hidden, stabilizer [B,H,dh]
        rec = jnp.einsum("bhd,hde->bhe", h, p["r"]).reshape(B, H, 4, dh)
        zi = t_in[:, 0] + rec[:, :, 0].reshape(B, H, dh)
        zf = t_in[:, 1] + rec[:, :, 1].reshape(B, H, dh)
        zz = t_in[:, 2] + rec[:, :, 2].reshape(B, H, dh)
        zo = t_in[:, 3] + rec[:, :, 3].reshape(B, H, dh)
        # exponential gating with stabilizer state m
        m_new = jnp.maximum(zf + m, zi)
        i_g = jnp.exp(zi - m_new)
        f_g = jnp.exp(zf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(zz)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    zeros = jnp.zeros((B, H, dh), jnp.float32)
    carry0 = (zeros, zeros, zeros, zeros)
    pre_t = pre.transpose(1, 0, 2, 3, 4).astype(jnp.float32)  # [S,B,4,H,dh]
    final_state, hs = jax.lax.scan(step, carry0, pre_t)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    from .layers import rms_norm

    out = rms_norm(h, p["out_ln"], cfg.norm_eps) @ p["w_down"]
    if return_state:
        return out, final_state
    return out


def slstm_decode(p, cfg: ModelConfig, x: jax.Array, state):
    """state = (c, n, h, m) each [B,H,dh] fp32."""
    B = x.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    pre = (x @ p["w"] + p["b"]).reshape(B, 4, H, dh).astype(jnp.float32)
    c, n, h, m = state
    rec = jnp.einsum("bhd,hde->bhe", h, p["r"]).reshape(B, H, 4, dh)
    zi = pre[:, 0] + rec[:, :, 0].reshape(B, H, dh)
    zf = pre[:, 1] + rec[:, :, 1].reshape(B, H, dh)
    zz = pre[:, 2] + rec[:, :, 2].reshape(B, H, dh)
    zo = pre[:, 3] + rec[:, :, 3].reshape(B, H, dh)
    m_new = jnp.maximum(zf + m, zi)
    i_g = jnp.exp(zi - m_new)
    f_g = jnp.exp(zf + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(zz)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
    out = h_new.reshape(B, 1, cfg.d_model).astype(x.dtype)
    from .layers import rms_norm

    out = rms_norm(out, p["out_ln"], cfg.norm_eps) @ p["w_down"]
    return out, (c_new, n_new, h_new, m_new)


def init_slstm_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, z, z)


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (used by the Hymba hybrid block)


def mamba_specs(cfg: ModelConfig, d_inner: int) -> Dict[str, ParamSpec]:
    D = cfg.d_model
    S = cfg.ssm.d_state if cfg.ssm else 16
    dt_rank = max(1, D // 16)
    s = D ** -0.5
    return {
        "w_in": ParamSpec((D, 2 * d_inner), ("embed", "inner"), s),
        "conv_w": ParamSpec((4, d_inner), ("conv", "inner"), 0.5),
        "w_bc": ParamSpec((d_inner, 2 * S), ("inner", None), d_inner ** -0.5),
        "w_dt1": ParamSpec((d_inner, dt_rank), ("inner", "rank"), d_inner ** -0.5),
        "w_dt2": ParamSpec((dt_rank, d_inner), ("rank", "inner"), dt_rank ** -0.5),
        "a_log": ParamSpec((d_inner, S), ("inner", "state"), 0.0, init="ones"),
        "d_skip": ParamSpec((d_inner,), ("inner",), 1.0, init="ones"),
        "w_out": ParamSpec((d_inner, D), ("inner", "embed"), d_inner ** -0.5),
    }


def _mamba_scan_inputs(p, x, d_inner, d_state):
    """Shared preprocessing: conv, gates, discretization."""
    B, S, _ = x.shape
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)  # [B,S,Di]
    # depthwise causal conv, width 4
    pad = jnp.pad(xin, ((0, 0), (3, 0), (0, 0)))
    conv = sum(pad[:, i : i + S] * p["conv_w"][i] for i in range(4))
    u = jax.nn.silu(conv)
    bc = u @ p["w_bc"]
    Bmat, Cmat = jnp.split(bc, 2, axis=-1)  # [B,S,state]
    dt = jax.nn.softplus((u @ p["w_dt1"]) @ p["w_dt2"])  # [B,S,Di]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # [Di, state]
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # [B,S,Di,state]
    dBu = (dt * u).astype(jnp.float32)[..., None] * Bmat.astype(jnp.float32)[:, :, None, :]
    return u, z, Cmat, dA, dBu


def mamba_forward(p, cfg: ModelConfig, x: jax.Array, d_inner: int, *,
                  return_state: bool = False):
    d_state = cfg.ssm.d_state if cfg.ssm else 16
    B, S, _ = x.shape
    u, z, Cmat, dA, dBu = _mamba_scan_inputs(p, x, d_inner, d_state)

    def step(h, t_in):
        dA_t, dBu_t, C_t = t_in  # [B,Di,state], [B,Di,state], [B,state]
        h = dA_t * h + dBu_t
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    h_final, ys = jax.lax.scan(
        step,
        h0,
        (
            dA.transpose(1, 0, 2, 3),
            dBu.transpose(1, 0, 2, 3),
            Cmat.transpose(1, 0, 2).astype(jnp.float32),
        ),
    )
    y = ys.transpose(1, 0, 2).astype(x.dtype)  # [B,S,Di]
    y = y + u * p["d_skip"]
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    if return_state:
        # conv ring buffer: last 3 pre-conv inputs
        xz = x @ p["w_in"]
        xin = jnp.split(xz, 2, axis=-1)[0]
        pad3 = jnp.pad(xin, ((0, 0), (3, 0), (0, 0)))[:, -3:]
        return out, (h_final, pad3)
    return out


def mamba_decode(p, cfg: ModelConfig, x: jax.Array, state, d_inner: int):
    """state = (h [B,Di,S], conv_buf [B,3,Di])."""
    d_state = cfg.ssm.d_state if cfg.ssm else 16
    B = x.shape[0]
    h, conv_buf = state
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)  # [B,1,Di]
    win = jnp.concatenate(
        [conv_buf.astype(x.dtype), xin.reshape(B, 1, d_inner)], axis=1)
    conv = jnp.einsum("bkd,kd->bd", win, p["conv_w"])
    u = jax.nn.silu(conv)  # [B,Di]
    bc = u @ p["w_bc"]
    Bv, Cv = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((u @ p["w_dt1"]) @ p["w_dt2"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    dBu = (dt * u).astype(jnp.float32)[..., None] * Bv.astype(jnp.float32)[:, None, :]
    h = dA * h + dBu
    y = jnp.einsum("bds,bs->bd", h, Cv.astype(jnp.float32)).astype(x.dtype)
    y = y + u * p["d_skip"]
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    new_buf = win[:, 1:]
    return y @ p["w_out"], (h, new_buf)


def init_mamba_state(cfg: ModelConfig, batch: int, d_inner: int,
                     dtype=jnp.float32):
    d_state = cfg.ssm.d_state if cfg.ssm else 16
    return (
        jnp.zeros((batch, d_inner, d_state), jnp.float32),  # SSM state: fp32
        jnp.zeros((batch, 3, d_inner), dtype),              # conv ring buffer
    )
