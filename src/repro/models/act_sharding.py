"""Activation sharding constraints.

GSPMD propagation through the embedding gather loses the batch ("data")
sharding, silently replicating every activation across the data axis
(~16x memory).  The launcher installs an activation context here and the
model inserts ``with_sharding_constraint`` at the residual-stream
boundaries.  ``seq_axis`` optionally shards the *sequence* dim of the
residual stream between blocks (sequence parallelism) — a §Perf lever
that divides per-layer remat storage by the model-axis size.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _get():
    return getattr(_state, "spec", None)


@contextlib.contextmanager
def activation_sharding(batch_axes: Optional[Tuple] = ("data",),
                        seq_axis: Optional[str] = None):
    """Context: residual stream [B, S, D] constrained to
    P(batch_axes, seq_axis, None)."""
    prev = _get()
    _state.spec = (batch_axes, seq_axis)
    try:
        yield
    finally:
        _state.spec = prev


def constrain_residual(x: jax.Array) -> jax.Array:
    spec = _get()
    if spec is None:
        return x
    batch_axes, seq_axis = spec
    if x.ndim < 2:
        return x
    b = batch_axes if batch_axes else None
    candidates = []
    if x.ndim == 3:
        s = seq_axis if seq_axis else None
        candidates.append(P(b, s, None))
        candidates.append(P(b, None, None))
    else:
        candidates.append(P(*([b] + [None] * (x.ndim - 1))))
    candidates.append(None)
    for p in candidates:
        if p is None:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, p)
        except Exception:
            continue
    return x


def constrain(x: jax.Array, spec: P) -> jax.Array:
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
