"""Hymba-style hybrid block: attention heads and mamba (SSM) heads run in
parallel on the same input; their normalized outputs are averaged with
learned scales [arXiv:2411.13676].  Attention uses sliding windows in all
but every ``global_attn_every``-th layer."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec
from .attention import attn_specs, attn_forward, attn_decode, init_kv_cache
from .ssm import mamba_specs, mamba_forward, mamba_decode, init_mamba_state
from .layers import rms_norm


def hymba_d_inner(cfg: ModelConfig) -> int:
    # mamba head width matches the attention width (parallel heads).
    return cfg.n_heads * cfg.head_dim


def hymba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D = cfg.d_model
    di = hymba_d_inner(cfg)
    specs: Dict[str, ParamSpec] = {}
    specs["attn"] = attn_specs(cfg)  # type: ignore[assignment]
    specs["mamba"] = mamba_specs(cfg, di)  # type: ignore[assignment]
    specs["attn_ln"] = ParamSpec((D,), ("embed",), 1.0, init="ones")
    specs["mamba_ln"] = ParamSpec((D,), ("embed",), 1.0, init="ones")
    return specs


def hymba_forward(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                  layer: int, *, return_cache: bool = False):
    window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
    a = attn_forward(p["attn"], cfg, x, positions, causal=True, window=window,
                     return_kv=return_cache)
    if return_cache:
        a, kv = a
        m, st = mamba_forward(p["mamba"], cfg, x, hymba_d_inner(cfg),
                              return_state=True)
    else:
        m = mamba_forward(p["mamba"], cfg, x, hymba_d_inner(cfg))
    a = rms_norm(a, p["attn_ln"], cfg.norm_eps)
    m = rms_norm(m, p["mamba_ln"], cfg.norm_eps)
    out = 0.5 * (a + m)
    if return_cache:
        return out, (kv, st)
    return out


def hymba_decode(p, cfg: ModelConfig, x: jax.Array, cache, position, layer: int):
    window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
    a, kv = attn_decode(p["attn"], cfg, x, cache["kv"], position, window=window)
    m, st = mamba_decode(p["mamba"], cfg, x, cache["ssm"], hymba_d_inner(cfg))
    a = rms_norm(a, p["attn_ln"], cfg.norm_eps)
    m = rms_norm(m, p["mamba_ln"], cfg.norm_eps)
    return 0.5 * (a + m), {"kv": kv, "ssm": st}


def init_hymba_cache(cfg: ModelConfig, batch: int, max_len: int, layer: int, dtype):
    window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
    return {
        "kv": init_kv_cache(cfg, batch, max_len, window, dtype),
        "ssm": init_mamba_state(cfg, batch, hymba_d_inner(cfg), dtype),
    }
