"""Model configuration covering the 10 assigned architecture families.

A single ``ModelConfig`` describes dense GQA/SWA transformers, MoE
(top-k routed + shared experts), MLA (DeepSeek multi-head latent
attention), xLSTM stacks (mLSTM/sLSTM), Hymba-style hybrid
attention+mamba blocks, Whisper encoder-decoder, and VLM backbones with a
stubbed vision frontend.  ``block_pattern`` selects the per-layer block
type; everything else is dimensionality.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

BlockKind = Literal[
    "attn",       # attention + MLP (dense)
    "attn_moe",   # attention + MoE FFN
    "mla_moe",    # MLA attention + MoE FFN (deepseek)
    "mla",        # MLA attention + dense MLP
    "mlstm",      # xLSTM mLSTM block (internal up-proj, no separate FFN)
    "slstm",      # xLSTM sLSTM block
    "hymba",      # parallel attention + mamba heads, + MLP
]

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int            # hidden size of each routed expert
    n_shared: int = 0        # shared (always-on) experts
    d_shared: int = 0        # hidden size of the shared expert MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0     # 0 = no query compression (deepseek-v2-lite)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16        # mamba state size / mLSTM key dim factor
    expand: int = 2          # inner expansion
    d_conv: int = 4          # depthwise conv width (mamba)
    n_ssm_heads: int = 0     # hymba: number of mamba heads in parallel


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed: inputs are frame
    embeddings) or VLM vision prefix (patch embeddings)."""

    n_layers: int = 0
    seq_len: int = 1500      # encoder frames (whisper-large-v3: 1500)
    is_causal: bool = False


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ()    # len == n_layers; default "attn"
    sliding_window: Optional[int] = None   # SWA window (danube/hymba)
    global_attn_every: int = 0             # hymba: every k-th layer full attn
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision_prefix_len: int = 0             # VLM: stub patch embeddings
    mlp_variant: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # federated / distribution knobs
    n_silos: int = 1
    use_flash_kernel: bool = False         # Pallas path (TPU); jnp ref on CPU
    remat: bool = True
    # Fully unroll inner attention/mlstm chunk scans so the dry-run's
    # cost_analysis counts every block (XLA counts a while body once).
    analysis_unroll: bool = False
    # §Perf: banded sliding-window attention (touch only the visible KV
    # band per query block -> O(S*window) instead of O(S^2) masked work).
    banded_swa: bool = False
    # §Perf: flash-style custom VJP — backward recomputes probability
    # blocks instead of storing them (dominant train-memory term).
    flash_vjp: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.block_pattern:
            kind: str
            if self.moe is not None and self.mla is not None:
                kind = "mla_moe"
            elif self.moe is not None:
                kind = "attn_moe"
            elif self.mla is not None:
                kind = "mla"
            else:
                kind = "attn"
            object.__setattr__(self, "block_pattern", (kind,) * self.n_layers)
        if len(self.block_pattern) != self.n_layers:
            raise ValueError("block_pattern length must equal n_layers")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 128 so the embedding / LM head can
        shard over the model axis (whisper: 51866->51968, hymba:
        32001->32128).  Logits are sliced back to ``vocab_size``."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None and self.encoder.n_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if decode memory is bounded (SWA / recurrent)."""
        kinds = set(self.block_pattern)
        if kinds <= {"mlstm", "slstm"}:
            return True
        if "hymba" in kinds:
            return self.sliding_window is not None
        return self.sliding_window is not None and not self.is_encdec

    def layer_uses_window(self, layer: int) -> bool:
        if self.sliding_window is None:
            return False
        if self.global_attn_every and (layer % self.global_attn_every == 0):
            return False
        return True

    def reduced(self, *, n_layers: int = 2, d_model: int = 256) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        scale = d_model / self.d_model
        n_heads = max(2, min(self.n_heads, d_model // 64))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        moe = None
        if self.moe is not None:
            n_exp = min(4, self.moe.n_experts)
            moe = dataclasses.replace(
                self.moe,
                n_experts=n_exp,
                top_k=min(2, self.moe.top_k),
                d_expert=max(32, int(self.moe.d_expert * scale)),
                n_shared=min(1, self.moe.n_shared),
                d_shared=max(32, int(self.moe.d_shared * scale)) if self.moe.n_shared else 0,
                # dropless at smoke scale: capacity >= any possible expert
                # load, so prefill/decode/forward agree exactly
                capacity_factor=float(n_exp),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                            v_head_dim=32, q_lora_rank=0)
        enc = None
        if self.encoder is not None:
            enc = dataclasses.replace(self.encoder, n_layers=min(2, self.encoder.n_layers),
                                      seq_len=min(64, self.encoder.seq_len))
        pattern = self.block_pattern[: n_layers]
        # keep family diversity: make sure at least one of each kind survives
        kinds = tuple(dict.fromkeys(self.block_pattern))
        if len(kinds) > 1 and n_layers >= len(kinds):
            pattern = kinds + pattern[len(kinds):]
            pattern = pattern[:n_layers]
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=0,
            d_ff=max(64, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=min(512, self.vocab_size),
            block_pattern=pattern,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            moe=moe,
            mla=mla,
            encoder=enc,
            vision_prefix_len=min(8, self.vision_prefix_len),
            use_flash_kernel=False,
        )
