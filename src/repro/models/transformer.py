"""Model assembly: builds the parameter spec tree from a ModelConfig,
and provides ``forward`` (training), ``prefill`` and ``decode_step``
(serving) for every supported block kind — dense GQA/SWA, MoE, MLA,
mLSTM/sLSTM, Hymba hybrid, Whisper encoder-decoder, VLM prefix."""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec
from . import attention as A
from . import moe as MOE
from . import ssm as SSM
from . import hybrid as HY
from .act_sharding import constrain_residual
from .layers import (
    embed_tokens,
    gelu_mlp,
    rms_norm,
    sinusoidal_positions,
    softmax_cross_entropy,
    swiglu,
)

AUDIO_FRONTEND_DIM = 128   # mel-bin stub features (whisper carve-out)
VISION_FRONTEND_DIM = 1024  # ViT patch-embedding stub features (VLM carve-out)


# ---------------------------------------------------------------------------
# parameter spec tree


def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    s = D ** -0.5
    if cfg.mlp_variant == "gelu":
        return {
            "w_up": ParamSpec((D, F), ("embed", "ffn"), s),
            "b_up": ParamSpec((F,), ("ffn",), 0.0, init="zeros"),
            "w_down": ParamSpec((F, D), ("ffn", "embed"), F ** -0.5),
            "b_down": ParamSpec((D,), ("embed",), 0.0, init="zeros"),
        }
    return {
        "w_gate": ParamSpec((D, F), ("embed", "ffn"), s),
        "w_up": ParamSpec((D, F), ("embed", "ffn"), s),
        "w_down": ParamSpec((F, D), ("ffn", "embed"), F ** -0.5),
    }


def block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    D = cfg.d_model
    ln = lambda: ParamSpec((D,), ("embed",), 1.0, init="ones")
    if kind == "attn":
        return {"ln1": ln(), "attn": A.attn_specs(cfg), "ln2": ln(), "mlp": mlp_specs(cfg)}
    if kind == "attn_moe":
        return {"ln1": ln(), "attn": A.attn_specs(cfg), "ln2": ln(), "moe": MOE.moe_specs(cfg)}
    if kind == "mla":
        return {"ln1": ln(), "attn": A.mla_specs(cfg), "ln2": ln(), "mlp": mlp_specs(cfg)}
    if kind == "mla_moe":
        return {"ln1": ln(), "attn": A.mla_specs(cfg), "ln2": ln(), "moe": MOE.moe_specs(cfg)}
    if kind == "mlstm":
        return {"ln1": ln(), "mlstm": SSM.mlstm_specs(cfg)}
    if kind == "slstm":
        return {"ln1": ln(), "slstm": SSM.slstm_specs(cfg)}
    if kind == "hymba":
        return {"ln1": ln(), "hymba": HY.hymba_specs(cfg), "ln2": ln(), "mlp": mlp_specs(cfg)}
    if kind == "xattn":  # whisper decoder block
        return {
            "ln1": ln(),
            "attn": A.attn_specs(cfg),
            "lnx": ln(),
            "xattn": A.cross_attn_specs(cfg),
            "ln2": ln(),
            "mlp": mlp_specs(cfg),
        }
    raise KeyError(f"unknown block kind {kind!r}")


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.padded_vocab_size
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), 1.0 / (D ** 0.5)),
        "layers": [
            block_specs(cfg, "xattn" if cfg.is_encdec and k == "attn" else k)
            for k in cfg.block_pattern
        ],
        "final_ln": ParamSpec((D,), ("embed",), 1.0, init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((D, V), ("embed", "vocab"), D ** -0.5)
    if cfg.is_encdec:
        enc = cfg.encoder
        specs["frontend"] = ParamSpec(
            (AUDIO_FRONTEND_DIM, D), (None, "embed"), AUDIO_FRONTEND_DIM ** -0.5
        )
        specs["enc_layers"] = [block_specs(cfg, "attn") for _ in range(enc.n_layers)]
        specs["enc_final_ln"] = ParamSpec((D,), ("embed",), 1.0, init="ones")
    if cfg.vision_prefix_len:
        specs["vision_proj"] = ParamSpec(
            (VISION_FRONTEND_DIM, D), (None, "embed"), VISION_FRONTEND_DIM ** -0.5
        )
    return specs


# ---------------------------------------------------------------------------
# forward (training / prefill)


def _block_forward(p, cfg: ModelConfig, kind: str, layer: int, x, positions,
                   enc_out=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_moe"):
        window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
        h = A.attn_forward(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                           positions, causal=True, window=window)
        x = x + h
    elif kind in ("mla", "mla_moe"):
        h = A.mla_forward(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions)
        x = x + h
    elif kind == "xattn":
        h = A.attn_forward(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                           positions, causal=True, window=None)
        x = x + h
        hx = A.cross_attn_forward(p["xattn"], cfg, rms_norm(x, p["lnx"], cfg.norm_eps), enc_out)
        x = x + hx
    elif kind == "mlstm":
        h = SSM.mlstm_forward(p["mlstm"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
        return x + h, aux
    elif kind == "slstm":
        h = SSM.slstm_forward(p["slstm"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
        return x + h, aux
    elif kind == "hymba":
        h = HY.hymba_forward(p["hymba"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                             positions, layer)
        x = x + h
    else:
        raise KeyError(kind)
    # FFN half
    xin = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind in ("attn_moe", "mla_moe"):
        h, aux = MOE.moe_forward(p["moe"], cfg, xin)
    elif cfg.mlp_variant == "gelu":
        h = gelu_mlp(xin, p["mlp"]["w_up"], p["mlp"]["b_up"],
                     p["mlp"]["w_down"], p["mlp"]["b_down"])
    else:
        h = swiglu(xin, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x + h, aux


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stubbed frame features [B, T_enc, 128]."""
    x = frames @ params["frontend"]
    T = x.shape[1]
    x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
    x = constrain_residual(x)
    positions = jnp.arange(T, dtype=jnp.int32)

    def enc_block(x, p):
        h = A.attn_forward(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                           positions, causal=False, window=None)
        x = x + h
        xin = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.mlp_variant == "gelu":
            h = gelu_mlp(xin, p["mlp"]["w_up"], p["mlp"]["b_up"],
                         p["mlp"]["w_down"], p["mlp"]["b_down"])
        else:
            h = swiglu(xin, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return x + h

    for li, p in enumerate(params["enc_layers"]):
        blk = jax.checkpoint(enc_block) if cfg.remat else enc_block
        x = constrain_residual(blk(x, p))
    return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                 # [B, S]
    *,
    enc_frames: Optional[jax.Array] = None,   # [B, T_enc, 128] (audio stub)
    vision_embeds: Optional[jax.Array] = None,  # [B, P, 1024] (VLM stub)
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits [B,S,V], aux_loss)."""
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = constrain_residual(x)
    if cfg.vision_prefix_len:
        assert vision_embeds is not None
        prefix = vision_embeds @ params["vision_proj"]
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    Sfull = x.shape[1]
    positions = jnp.arange(Sfull, dtype=jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)

    def run_block(x, p, kind, layer):
        k = "xattn" if cfg.is_encdec and kind == "attn" else kind
        return _block_forward(p, cfg, k, layer, x, positions, enc_out)

    aux_total = jnp.zeros((), jnp.float32)
    for layer, (p, kind) in enumerate(zip(params["layers"], cfg.block_pattern)):
        blk = run_block
        if cfg.remat:
            blk = jax.checkpoint(run_block, static_argnums=(2, 3))
        x, aux = blk(x, p, kind, layer)
        x = constrain_residual(x)
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if cfg.vision_prefix_len:
        x = x[:, cfg.vision_prefix_len :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[..., : cfg.vocab_size]
    return logits, aux_total


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, aux = forward(
        params, cfg, batch["tokens"],
        enc_frames=batch.get("enc_frames"),
        vision_embeds=batch.get("vision_embeds"),
    )
    return softmax_cross_entropy(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    caches: List[Any] = []
    for layer, kind in enumerate(cfg.block_pattern):
        if kind in ("attn", "attn_moe"):
            window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
            c = A.init_kv_cache(cfg, batch, max_len, window, dtype)
            if cfg.is_encdec:
                c = {"kv": c, "xk": None, "xv": None}  # filled at prefill
            caches.append(c)
        elif kind in ("mla", "mla_moe"):
            caches.append(A.init_mla_cache(cfg, batch, max_len, dtype))
        elif kind == "mlstm":
            caches.append(SSM.init_mlstm_state(cfg, batch))
        elif kind == "slstm":
            caches.append(SSM.init_slstm_state(cfg, batch))
        elif kind == "hymba":
            caches.append(HY.init_hymba_cache(cfg, batch, max_len, layer, dtype))
        else:
            raise KeyError(kind)
    return caches


def decode_step(
    params,
    cfg: ModelConfig,
    token: jax.Array,      # [B] int32
    cache,
    position: jax.Array,   # scalar int32
    *,
    enc_out: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """One-token decode; returns (logits [B,V], new_cache)."""
    B = token.shape[0]
    x = embed_tokens(params["embed"], token[:, None])  # [B,1,D]
    x = constrain_residual(x)
    new_cache = []
    for layer, (p, kind, c) in enumerate(zip(params["layers"], cfg.block_pattern, cache)):
        if cfg.is_encdec and kind == "attn":
            h, kv = A.attn_decode(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                                  c["kv"], position, window=None)
            x = x + h
            # cross attention against cached encoder K/V
            hx = _cross_decode(p["xattn"], cfg, rms_norm(x, p["lnx"], cfg.norm_eps),
                               c["xk"], c["xv"])
            x = x + hx
            new_cache.append({"kv": kv, "xk": c["xk"], "xv": c["xv"]})
        elif kind in ("attn", "attn_moe"):
            window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
            h, kv = A.attn_decode(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                                  c, position, window=window)
            x = x + h
            new_cache.append(kv)
        elif kind in ("mla", "mla_moe"):
            h, kv = A.mla_decode(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                                 c, position)
            x = x + h
            new_cache.append(kv)
        elif kind == "mlstm":
            h, st = SSM.mlstm_decode(p["mlstm"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), c)
            x = x + h
            new_cache.append(st)
            continue
        elif kind == "slstm":
            h, st = SSM.slstm_decode(p["slstm"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), c)
            x = x + h
            new_cache.append(st)
            continue
        elif kind == "hymba":
            h, hc = HY.hymba_decode(p["hymba"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                                    c, position, layer)
            x = x + h
            new_cache.append(hc)
        else:
            raise KeyError(kind)
        # FFN half
        xin = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind in ("attn_moe", "mla_moe"):
            h, _ = MOE.moe_forward(p["moe"], cfg, xin)
        elif cfg.mlp_variant == "gelu":
            h = gelu_mlp(xin, p["mlp"]["w_up"], p["mlp"]["b_up"],
                         p["mlp"]["w_down"], p["mlp"]["b_down"])
        else:
            h = swiglu(xin, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        x = x + h
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0, : cfg.vocab_size]
    return logits, new_cache


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,     # [B, S]
    max_len: int,
    *,
    cache_dtype=jnp.bfloat16,
    enc_frames: Optional[jax.Array] = None,
    vision_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """Serving prefill: full forward + populate the serving cache.

    Returns (last-token logits [B, V], cache ready for decode at position
    S).  Attention caches are written via scatter into the (ring) buffers;
    recurrent blocks return their final state directly.
    """
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = constrain_residual(x)
    if cfg.vision_prefix_len:
        assert vision_embeds is not None
        prefix = vision_embeds @ params["vision_proj"]
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    Sfull = x.shape[1]
    positions = jnp.arange(Sfull, dtype=jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)

    cache = init_cache(cfg, B, max_len, cache_dtype)
    new_cache: List[Any] = []
    for layer, (p, kind, c) in enumerate(zip(params["layers"], cfg.block_pattern, cache)):
        if cfg.is_encdec and kind == "attn":
            h, (k, v) = A.attn_forward(
                p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                causal=True, window=None, return_kv=True)
            x = x + h
            hx = A.cross_attn_forward(p["xattn"], cfg,
                                      rms_norm(x, p["lnx"], cfg.norm_eps), enc_out)
            x = x + hx
            kv = A.fill_kv_cache(cfg, c["kv"], k, v, positions, None)
            H, hd = cfg.n_heads, cfg.head_dim
            T = enc_out.shape[1]
            xk = (enc_out @ p["xattn"]["wk"]).reshape(B, T, H, hd)
            xv = (enc_out @ p["xattn"]["wv"]).reshape(B, T, H, hd)
            new_cache.append({"kv": kv, "xk": xk, "xv": xv})
        elif kind in ("attn", "attn_moe"):
            window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
            h, (k, v) = A.attn_forward(
                p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                causal=True, window=window, return_kv=True)
            x = x + h
            new_cache.append(A.fill_kv_cache(cfg, c, k, v, positions, window))
        elif kind in ("mla", "mla_moe"):
            h, (c_kv, k_rope) = A.mla_forward(
                p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                return_latent=True)
            x = x + h
            new_cache.append(A.fill_mla_cache(cfg, c, c_kv, k_rope, positions))
        elif kind == "mlstm":
            h, st = SSM.mlstm_forward(p["mlstm"], cfg,
                                      rms_norm(x, p["ln1"], cfg.norm_eps),
                                      return_state=True)
            x = x + h
            new_cache.append(st)
            continue
        elif kind == "slstm":
            h, st = SSM.slstm_forward(p["slstm"], cfg,
                                      rms_norm(x, p["ln1"], cfg.norm_eps),
                                      return_state=True)
            x = x + h
            new_cache.append(st)
            continue
        elif kind == "hymba":
            h, ((k, v), st) = HY.hymba_forward(
                p["hymba"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                layer, return_cache=True)
            x = x + h
            window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
            kv = A.fill_kv_cache(cfg, c["kv"], k, v, positions, window)
            new_cache.append({"kv": kv, "ssm": st})
        else:
            raise KeyError(kind)
        # FFN half (skipped for pure recurrent blocks via `continue`)
        xin = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind in ("attn_moe", "mla_moe"):
            h, _ = MOE.moe_forward(p["moe"], cfg, xin)
        elif cfg.mlp_variant == "gelu":
            h = gelu_mlp(xin, p["mlp"]["w_up"], p["mlp"]["b_up"],
                         p["mlp"]["w_down"], p["mlp"]["b_down"])
        else:
            h = swiglu(xin, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        x = constrain_residual(x + h)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head)[:, : cfg.vocab_size]
    return logits, new_cache


def _cross_decode(p, cfg: ModelConfig, x, xk, xv):
    H, hd = cfg.n_heads, cfg.head_dim
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    s = jnp.einsum("bshd,bthd->bsht", q.astype(jnp.float32) * hd ** -0.5,
                   xk.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bsht,bthd->bshd", w, xv).reshape(B, 1, H * hd)
    return o @ p["wo"]


def prefill_cross_cache(params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V from encoder output."""
    out = []
    B, T, _ = enc_out.shape
    H, hd = cfg.n_heads, cfg.head_dim
    for p in params["layers"]:
        xk = (enc_out @ p["xattn"]["wk"]).reshape(B, T, H, hd)
        xv = (enc_out @ p["xattn"]["wv"]).reshape(B, T, H, hd)
        out.append((xk, xv))
    return out
