"""Attention: GQA (causal / sliding-window / bidirectional), DeepSeek MLA
(multi-head latent attention, absorbed decode path), and cross-attention.

Full-sequence attention uses a memory-bounded chunked (flash-style)
formulation in pure jnp — `lax.scan` over KV blocks with running
max/normalizer — so 32k-token prefill lowers without materializing S^2
score matrices.  The Pallas TPU kernel (repro.kernels.flash_attention)
implements the same contract and is validated against this reference.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, rope_angles
from .params import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter specs


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = D ** -0.5
    return {
        "wq": ParamSpec((D, H * hd), ("embed", "heads"), s),
        "wk": ParamSpec((D, K * hd), ("embed", "kv_heads"), s),
        "wv": ParamSpec((D, K * hd), ("embed", "kv_heads"), s),
        "wo": ParamSpec((H * hd, D), ("heads", "embed"), (H * hd) ** -0.5),
    }


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.mla is not None
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    s = D ** -0.5
    return {
        "w_dkv": ParamSpec((D, m.kv_lora_rank), ("embed", "rank"), s),
        "w_krope": ParamSpec((D, m.qk_rope_dim), ("embed", None), s),
        "kv_ln": ParamSpec((m.kv_lora_rank,), ("rank",), 1.0, init="ones"),
        "w_uk": ParamSpec((m.kv_lora_rank, H * m.qk_nope_dim), ("rank", "heads"), m.kv_lora_rank ** -0.5),
        "w_uv": ParamSpec((m.kv_lora_rank, H * m.v_head_dim), ("rank", "heads"), m.kv_lora_rank ** -0.5),
        "wq": ParamSpec((D, H * (m.qk_nope_dim + m.qk_rope_dim)), ("embed", "heads"), s),
        "wo": ParamSpec((H * m.v_head_dim, D), ("heads", "embed"), (H * m.v_head_dim) ** -0.5),
    }


def cross_attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    s = D ** -0.5
    return {
        "wq": ParamSpec((D, H * hd), ("embed", "heads"), s),
        "wk": ParamSpec((D, H * hd), ("embed", "heads"), s),
        "wv": ParamSpec((D, H * hd), ("embed", "heads"), s),
        "wo": ParamSpec((H * hd, D), ("heads", "embed"), (H * hd) ** -0.5),
    }


# ---------------------------------------------------------------------------
# chunked (flash-style) attention reference — memory O(S * kv_block)


def chunked_attention(
    q: jax.Array,  # [B, S, K, G, hd] (grouped query heads)
    k: jax.Array,  # [B, T, K, hd]
    v: jax.Array,  # [B, T, K, hd]
    q_pos: jax.Array,  # [S] int32
    kv_pos: jax.Array,  # [T] int32 (-1 marks invalid cache slots)
    *,
    causal: bool,
    window: Optional[int],
    kv_block: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    B, S, K, G, hd = q.shape
    hd_v = v.shape[-1]
    T = k.shape[1]
    blocks = max(1, (T + kv_block - 1) // kv_block)
    pad = blocks * kv_block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    kb = k.reshape(B, blocks, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, blocks, kv_block, K, hd_v).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(blocks, kv_block)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk  # [B, kb, K, hd], [B, kb, K, hd], [kb]
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kc.astype(jnp.float32))
        mask = pc[None, :] >= 0  # [1, kb] valid
        if causal:
            mask = mask & (pc[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - pc[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb),
                                  unroll=blocks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention_vjp(q, k, v, q_pos, kv_pos, causal, window, kv_block):
    """chunked_attention with a flash-style custom VJP: the backward pass
    recomputes the probability blocks from (q, k, logsumexp stats) instead
    of storing them — O(S * kv_block) residuals instead of
    O(S * T) fp32 probabilities per layer (the dominant training-memory
    term at 4k+ context; see EXPERIMENTS.md §Perf)."""
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, window, kv_block)
    return out


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, window, kv_block):
    B, S, K, G, hd = q.shape
    hd_v = v.shape[-1]
    T = k.shape[1]
    blocks = max(1, (T + kv_block - 1) // kv_block)
    pad = blocks * kv_block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    kb = k.reshape(B, blocks, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, blocks, kv_block, K, hd_v).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(blocks, kv_block)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kc.astype(jnp.float32))
        mask = pc[None, :] >= 0
        if causal:
            mask = mask & (pc[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - pc[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # [B,S,K,G]
    return out, lse


def _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, window, kv_block)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(causal, window, kv_block, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, S, K, G, hd = q.shape
    hd_v = v.shape[-1]
    T = k.shape[1]
    blocks = max(1, (T + kv_block - 1) // kv_block)
    pad = blocks * kv_block - T
    kp, vp, kvp = k, v, kv_pos
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvp = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    kb = kp.reshape(B, blocks, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, blocks, kv_block, K, hd_v).transpose(1, 0, 2, 3, 4)
    pb = kvp.reshape(blocks, kv_block)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    do = dout.astype(jnp.float32)
    # D_i = sum_d dout_i * out_i  (rowwise)
    Drow = jnp.einsum("bskgd,bskgd->bskg", do, out.astype(jnp.float32))

    def step(dq, blk):
        kc, vc, pc = blk
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kc.astype(jnp.float32))
        mask = pc[None, :] >= 0
        if causal:
            mask = mask & (pc[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - pc[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # [B,S,K,G,t]
        dv_blk = jnp.einsum("bskgt,bskgd->btkd", p, do)
        dp = jnp.einsum("bskgd,btkd->bskgt", do, vc.astype(jnp.float32))
        ds = p * (dp - Drow[..., None])
        dq = dq + jnp.einsum("bskgt,btkd->bskgd", ds, kc.astype(jnp.float32))
        dk_blk = jnp.einsum("bskgt,bskgd->btkd", ds, qf)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, pb))
    dq = (dq * scale).astype(q.dtype)
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, blocks * kv_block, K, hd)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, blocks * kv_block, K, hd_v)
    if pad:
        dk = dk[:, :T]
        dv = dv[:, :T]
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None)


flash_attention_vjp.defvjp(_flash_fwd, _flash_bwd)


def banded_swa_attention(
    q: jax.Array,  # [B, S, K, G, hd]
    k: jax.Array,  # [B, S, K, hd]
    v: jax.Array,  # [B, S, K, hd]
    positions: jax.Array,  # [S]
    *,
    window: int,
    q_block: int = 1024,
) -> jax.Array:
    """Sliding-window attention that only touches the KV band each query
    block can see — O(S * window) compute/bytes instead of O(S^2).

    §Perf optimization (beyond the naive chunked formulation): scan over
    query blocks; for each, ``dynamic_slice`` the KV band
    [q_start - window + 1, q_end] (clamped), compute one flash-style
    block.  Band length = q_block + window rounded up — static, so the
    whole thing stays jittable.
    """
    B, S, K, G, hd = q.shape
    if S % q_block:
        q_block = math_gcd_block(S, q_block)
    n_q = S // q_block
    band = q_block + window  # static band length (covers the visible range)
    band = min(band, S)
    scale = hd ** -0.5

    qb = q.reshape(B, n_q, q_block, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pb = positions.reshape(n_q, q_block)

    def one_block(_, inp):
        qc, pc, qi = inp  # [B,q_block,K,G,hd], [q_block], scalar
        start = jnp.clip(qi * q_block + q_block - band, 0, S - band)
        kc = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, band, K, hd))
        vc = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, band, K, hd))
        kv_pos = start + jnp.arange(band, dtype=jnp.int32)
        s = jnp.einsum("bskgd,btkd->bskgt", qc.astype(jnp.float32) * scale,
                       kc.astype(jnp.float32))
        mask = (kv_pos[None, :] <= pc[:, None]) & (
            pc[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bskgt,btkd->bskgd", p, vc.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        one_block, None,
        (qb, pb, jnp.arange(n_q, dtype=jnp.int32)))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)


def math_gcd_block(S: int, prefer: int) -> int:
    b = min(prefer, S)
    while S % b:
        b -= 1
    return b


def naive_attention(q, k, v, q_pos, kv_pos, *, causal, window):
    """O(S*T) reference used for small-shape correctness tests."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgd,btkd->bskgt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    mask = kv_pos[None, :] >= 0
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block forward


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def attn_forward(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [S]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_heads_override: Optional[int] = None,
    return_kv: bool = False,
):
    H, K, hd = cfg.n_heads, kv_heads_override or cfg.n_kv_heads, cfg.head_dim
    G = H // K
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], K, hd)
    v = _split_heads(x @ p["wv"], K, hd)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    B, S = x.shape[:2]
    qg = q.reshape(B, S, K, G, hd)
    use_kernel = cfg.use_flash_kernel and causal
    if use_kernel:
        from repro.kernels import ops as kops

        out = kops.flash_attention(qg, k, v, positions, positions,
                                   causal=causal, window=window)
    elif cfg.banded_swa and causal and window is not None and S > 2 * window:
        out = banded_swa_attention(qg, k, v, positions, window=window)
    elif cfg.flash_vjp:
        out = flash_attention_vjp(qg, k, v, positions, positions,
                                  causal, window, 1024)
    else:
        out = chunked_attention(qg, k, v, positions, positions,
                                causal=causal, window=window,
                                unroll=cfg.analysis_unroll)
    out = out.reshape(B, S, H * hd)
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def fill_kv_cache(cfg: ModelConfig, cache: Dict[str, jax.Array],
                  k: jax.Array, v: jax.Array, positions: jax.Array,
                  window: Optional[int]) -> Dict[str, jax.Array]:
    """Write prefill K/V into a (possibly ring-buffered) cache."""
    size = cache["k"].shape[1]
    S = k.shape[1]
    take = min(S, size)
    k_t, v_t = k[:, -take:], v[:, -take:]
    pos_t = positions[-take:]
    slots = (pos_t % size).astype(jnp.int32)
    ck = cache["k"].at[:, slots].set(k_t.astype(cache["k"].dtype))
    cv = cache["v"].at[:, slots].set(v_t.astype(cache["v"].dtype))
    cpos = cache["pos"].at[slots].set(pos_t.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cpos}


def attn_decode(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # [B, 1, D]
    cache: Dict[str, jax.Array],
    position: jax.Array,  # scalar int32
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a (possibly ring-buffered) KV cache."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    B = x.shape[0]
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], K, hd)
    v = _split_heads(x @ p["wv"], K, hd)
    pos_arr = position[None]
    cos, sin = rope_angles(pos_arr, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_len = cache["k"].shape[1]
    slot = (position if window is None else position % cache_len).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos_arr.astype(jnp.int32), (slot,))
    qg = q.reshape(B, 1, K, G, hd)
    out = chunked_attention(qg, ck, cv, pos_arr, cpos, causal=True, window=window)
    out = out.reshape(B, 1, H * hd)
    return out @ p["wo"], {"k": ck, "v": cv, "pos": cpos}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int], dtype) -> Dict[str, jax.Array]:
    K, hd = cfg.n_kv_heads, cfg.head_dim
    size = min(max_len, window) if window is not None else max_len
    return {
        "k": jnp.zeros((batch, size, K, hd), dtype),
        "v": jnp.zeros((batch, size, K, hd), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)


def mla_forward(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                *, return_latent: bool = False):
    """Training/prefill path: expand the latent to per-head K/V."""
    m = cfg.mla
    H = cfg.n_heads
    B, S, D = x.shape
    from .layers import rms_norm

    c_kv = rms_norm(x @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)  # [B,S,R]
    k_rope = (x @ p["w_krope"]).reshape(B, S, 1, m.qk_rope_dim)
    cos, sin = rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)  # shared across heads
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = (x @ p["wq"]).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, cos, sin)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_dim))], axis=-1
    )
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = qfull.reshape(B, S, H, 1, m.qk_nope_dim + m.qk_rope_dim)
    out = chunked_attention(qg, k, v, positions, positions, causal=True,
                            window=None, unroll=cfg.analysis_unroll)
    out = out.reshape(B, S, H * m.v_head_dim)
    out = out @ p["wo"]
    if return_latent:
        return out, (c_kv, k_rope[:, :, 0, :])
    return out


def fill_mla_cache(cfg: ModelConfig, cache, c_kv, k_rope, positions):
    size = cache["c_kv"].shape[1]
    S = c_kv.shape[1]
    take = min(S, size)
    slots = (positions[-take:] % size).astype(jnp.int32)
    return {
        "c_kv": cache["c_kv"].at[:, slots].set(c_kv[:, -take:].astype(cache["c_kv"].dtype)),
        "k_rope": cache["k_rope"].at[:, slots].set(k_rope[:, -take:].astype(cache["k_rope"].dtype)),
        "pos": cache["pos"].at[slots].set(positions[-take:].astype(jnp.int32)),
    }


def mla_decode(p, cfg: ModelConfig, x: jax.Array, cache, position):
    """Absorbed decode: the cache holds only (c_kv, k_rope) — the paper-
    faithful MLA memory saving.  Scores are computed in latent space by
    absorbing W_uk into the query and W_uv into the output projection."""
    m = cfg.mla
    H = cfg.n_heads
    B = x.shape[0]
    from .layers import rms_norm

    c_kv_new = rms_norm(x @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)  # [B,1,R]
    k_rope_new = (x @ p["w_krope"]).reshape(B, 1, 1, m.qk_rope_dim)
    pos_arr = position[None]
    cos, sin = rope_angles(pos_arr, m.qk_rope_dim, cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new, cos, sin)[:, :, 0, :]  # [B,1,rope]
    ckv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, position, 0))
    ckr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, position, 0))
    cpos = jax.lax.dynamic_update_slice(
        cache["pos"], pos_arr.astype(jnp.int32), (position,)
    )
    q = (x @ p["wq"]).reshape(B, 1, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, cos, sin)
    # absorb: q_lat[b,1,h,R] = q_nope . W_uk^T
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (
        jnp.einsum("bshr,btr->bsht", q_lat, ckv)
        + jnp.einsum("bshn,btn->bsht", q_rope, ckr)
    ) * scale
    mask = (cpos >= 0) & (cpos <= position)
    s = jnp.where(mask[None, None, None, :], s.astype(jnp.float32), NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bsht,btr->bshr", pattn, ckv)  # [B,1,H,R]
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv).reshape(B, 1, H * m.v_head_dim)
    return out @ p["wo"], {"c_kv": ckv, "k_rope": ckr, "pos": cpos}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)


def cross_attn_forward(p, cfg: ModelConfig, x: jax.Array, enc: jax.Array) -> jax.Array:
    H, hd = cfg.n_heads, cfg.head_dim
    B, S, _ = x.shape
    T = enc.shape[1]
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(enc @ p["wk"], H, hd)
    v = _split_heads(enc @ p["wv"], H, hd)
    qg = q.reshape(B, S, H, 1, hd)
    pos_q = jnp.arange(S, dtype=jnp.int32)
    pos_k = jnp.arange(T, dtype=jnp.int32)
    out = chunked_attention(qg, k, v, pos_q, pos_k, causal=False, window=None)
    return out.reshape(B, S, H * hd) @ p["wo"]
