"""Mixture-of-Experts FFN: top-k router, capacity-based dispatch
(GShard/Switch style), shared experts (DeepSeek-V2), expert-parallel
sharding over the "model" mesh axis, and the load-balance auxiliary loss.

Dispatch is *group-local*: tokens are grouped per sequence (the batch
dim, sharded over "data"), each group gets its own capacity
``cap = top_k * S * capacity_factor / E``, and positions are computed by
a sort within the group — so dispatch buffers scale with the per-shard
token count, not the global batch (GShard semantics), and the only
cross-shard communication is the expert-parallel einsum itself.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .act_sharding import constrain
from .config import ModelConfig
from .params import ParamSpec


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    s = D ** -0.5
    specs = {
        "router": ParamSpec((D, E), ("embed", None), s),
        "w_gate": ParamSpec((E, D, F), ("experts", "embed", "expert_ffn"), s),
        "w_up": ParamSpec((E, D, F), ("experts", "embed", "expert_ffn"), s),
        "w_down": ParamSpec((E, F, D), ("experts", "expert_ffn", "embed"), F ** -0.5),
    }
    if m.n_shared:
        Fs = m.d_shared or F
        specs.update(
            sh_gate=ParamSpec((D, m.n_shared * Fs), ("embed", "ffn"), s),
            sh_up=ParamSpec((D, m.n_shared * Fs), ("embed", "ffn"), s),
            sh_down=ParamSpec((m.n_shared * Fs, D), ("ffn", "embed"), Fs ** -0.5),
        )
    return specs


def _dispatch_group(xf, logits, k: int, E: int, cap: int):
    """Group-local top-k dispatch.  xf [T, D]; logits [T, E] fp32.

    Positions are computed by one joint sort over all T*k assignments;
    the scatter itself runs per top-k slot with [T, D] updates (k-x
    smaller live buffers than a flat [T*k, D] formulation) and bf16
    gates — see EXPERIMENTS.md §Perf (qwen3 iteration 1)."""
    T, D = xf.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_idx = gate_idx.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_idx, stable=True)
    sorted_e = flat_idx[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - first
    inv = jnp.argsort(order, stable=True)
    pos = pos_sorted[inv].reshape(T, k)
    keep = pos < cap
    gate = (gate_vals * keep).astype(xf.dtype)  # [T, k] bf16
    safe_pos = jnp.where(keep, pos, cap - 1)
    buf = jnp.zeros((E, cap, D), xf.dtype)
    for slot in range(k):
        buf = buf.at[gate_idx[:, slot], safe_pos[:, slot]].add(
            jnp.where(keep[:, slot, None], xf, 0))
    return buf, (gate_idx, safe_pos, gate, probs)


def moe_forward(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Capacity-dropped tokens pass through
    the residual (output 0 from the routed path)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    cap = int(max(1, (k * S * m.capacity_factor) // E))
    logits = (x @ p["router"]).astype(jnp.float32)  # [B, S, E]

    buf, combine = jax.vmap(
        lambda xf, lg: _dispatch_group(xf, lg, k, E, cap))(x, logits)
    # buf: [B(groups->data), E, cap, D]
    buf = constrain(buf, P("data", "model", None, None))
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", g * u, p["w_down"])  # [B, E, cap, D]
    y = constrain(y, P("data", "model", None, None))

    def _combine_group(y_g, info):
        gate_idx, safe_pos, gate, _ = info
        out = jnp.zeros((S, D), y_g.dtype)
        for slot in range(k):
            out = out + y_g[gate_idx[:, slot], safe_pos[:, slot]] * gate[:, slot, None]
        return out

    out = jax.vmap(_combine_group)(y, combine)  # [B, S, D]

    # ---- load-balance aux loss (Switch): E * sum_e f_e * p_e (global)
    probs = combine[3]  # [B, S, E]
    me = probs.reshape(-1, E).mean(axis=0)
    top1 = combine[0][..., 0].reshape(-1)  # [B*S]
    ce = jax.nn.one_hot(top1, E, dtype=jnp.float32).mean(axis=0)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    if m.n_shared:
        sg = jax.nn.silu(x @ p["sh_gate"])
        su = x @ p["sh_up"]
        out = out + (sg * su) @ p["sh_down"]
    return out.astype(x.dtype), aux
