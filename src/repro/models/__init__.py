from .config import ModelConfig, MoEConfig, MLAConfig, SSMConfig, EncoderConfig
from .params import (
    ParamSpec,
    ShardingRules,
    FSDP_TP,
    FSDP_TP_PODS,
    SILO_TP,
    init_params,
    abstract_params,
    param_pspecs,
    count_params,
)
from .transformer import (
    model_specs,
    forward,
    loss_fn,
    init_cache,
    decode_step,
    encode,
    prefill,
    prefill_cross_cache,
)
