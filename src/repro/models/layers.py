"""Elementary layers: RMSNorm, RoPE, MLPs, embeddings (pure functions)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight).astype(dtype)


def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embeddings.  positions: [...]; returns
    cos,sin of shape [..., dim//2]."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array, w_down: jax.Array,
             b_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up) + b_up)
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def sinusoidal_positions(seq_len: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-level CE, mean over all positions. logits [...,V], labels [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
