"""Online topology re-design controller.

Closes the loop the paper leaves open: the designed overlay is
throughput-optimal for the network *as measured*, so when the network
drifts (failure, degradation, straggler, churn) the measured round time
detaches from the max-plus prediction.  The controller

1. **monitors** realized round durations against the simulated max-plus
   round-time profile of the active overlay (a rolling window, a
   two-sided deviation ratio — slow rounds mean congestion, suspiciously
   fast rounds mean vanished arcs — and a strike count to ignore
   one-off jitter);
2. on sustained regression, pulls a fresh connectivity estimate from the
   measurement service and **re-designs**: every Table 1 designer,
   hundreds of seeded ring perturbations scored in one call to the
   batched max-plus engine (`[B, N, N]` Karp — re-scoring ~256 overlays
   at N=22 takes well under a second, cheap enough to live inside the
   training loop), plus the device-side sparse-rewire hill climb
   (:func:`repro.core.topologies.search_overlays_jit`) seeded from the
   *incumbent* overlay — local arc repairs the ring/tree candidate
   families cannot express;
3. **explains** the winning overlay's bottleneck via the critical
   circuit — edge-list extraction
   (:func:`repro.core.maxplus_sparse.critical_circuit_sparse`), so the
   explanation never densifies at scale;
4. **emits** the new :class:`~repro.fed.gossip.GossipPlan` through
   :func:`~repro.fed.topology_runtime.plan_from_overlay` into a
   :class:`~repro.fed.gossip.PlanSlot`, the hot-swap hook the training
   loop re-lowers its jitted step from.

Randomized schedules are in the loop too: with
:attr:`ControllerConfig.matcha_budgets` set, re-design also prices a
MATCHA plan distribution (one batched budgets × seeds sweep) and — under
``schedule_family="matcha"`` — re-fits it to every fresh estimate,
hot-swapping fixed ↔ randomized through a
:class:`~repro.fed.gossip.ScheduleSlot` (whose per-round sampled plans
need no step re-lowering: the consensus matrix is a traced input).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from ..core.delays import (
    ConnectivityGraph,
    TrainingParams,
    batched_overlay_delay_matrices,
)
from ..core.maxplus_sparse import (
    batched_overlay_delay_edges,
    critical_circuit_sparse,
)
from ..core.maxplus_vec import (
    batched_cycle_time,
    batched_is_strongly_connected,
)
from ..core.mixing import (
    OBJECTIVES,
    overlay_rho_batch,
    score_estimate,
)
from ..core.schedule import (
    FixedSchedule,
    Schedule,
    ScheduleEstimate,
    ScheduleInfeasibleError,
    design_matcha_schedule,
)
from ..core.topologies import Overlay, design_overlay, search_overlays_jit
from ..fed.gossip import GossipPlan, MembershipSlot, PlanSlot, ScheduleSlot
from ..fed.topology_runtime import plan_from_overlay
from ..obs import metrics as obs_metrics
from ..obs.events import FlightRecorder
from ..obs.spans import span_fn

Arc = Tuple[int, int]


@dataclass(frozen=True)
class ControllerConfig:
    """Tuning knobs of :class:`OnlineTopologyController`.

    ``rewire_restarts``/``rewire_steps`` budget the device-side
    sparse-rewire search (:func:`repro.core.topologies.search_overlays_jit`)
    that extends the re-design candidate pool beyond rings and the
    designer heuristics with local edge rewires of the *incumbent*
    overlay; ``rewire_restarts=0`` disables it (e.g. on jax-free hosts).
    """

    window: Optional[int] = None  # rolling-mean span; None = one ring period (N)
    regression_ratio: float = 1.04  # measured / predicted-profile max triggering a strike
    patience: int = 2  # consecutive regressed rounds before re-design
    cooldown_rounds: int = 12  # min rounds between re-designs
    warmup_rounds: Optional[int] = None  # rounds ignored after init/swap; None = window
    calibration_rounds: int = 64  # simulated rounds behind the expected profile
    n_candidates: int = 256  # seeded ring perturbations per re-design
    designers: Tuple[str, ...] = ("ring", "ring_2opt", "mst", "delta_mbst")
    rewire_restarts: int = 8  # parallel sparse-rewire climb states (0 = off)
    rewire_steps: int = 48  # device-side rewire moves per restart
    # Which engine prices the rewire search's proposals: "jit" (device
    # climb, full Karp per proposal), "delta" (host climb, incremental
    # DeltaPricer certificates), or "auto" (size-dispatched — delta
    # above ~384 silos, where per-proposal Karp dominates).
    rewire_engine: str = "auto"  # "auto" | "jit" | "delta"
    # Randomized-schedule candidates: with a nonempty budget tuple every
    # re-design also prices a MATCHA schedule at these budgets (one
    # batched sweep).  Under ``schedule_family="auto"`` it competes with
    # the fixed pool on Monte-Carlo τ̄ — which it rarely wins, since RING
    # tends to dominate cycle time (the paper's headline result); under
    # ``schedule_family="matcha"`` the operator has pinned the family
    # (for its mixing-per-traffic properties) and re-design *re-fits* the
    # distribution — matchings from the fresh estimate, budget re-swept —
    # falling back to the fixed pool only when no matcha schedule is
    # feasible.  Empty budgets (default) keep the controller
    # fixed-overlay-only.
    schedule_family: str = "auto"  # "auto" | "matcha"
    matcha_budgets: Tuple[float, ...] = ()
    matcha_rounds: int = 150  # Monte-Carlo rounds per pricing chain
    matcha_seeds: Tuple[int, ...] = (0, 1, 2)  # chains per budget (CI)
    calibration_seeds: Tuple[int, ...] = (0, 1, 2)  # randomized-profile envelope
    # What re-design optimizes (repro.core.mixing.OBJECTIVES): "tau"
    # ranks every candidate on cycle time alone (the paper's Table 1
    # regime); "time_to_eps" prices each candidate's consensus
    # contraction rho as well and ranks on the composite wall-clock-
    # to-epsilon score tau / -log(rho) — the Sect. 4 framing, under
    # which a well-mixing MATCHA can beat a sparse ring that wins
    # rounds-per-second but mixes at 1 - O(1/N^2) per round.
    objective: str = "tau"  # "tau" | "time_to_eps"
    mixing_rounds: int = 128  # sampled rounds behind E[W^T W] pricing
    seed: int = 0


@dataclass(frozen=True)
class Redesign:
    """One controller actuation, with its audit trail."""

    round_idx: int
    overlay: Optional[Overlay]  # None when a randomized schedule won
    plan: Optional[GossipPlan]  # round-0 plan for randomized schedules
    predicted_tau_ms: float
    measured_ms: float  # rolling round-duration estimate that tripped it
    n_candidates: int  # overlays scored by the batched engine
    elapsed_s: float  # wall time of the whole re-design step
    bottleneck: Tuple[int, ...]  # critical circuit of the new overlay
    expected_window_ms: float = float("nan")  # calibrated profile at trip time
    drift: float = float("nan")  # measured / expected - 1 at trip time
    schedule: Optional[Schedule] = None  # the winning schedule (always set)
    membership: Optional[Tuple[int, ...]] = None  # new active set, when churn
    # triggered this actuation (None: same universe as the previous design)
    rho: float = float("nan")  # winner's consensus contraction (NaN when
    # mixing was not priced, i.e. objective="tau")
    objective: str = "tau"  # the objective this actuation optimized


def search_ring_candidates(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    n_candidates: int,
    rng: np.random.Generator,
) -> Optional[Overlay]:
    """Score ``n_candidates`` random ring tours in one batched engine call.

    Rings are the paper's asymptotically dominant family (Prop. 3.3), and
    as N-arc overlays they are the cheapest candidates to mass-produce;
    the designer heuristics cover the tree-shaped part of the space.
    Returns the best strongly-connected tour (None if every tour hits an
    unrouted pair — e.g. a partitioned network)."""
    silos = list(gc.silos)
    n = len(silos)
    if n < 2 or n_candidates == 0:
        return None
    arcs = [e for e in gc.edges() if e[0] != e[1]]
    arc_index = {a: k for k, a in enumerate(arcs)}
    masks = np.zeros((n_candidates, len(arcs)), dtype=bool)
    tours: List[Optional[List[Arc]]] = []
    for b in range(n_candidates):
        perm = rng.permutation(n)
        tour = [silos[p] for p in perm]
        hops = [(tour[k], tour[(k + 1) % n]) for k in range(n)]
        rows = [arc_index.get(h) for h in hops]
        if any(r is None for r in rows):
            tours.append(None)  # tour uses an unrouted pair; leave mask empty
            continue
        masks[b, rows] = True
        tours.append(hops)
    W = batched_overlay_delay_matrices(gc, tp, arcs, masks)
    valid = np.array([t is not None for t in tours])
    strong = batched_is_strongly_connected(W) & valid
    taus = np.where(strong, batched_cycle_time(W), np.inf)
    k = int(np.argmin(taus))
    if not np.isfinite(taus[k]):
        return None
    return Overlay(
        name="ring_search", edges=tuple(tours[k]), cycle_time_ms=float(taus[k])
    )


def design_best_overlay(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_candidates: int = 256,
    designers: Sequence[str] = ControllerConfig.designers,
    rng: Optional[np.random.Generator] = None,
    incumbent: Optional[Overlay] = None,
    rewire_restarts: int = 0,
    rewire_steps: int = 48,
    rewire_engine: str = "auto",
) -> Tuple[Overlay, int]:
    """(best overlay, number of candidates scored) on the given estimate.

    Candidates = each designer heuristic (skipping any that cannot run on
    the current graph, e.g. δ-MBST on a partitioned estimate), the
    batched random-ring search, and — when ``rewire_restarts > 0`` — the
    device-side sparse-rewire hill climb seeded from ``incumbent``
    (:func:`repro.core.topologies.search_overlays_jit`), which explores
    local repairs of the running overlay the ring/tree families cannot
    express.  The rewire search is skipped silently if jax is missing."""
    candidates, scored = _overlay_candidates(
        gc,
        tp,
        n_candidates=n_candidates,
        designers=designers,
        rng=rng,
        incumbent=incumbent,
        rewire_restarts=rewire_restarts,
        rewire_steps=rewire_steps,
        rewire_engine=rewire_engine,
    )
    if not candidates:
        raise ValueError("no feasible overlay candidate on the current estimate")
    return min(candidates, key=lambda ov: ov.cycle_time_ms), scored


def _overlay_candidates(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_candidates: int = 256,
    designers: Sequence[str] = ControllerConfig.designers,
    rng: Optional[np.random.Generator] = None,
    incumbent: Optional[Overlay] = None,
    rewire_restarts: int = 0,
    rewire_steps: int = 48,
    rewire_engine: str = "auto",
) -> Tuple[List[Overlay], int]:
    """The fixed-overlay candidate pool: (feasible candidates, number of
    overlays scored).  Shared by :func:`design_best_overlay` (τ argmin)
    and :func:`design_schedule_portfolio` (which keeps the whole pool so
    every candidate can be priced under any objective)."""
    rng = np.random.default_rng(0) if rng is None else rng
    candidates: List[Overlay] = []
    scored = 0
    for kind in designers:
        try:
            candidates.append(design_overlay(kind, gc, tp))
            scored += 1
        except (ValueError, KeyError):
            continue
    ring = search_ring_candidates(gc, tp, n_candidates, rng)
    scored += n_candidates
    if ring is not None:
        candidates.append(ring)
    if rewire_restarts > 0:
        try:
            candidates.append(
                search_overlays_jit(
                    gc,
                    tp,
                    n_restarts=rewire_restarts,
                    n_steps=rewire_steps,
                    seed=int(rng.integers(1 << 31)),
                    incumbent=incumbent,
                    engine=rewire_engine,
                )
            )
            scored += rewire_restarts * rewire_steps
        except ImportError:
            pass
        except ValueError:
            pass
    return candidates, scored


def design_schedule_portfolio(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_candidates: int = 256,
    designers: Sequence[str] = ControllerConfig.designers,
    rng: Optional[np.random.Generator] = None,
    incumbent: Optional[Overlay] = None,
    rewire_restarts: int = 0,
    rewire_steps: int = 48,
    rewire_engine: str = "auto",
    matcha_budgets: Sequence[float] = (),
    matcha_rounds: int = 150,
    matcha_seeds: Sequence[int] = (0, 1, 2),
    sample_seed: int = 0,
    objective: str = "tau",
    mixing_rounds: int = 128,
) -> Tuple[List[Tuple[Schedule, ScheduleEstimate]], int]:
    """The whole priced candidate portfolio: ([(schedule, estimate)],
    number of candidates scored).

    Every feasible fixed candidate (designers + ring search + sparse
    rewire) enters as a :class:`FixedSchedule` with its exact Karp τ;
    with a nonempty ``matcha_budgets`` the winning MATCHA budget enters
    too (one batched budgets × seeds sweep).  Under
    ``objective="time_to_eps"`` each estimate also carries its ρ — the
    fixed pool's deployed-matrix contractions priced in *one* batched
    SVD (:func:`repro.core.mixing.overlay_rho_batch`), MATCHA's expected
    contraction from its own sampled activation rows — so callers can
    scalarize (:func:`repro.core.mixing.score_estimate`) or keep the
    (τ, ρ) Pareto frontier (:func:`repro.core.mixing.pareto_frontier`).
    Under ``objective="tau"`` ρ stays NaN and no spectral cost is paid.
    """
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; one of {OBJECTIVES}"
        )
    rng = np.random.default_rng(0) if rng is None else rng
    overlays, scored = _overlay_candidates(
        gc,
        tp,
        n_candidates=n_candidates,
        designers=designers,
        rng=rng,
        incumbent=incumbent,
        rewire_restarts=rewire_restarts,
        rewire_steps=rewire_steps,
        rewire_engine=rewire_engine,
    )
    if objective == "time_to_eps" and overlays:
        rhos = overlay_rho_batch(
            overlays, gc.num_silos, silos=tuple(gc.silos)
        )
    else:
        rhos = np.full(len(overlays), float("nan"), dtype=np.float64)
    portfolio: List[Tuple[Schedule, ScheduleEstimate]] = [
        (
            FixedSchedule(ov),
            ScheduleEstimate(
                tau_ms=ov.cycle_time_ms,
                ci95_ms=0.0,
                per_seed_ms=(ov.cycle_time_ms,),
                rho=float(rho),
            ),
        )
        for ov, rho in zip(overlays, rhos)
    ]
    if matcha_budgets:
        try:
            sched, est = design_matcha_schedule(
                gc,
                tp,
                budgets=tuple(matcha_budgets),
                rounds=matcha_rounds,
                seeds=tuple(matcha_seeds),
                sample_seed=sample_seed,
                objective=objective,
                mixing_rounds=mixing_rounds,
            )
            scored += len(matcha_budgets) * len(matcha_seeds)
            portfolio.append((sched, est))
        except ScheduleInfeasibleError:  # no routable pairs on this estimate
            pass
    return portfolio, scored


def design_best_schedule(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_candidates: int = 256,
    designers: Sequence[str] = ControllerConfig.designers,
    rng: Optional[np.random.Generator] = None,
    incumbent: Optional[Overlay] = None,
    rewire_restarts: int = 0,
    rewire_steps: int = 48,
    rewire_engine: str = "auto",
    matcha_budgets: Sequence[float] = (),
    matcha_rounds: int = 150,
    matcha_seeds: Sequence[int] = (0, 1, 2),
    sample_seed: int = 0,
    objective: str = "tau",
    mixing_rounds: int = 128,
) -> Tuple[Schedule, int]:
    """(best schedule, number of candidates scored): the schedule-valued
    superset of :func:`design_best_overlay`.

    Scalarizes :func:`design_schedule_portfolio` under ``objective``:
    ``"tau"`` compares candidates on cycle time alone (randomized
    schedules on mean Monte-Carlo τ̄ — which they rarely win, the
    paper's headline result); ``"time_to_eps"`` on the composite
    ``τ / −log(ρ)``, under which MATCHA's mixing-per-traffic advantage
    is finally visible to the auto-family arbitration.  Exact ties go
    to the fixed pool (listed first).
    """
    portfolio, scored = design_schedule_portfolio(
        gc,
        tp,
        n_candidates=n_candidates,
        designers=designers,
        rng=rng,
        incumbent=incumbent,
        rewire_restarts=rewire_restarts,
        rewire_steps=rewire_steps,
        rewire_engine=rewire_engine,
        matcha_budgets=matcha_budgets,
        matcha_rounds=matcha_rounds,
        matcha_seeds=matcha_seeds,
        sample_seed=sample_seed,
        objective=objective,
        mixing_rounds=mixing_rounds,
    )
    if not portfolio:
        raise ValueError("no feasible overlay candidate on the current estimate")
    best, _ = min(portfolio, key=lambda c: score_estimate(c[1], objective))
    return best, scored


class OnlineTopologyController:
    """Monitor -> detect -> re-design -> hot-swap, one overlay at a time.

    ``connectivity_provider`` is the measurement service: it returns the
    current connectivity estimate (restricted to active silos) whenever
    the controller decides to re-design.  In the simulator it is backed by
    the scenario's current epoch; in a deployment it would be the same
    probing that produced the initial measurements (Sect. 2.2).
    """

    def __init__(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        overlay: Overlay,
        *,
        config: ControllerConfig = ControllerConfig(),
        connectivity_provider: Optional[Callable[[], ConnectivityGraph]] = None,
        plan_slot: Optional[PlanSlot] = None,
        schedule_slot: Optional[ScheduleSlot] = None,
        schedule: Optional[Schedule] = None,
        membership_slot: Optional[MembershipSlot] = None,
        membership_provider: Optional[Callable[[], Sequence[int]]] = None,
        recorder: Optional[FlightRecorder] = None,
        silo_names: Optional[Sequence[str]] = None,
    ):
        """``overlay`` is the initial (or fallback) fixed overlay; pass
        ``schedule`` to start on a randomized one instead (``overlay``
        still seeds the incumbent-local rewire search at re-design).
        ``schedule_slot`` is the schedule-valued hot-swap hook — it
        receives *every* winner, fixed or randomized; ``plan_slot`` keeps
        the legacy fixed-plan interface and is skipped (with an audit
        note) when a randomized schedule wins.

        ``membership_provider`` is the control-plane signal of elastic
        membership: the current active silo set (in a deployment, the
        consortium's registration service; in the simulator, the
        scenario's current epoch).  Unlike congestion — which must be
        *inferred* from round timings through the strike detector — churn
        is *known*, so a membership change triggers an immediate
        re-design over the surviving universe, bypassing warmup, strikes,
        and cooldown.  The new active set is published through
        ``membership_slot`` (see :class:`~repro.fed.gossip.MembershipSlot`)
        *before* the plan/schedule slots are resized onto it, so the
        training loop always observes membership first and can rebuild
        its mesh/state before re-lowering.

        ``recorder`` (a :class:`repro.obs.events.FlightRecorder`) makes
        every decision externally auditable: a ``regression`` record when
        the strike detector trips, a ``redesign`` record per actuation
        (with the critical circuit, by silo name when ``silo_names`` maps
        labels to sites), ``membership`` and ``swap`` records as the
        slots move.  ``None`` (the default) emits nothing."""
        self.tp = tp
        self.config = config
        self.gc = gc
        self._gc_full = gc  # launch-time estimate over the full universe
        self.overlay = overlay
        self.schedule: Schedule = (
            schedule if schedule is not None else FixedSchedule(overlay)
        )
        if self.schedule.is_randomized:
            est = self.schedule.price(
                gc, tp, rounds=config.matcha_rounds,
                seeds=(config.matcha_seeds[0],),
            )
            self.predicted_tau_ms = est.tau_ms
        else:
            self.predicted_tau_ms = overlay.cycle_time_ms
        self.connectivity_provider = connectivity_provider
        self.plan_slot = plan_slot
        self.schedule_slot = schedule_slot
        self.membership_slot = membership_slot
        self.membership_provider = membership_provider
        self._active: Tuple[int, ...] = (
            membership_slot.active
            if membership_slot is not None
            else tuple(sorted(gc.silos))
        )
        self.plan = plan_from_overlay(overlay, len(gc.silos), silos=gc.silos)
        if plan_slot is not None and plan_slot.version == 0:
            plan_slot.swap(self.plan, label="controller-init")
        if schedule_slot is not None and schedule_slot.version == 0:
            schedule_slot.swap_schedule(self.schedule, label="controller-init")
        self._rng = np.random.default_rng(config.seed)
        self._window_size = config.window or len(gc.silos)
        self._warmup = (
            config.warmup_rounds
            if config.warmup_rounds is not None
            else self._window_size
        )
        self._window: Deque[float] = deque(maxlen=self._window_size)
        self._window_sum = 0.0
        self._strikes = 0
        self._round = 0
        self._rounds_since_swap = 0
        self._last_redesign = -config.cooldown_rounds
        self.redesigns: List[Redesign] = []
        self.recorder = recorder
        self._silo_names = list(silo_names) if silo_names is not None else None
        # Last observed deviation, exposed so the launcher can stamp
        # per-round drift onto "round" trace records without recomputing
        # the rolling window.
        self.last_measured_ms: Optional[float] = None
        self.last_drift: Optional[float] = None
        self._calibrate()

    @span_fn("controller.calibrate")
    def _calibrate(self) -> None:
        """Expected rolling round-time profile of the active *schedule* on
        the current estimate, from the Eq. 4 recursion itself.

        Max-plus round durations are not constant — they settle into a
        periodic regime oscillating around tau — so comparing a measured
        rolling mean against bare tau false-alarms on healthy networks.
        Simulating the recursion gives the *whole* predicted profile; the
        detector thresholds against its worst settled rolling mean, which
        lets ``regression_ratio`` sit a few percent above 1.  Randomized
        schedules add sampling variance on top of the max-plus transient,
        so their band is the envelope over several seeded rollouts
        (``calibration_seeds``)."""
        w = self._window_size
        rounds = max(self.config.calibration_rounds, 3 * w)
        seeds = (
            self.config.calibration_seeds
            if self.schedule.is_randomized
            else (0,)
        )
        profiles = self.schedule.simulate_rounds_batch(
            self.gc, self.tp, rounds, seeds
        )  # all seed chains in one engine call
        maxes, mins = [], []
        for durations in profiles:
            rolling = np.convolve(durations, np.ones(w) / w, mode="valid")
            settled = rolling[min(w, len(rolling) - 1) :]
            maxes.append(settled.max())
            mins.append(settled.min())
        self.expected_window_ms = float(max(maxes))
        self.expected_window_min_ms = float(min(mins))

    @property
    def measured_ms(self) -> Optional[float]:
        if len(self._window) < self._window_size:
            return None
        # O(1) running sum: this property is read every observed round,
        # and an O(window) np.mean over the deque showed up in the
        # controller hot path once rounds got cheap (repro-lint sweep).
        return self._window_sum / self._window_size

    def _window_push(self, duration_ms: float) -> None:
        if len(self._window) == self._window_size:
            self._window_sum -= self._window[0]  # deque evicts leftmost
        self._window.append(duration_ms)
        self._window_sum += duration_ms

    def observe_round(self, duration_ms: float) -> Optional[Redesign]:
        """Feed one realized round duration; maybe returns an actuation."""
        self._round += 1
        self._rounds_since_swap += 1
        if self.membership_provider is not None:
            active = tuple(sorted(self.membership_provider()))
            if active != self._active:
                # Churn is control-plane knowledge, not a timing anomaly:
                # re-design immediately over the surviving universe (no
                # warmup / strikes / cooldown — a departed silo must stop
                # being mixed with, a joiner must start).
                measured = self.measured_ms
                return self._redesign(
                    measured if measured is not None else duration_ms,
                    membership=active,
                )
        if self._rounds_since_swap <= self._warmup:
            return None  # swap transient: not the network's fault
        self._window_push(duration_ms)
        measured = self.measured_ms
        self.last_measured_ms = measured
        self.last_drift = (
            measured / self.expected_window_ms - 1.0
            if measured is not None and self.expected_window_ms
            else None
        )
        if measured is None:
            return None
        # Two-sided: slower-than-predicted means congestion/failure/straggler;
        # *faster*-than-predicted means arcs silently vanished (e.g. a silo
        # left and the ring broke) — rounds speed up while mixing stops.
        # Either way the max-plus model is stale and the overlay needs
        # re-designing on a fresh estimate.
        ratio = self.config.regression_ratio
        deviates = (
            measured > ratio * self.expected_window_ms
            or measured < self.expected_window_min_ms / ratio
        )
        self._strikes = self._strikes + 1 if deviates else 0
        if self._strikes < self.config.patience:
            return None
        if self._round - self._last_redesign < self.config.cooldown_rounds:
            return None
        if self.recorder is not None:
            self.recorder.emit(
                "regression",
                round_idx=self._round,
                measured_ms=measured,
                expected_window_ms=self.expected_window_ms,
                drift=self.last_drift,
                strikes=self._strikes,
            )
        obs_metrics.counter("controller.regressions").inc()
        return self._redesign(measured)

    def _sparse_bottleneck(self, edges) -> Tuple[int, ...]:
        """Critical circuit of an edge list on the current estimate via
        the edge-list extractor — no dense [N, N] materialization, so the
        explanation step scales with the controller (the dense extractor
        stays as the tested oracle)."""
        arcs = [e for e in edges if e[0] != e[1]]
        if not arcs:
            return ()
        eb = batched_overlay_delay_edges(
            self.gc, self.tp, arcs, np.ones((1, len(arcs)), dtype=bool)
        )
        _, circ = critical_circuit_sparse(
            eb.src[0], eb.dst[0], eb.w[0], self.gc.num_silos
        )
        return tuple(self.gc.silos[c] for c in circ)

    def _names(self, labels: Sequence[int]) -> List[str]:
        """Silo labels -> site names, where the launch-time mapping has
        one (labels index the full universe, so it survives churn)."""
        names = self._silo_names
        return [
            names[s] if names is not None and 0 <= s < len(names) else str(s)
            for s in labels
        ]

    @span_fn("controller.redesign")
    def _redesign(
        self, measured: float, membership: Optional[Tuple[int, ...]] = None
    ) -> Redesign:
        t0 = time.perf_counter()
        expected = self.expected_window_ms  # profile that tripped (pre-recal)
        drift = measured / expected - 1.0 if expected else float("nan")
        if self.connectivity_provider is not None:
            self.gc = self.connectivity_provider()
        elif membership is not None:
            # no measurement service: restrict the launch-time estimate
            # to the reported membership so the designed plan/schedule
            # spans exactly the silos the MembershipSlot publishes (the
            # full-universe snapshot also covers rejoining silos)
            from .events import active_subgraph

            self.gc = active_subgraph(self._gc_full, membership)
        if membership is not None and membership != self._active:
            old_active = self._active
            self._active = membership
            if self.membership_slot is not None:
                # Publish membership before resizing plan/schedule slots:
                # the training loop rebuilds its mesh/state off this.
                self.membership_slot.swap(
                    membership,
                    label=(
                        f"round{self._round}: {len(old_active)} -> "
                        f"{len(membership)} silos"
                    ),
                )
            if self.recorder is not None:
                self.recorder.emit(
                    "membership",
                    step=self._round,
                    version=(
                        self.membership_slot.version
                        if self.membership_slot is not None
                        else -1
                    ),
                    n_before=len(old_active),
                    n_after=len(membership),
                    left=self._names(sorted(set(old_active) - set(membership))),
                    joined=self._names(
                        sorted(set(membership) - set(old_active))
                    ),
                )
        else:
            membership = None  # unchanged universe: not a membership event
        best_sched: Optional[Schedule] = None
        sched_tau: Optional[float] = None
        sched_est: Optional[ScheduleEstimate] = None
        scored = 0
        if self.config.schedule_family == "matcha" and self.config.matcha_budgets:
            try:  # family pinned: re-fit the distribution to the estimate
                best_sched, est = design_matcha_schedule(
                    self.gc,
                    self.tp,
                    budgets=self.config.matcha_budgets,
                    rounds=self.config.matcha_rounds,
                    seeds=self.config.matcha_seeds,
                    sample_seed=int(self._rng.integers(1 << 31)),
                    objective=self.config.objective,
                    mixing_rounds=self.config.mixing_rounds,
                )
                sched_tau = est.tau_ms
                sched_est = est
                scored = len(self.config.matcha_budgets) * len(
                    self.config.matcha_seeds
                )
            except ScheduleInfeasibleError as e:
                best_sched = None  # infeasible: fall back to the fixed pool
                if self.schedule_slot is not None:  # leave an audit trail
                    self.schedule_slot.history.append(
                        (
                            self.schedule_slot.version,
                            f"round{self._round}: matcha re-fit infeasible "
                            f"({e}); using the fixed pool",
                        )
                    )
        if best_sched is None:
            portfolio, scored = design_schedule_portfolio(
                self.gc,
                self.tp,
                n_candidates=self.config.n_candidates,
                designers=self.config.designers,
                rng=self._rng,
                incumbent=self.overlay,
                rewire_restarts=self.config.rewire_restarts,
                rewire_steps=self.config.rewire_steps,
                rewire_engine=self.config.rewire_engine,
                matcha_budgets=self.config.matcha_budgets,
                matcha_rounds=self.config.matcha_rounds,
                matcha_seeds=self.config.matcha_seeds,
                sample_seed=int(self._rng.integers(1 << 31)),
                objective=self.config.objective,
                mixing_rounds=self.config.mixing_rounds,
            )
            if not portfolio:
                raise ValueError(
                    "no feasible overlay candidate on the current estimate"
                )
            best_sched, sched_est = min(
                portfolio,
                key=lambda c: score_estimate(c[1], self.config.objective),
            )
            if not isinstance(best_sched, FixedSchedule):
                sched_tau = sched_est.tau_ms
        if isinstance(best_sched, FixedSchedule):
            best = best_sched.overlay
            name = best.name
            predicted = best.cycle_time_ms
            bottleneck = self._sparse_bottleneck(best.edges)
            plan = plan_from_overlay(
                best, len(self.gc.silos), silos=self.gc.silos
            )
        else:  # randomized winner: τ̄ of the distribution, not one Karp value
            best = None
            name = f"{best_sched.name}@{best_sched.budget:g}"
            predicted = (
                sched_tau
                if sched_tau is not None  # reuse the sweep's estimate
                else best_sched.price(
                    self.gc, self.tp, rounds=self.config.matcha_rounds,
                    seeds=(self.config.matcha_seeds[0],),
                ).tau_ms
            )
            # Explain with the support's circuit: every matching active —
            # the links the distribution can be throttled by at budget 1.
            bottleneck = self._sparse_bottleneck(
                best_sched._arc_pool(self.gc)[0]
            )
            plan = None
        elapsed = time.perf_counter() - t0
        label = f"round{self._round}:{name}"
        if self.schedule_slot is not None:
            # Re-pinning the label -> mesh-position order (silos=...) is
            # only sound when the MembershipSlot swap above published the
            # new universe to the training loop; without one the mesh
            # axis is sized at launch and cannot follow.
            resize = membership is not None and self.membership_slot is not None
            if resize or len(self.gc.silos) == self.schedule_slot.plan.n_silos:
                self.schedule_slot.swap_schedule(
                    best_sched,
                    label=label,
                    silos=tuple(self.gc.silos) if resize else None,
                )
                if self.recorder is not None:
                    self.recorder.emit(
                        "swap",
                        slot="schedule",
                        version=self.schedule_slot.version,
                        label=label,
                        resized=resize,
                    )
                if plan is None:
                    plan = self.schedule_slot.plan
            else:
                # Churn changed the silo count but no MembershipSlot can
                # tell the training loop to rebuild; keep the running
                # schedule and leave an audit note (same discipline as
                # the plan slot below).
                self.schedule_slot.history.append(
                    (
                        self.schedule_slot.version,
                        f"{label} NOT swapped ({len(self.gc.silos)} != "
                        f"{self.schedule_slot.plan.n_silos} silos without "
                        f"a MembershipSlot)",
                    )
                )
        if self.plan_slot is not None:
            if best is None:
                # The fixed-plan slot cannot follow a plan *distribution*;
                # callers that want randomized actuation listen on a
                # ScheduleSlot.  Audit-note it, as for churn below.
                self.plan_slot.history.append(
                    (
                        self.plan_slot.version,
                        f"{label} NOT swapped (randomized schedule needs "
                        "a ScheduleSlot)",
                    )
                )
            elif plan.n_silos == self.plan_slot.plan.n_silos:
                self.plan_slot.swap(plan, label=label)
                if self.recorder is not None:
                    self.recorder.emit(
                        "swap",
                        slot="plan",
                        version=self.plan_slot.version,
                        label=label,
                        resized=False,
                    )
            elif membership is not None and self.membership_slot is not None:
                # Elastic membership: the MembershipSlot swap above (this
                # actuation's, not a mere slot existing) told the training
                # loop to rebuild mesh/state; the resized plan rides the
                # same actuation.
                self.plan_slot.swap(plan, label=label, allow_resize=True)
                if self.recorder is not None:
                    self.recorder.emit(
                        "swap",
                        slot="plan",
                        version=self.plan_slot.version,
                        label=label,
                        resized=True,
                    )
            else:
                # Churn changed the silo count but without a
                # MembershipSlot the mesh axis is sized at launch and
                # cannot follow.  Keep the old plan running and leave an
                # audit note instead of crashing the training loop from
                # inside observe_round.
                self.plan_slot.history.append(
                    (
                        self.plan_slot.version,
                        f"{label} NOT swapped "
                        f"({plan.n_silos} != {self.plan_slot.plan.n_silos} silos)",
                    )
                )
        if best is not None:
            self.overlay = best  # randomized winners keep the fixed fallback
            self.plan = plan
        self.schedule = best_sched
        self.predicted_tau_ms = predicted
        self._window.clear()
        self._window_sum = 0.0
        self._strikes = 0
        self._rounds_since_swap = 0
        self._last_redesign = self._round
        self._calibrate()
        rho = float(sched_est.rho) if sched_est is not None else float("nan")
        redesign = Redesign(
            round_idx=self._round,
            overlay=best,
            plan=plan,
            predicted_tau_ms=predicted,
            measured_ms=measured,
            n_candidates=scored,
            elapsed_s=elapsed,
            bottleneck=bottleneck,
            expected_window_ms=expected,
            drift=drift,
            schedule=best_sched,
            membership=membership,
            rho=rho,
            objective=self.config.objective,
        )
        self.redesigns.append(redesign)
        obs_metrics.counter("controller.redesigns").inc()
        obs_metrics.histogram("controller.redesign_s").observe(elapsed)
        if elapsed > 0:
            obs_metrics.gauge("controller.candidates_per_s").set(
                scored / elapsed
            )
        obs_metrics.gauge("controller.predicted_tau_ms").set(predicted)
        obs_metrics.histogram("controller.drift").observe(drift)
        if self.recorder is not None:
            self.recorder.emit(
                "redesign",
                round_idx=self._round,
                winner="fixed" if best is not None else "randomized",
                name=name,
                predicted_tau_ms=predicted,
                measured_ms=measured,
                expected_window_ms=expected,
                drift=drift,
                n_candidates=scored,
                elapsed_s=elapsed,
                bottleneck=list(bottleneck),
                bottleneck_names=self._names(bottleneck),
                membership=list(membership) if membership else None,
                # (tau, rho) co-design audit: extra fields, so traces
                # from tau-only runs stay schema-valid (NaN -> None:
                # JSON has no NaN and readers shouldn't need one).
                rho=rho if rho == rho else None,
                objective=self.config.objective,
            )
        return redesign
