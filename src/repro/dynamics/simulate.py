"""Event-driven simulator: the Eq. 4 recursion on a time-varying network.

Extends Algorithm 3 (Appendix F) from one delay matrix to the ``[E, N, N]``
stack of per-epoch Eq. 3 matrices produced by the scenario layer.  Each
round, every silo transmits with the delays of the epoch containing its
start time (rows of the effective matrix are gathered per silo — see
:func:`repro.core.maxplus_vec.timing_recursion_piecewise`), so failures
and stragglers show up as transients exactly at the event boundary.

Three entry points:

* :func:`simulate_dynamic`          — one (scenario, overlay) run with full
                                      reporting: realized round times,
                                      per-epoch empirical vs predicted
                                      cycle times, throughput loss vs the
                                      static-optimal overlay;
* :func:`simulate_scenarios_batched`— many scenarios at once through
                                      ``batched_timing_recursion_piecewise``
                                      (epoch grids padded to a common E);
* :class:`DynamicTimeline`          — a round-by-round stepper with a
                                      swappable overlay: the plant the
                                      online controller closes its loop
                                      around.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.delays import ConnectivityGraph, TrainingParams, overlay_delay_matrix
from ..core.maxplus_vec import (
    NEG_INF,
    _epoch_of,
    batched_cycle_time,
    batched_timing_recursion_piecewise,
    missing_mask,
)
from ..core.schedule import Schedule, ScheduleEstimate
from .events import NetworkEpoch, Scenario, active_subgraph

Arc = Tuple[int, int]


def _epoch_matrix(
    epoch: NetworkEpoch, tp: TrainingParams, overlay_edges: Sequence[Arc]
) -> np.ndarray:
    """Eq. 3 delay matrix of one epoch, overlay arcs filtered to the pairs
    that still exist (both endpoints active, pair routed)."""
    keep = set(epoch.active)
    arcs = [
        (i, j)
        for (i, j) in overlay_edges
        if i != j and i in keep and j in keep and epoch.gc.has_edge(i, j)
    ]
    return overlay_delay_matrix(epoch.gc, tp, arcs)


def epoch_delay_matrices(
    scenario: Scenario, tp: TrainingParams, overlay_edges: Sequence[Arc]
) -> Tuple[np.ndarray, np.ndarray, List[NetworkEpoch]]:
    """``([E, N, N] delay stack, [E] epoch starts, epochs)`` for a fixed
    overlay riding through the scenario."""
    epochs = scenario.segments()
    Ws = np.stack([_epoch_matrix(e, tp, overlay_edges) for e in epochs])
    starts = np.array([e.t_start_ms for e in epochs])
    return Ws, starts, epochs


@dataclass(frozen=True)
class DynamicRun:
    """Result of one (scenario, overlay) simulation."""

    times: np.ndarray  # [R+1, N] silo start times
    round_finish_ms: np.ndarray  # [R+1] max over silos
    round_durations_ms: np.ndarray  # [R] finish-to-finish increments
    epoch_starts_ms: np.ndarray  # [E]
    predicted_tau_ms: np.ndarray  # [E] Karp cycle time of each epoch matrix
    empirical_tau_ms: np.ndarray  # [E] realized slope inside each epoch (nan if <4 rounds)

    @property
    def num_rounds(self) -> int:
        return len(self.round_durations_ms)

    def rounds_completed_by(self, t_ms: float) -> int:
        """Max k such that every silo has started round k by ``t_ms``."""
        return int(np.searchsorted(self.round_finish_ms, t_ms, side="right")) - 1

    def throughput_loss_vs(self, tau_static_ms: float, deadline_ms: float) -> float:
        """1 - realized/ideal rounds by the deadline, against an idealized
        static network where every round costs ``tau_static_ms``."""
        ideal = deadline_ms / tau_static_ms
        return 1.0 - self.rounds_completed_by(deadline_ms) / ideal


def simulate_dynamic(
    scenario: Scenario,
    tp: TrainingParams,
    overlay_edges: Sequence[Arc],
    num_rounds: int = 200,
) -> DynamicRun:
    """Ride a *fixed* overlay through the scenario (the non-adaptive
    baseline an online controller is judged against)."""
    Ws, starts, _ = epoch_delay_matrices(scenario, tp, overlay_edges)
    times = batched_timing_recursion_piecewise(
        Ws[None], starts[None], num_rounds
    )[0]
    finish = times.max(axis=1)
    predicted = np.atleast_1d(batched_cycle_time(Ws))
    empirical = _per_epoch_slopes(finish, starts)
    return DynamicRun(
        times=times,
        round_finish_ms=finish,
        round_durations_ms=np.diff(finish),
        epoch_starts_ms=starts,
        predicted_tau_ms=predicted,
        empirical_tau_ms=empirical,
    )


def _per_epoch_slopes(finish: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Realized cycle time inside each epoch: slope of the round-finish
    sequence over the rounds fully contained in the epoch (with one round
    of settling after the boundary; nan when fewer than 4 rounds land)."""
    E = len(starts)
    bounds = np.append(starts, np.inf)
    out = np.full(E, np.nan)
    for e in range(E):
        inside = np.nonzero(
            (finish >= bounds[e]) & (finish < bounds[e + 1])
        )[0]
        if len(inside) >= 4:
            ks = inside[1:]  # drop the boundary-straddling round
            out[e] = (finish[ks[-1]] - finish[ks[0]]) / (ks[-1] - ks[0])
    return out


def simulate_scenarios_batched(
    scenarios: Sequence[Scenario],
    tp: TrainingParams,
    overlay_edges: Sequence[Arc],
    num_rounds: int = 200,
) -> np.ndarray:
    """``[B, R+1, N]`` start times for one overlay under many scenarios.

    Scenarios must share the silo universe; epoch grids are padded to a
    common depth by repeating each scenario's final epoch (a start of
    ``+inf`` is never selected by the epoch gather)."""
    n = scenarios[0].num_silos
    if any(s.num_silos != n for s in scenarios):
        raise ValueError("batched scenarios must share one silo universe")
    stacks = [epoch_delay_matrices(s, tp, overlay_edges)[:2] for s in scenarios]
    E = max(Ws.shape[0] for Ws, _ in stacks)
    B = len(scenarios)
    Ws_all = np.full((B, E, n, n), NEG_INF)
    starts_all = np.full((B, E), np.inf)
    for b, (Ws, starts) in enumerate(stacks):
        e = Ws.shape[0]
        Ws_all[b, :e] = Ws
        Ws_all[b, e:] = Ws[-1]
        starts_all[b, :e] = starts
    return batched_timing_recursion_piecewise(Ws_all, starts_all, num_rounds)


def schedule_epoch_estimates(
    scenario: Scenario,
    tp: TrainingParams,
    schedule: Schedule,
    *,
    rounds: int = 150,
    seeds: Sequence[int] = (0, 1),
) -> List[ScheduleEstimate]:
    """Price a schedule on *every epoch* of a scenario — the average
    cycle time of a plan distribution per epoch.

    The fixed-overlay analogue is ``DynamicRun.predicted_tau_ms`` (one
    Karp value per epoch); for a randomized schedule each epoch gets a
    Monte-Carlo :class:`~repro.core.schedule.ScheduleEstimate` (τ̄ + CI)
    on that epoch's re-measured, active-silo connectivity graph.  This is
    what lets the controller reason about a MATCHA schedule under drift:
    the same distribution prices differently on every network the
    scenario visits.
    """
    out: List[ScheduleEstimate] = []
    for epoch in scenario.segments():
        gc = active_subgraph(epoch.gc, epoch.active)
        out.append(schedule.price(gc, tp, rounds=rounds, seeds=seeds))
    return out


class DynamicTimeline:
    """Round-by-round stepper over a scenario, with a hot-swappable overlay.

    This is the *plant* for closed-loop control: the training loop calls
    :meth:`step` once per communication round and reads off the realized
    duration (what a wall clock would measure); the controller may call
    :meth:`set_overlay` between rounds, which rebuilds the per-epoch delay
    stack while preserving the current silo start times — models swapped
    mid-flight keep their progress.
    """

    def __init__(self, scenario: Scenario, tp: TrainingParams):
        self.scenario = scenario
        self.tp = tp
        self.epochs = scenario.segments()
        self.starts = np.array([e.t_start_ms for e in self.epochs])
        self.t = np.zeros(scenario.num_silos)
        self.round_finish_ms: List[float] = [0.0]
        self.overlay_edges: Optional[Tuple[Arc, ...]] = None
        self._Weff: Optional[np.ndarray] = None
        self._schedule: Optional[Schedule] = None
        self._sched_cache: dict = {}
        self.recorder = None  # optional flight recorder (attach_recorder)
        self._epoch_emitted = -1

    @property
    def now_ms(self) -> float:
        return self.round_finish_ms[-1]

    @property
    def rounds_done(self) -> int:
        return len(self.round_finish_ms) - 1

    def set_overlay(self, overlay_edges: Sequence[Arc]) -> None:
        self._schedule = None
        self.overlay_edges = tuple(overlay_edges)
        Ws = np.stack(
            [_epoch_matrix(e, self.tp, self.overlay_edges) for e in self.epochs]
        )
        idx = np.arange(Ws.shape[-1])
        diag = Ws[:, idx, idx]
        Ws[:, idx, idx] = np.where(missing_mask(diag), 0.0, diag)
        self._Weff = Ws

    def set_schedule(self, schedule: Schedule) -> None:
        """Install a :class:`~repro.core.schedule.Schedule` as the plant's
        communication topology.

        A deterministic schedule takes the precomputed per-epoch fast
        path of :meth:`set_overlay`; a randomized one samples its overlay
        per round from the shared round counter (``round_edges(k)`` with
        ``k = rounds_done``), pricing the sampled arcs on whichever epoch
        each sender currently sits in — delay matrices are cached per
        (sampled edge set, epoch).
        """
        if not schedule.is_randomized:
            self.set_overlay(schedule.round_edges(0))
            self._schedule = schedule
            return
        self.overlay_edges = None
        self._Weff = None
        self._schedule = schedule
        self._sched_cache.clear()

    @property
    def schedule(self) -> Optional[Schedule]:
        return self._schedule

    _SCHED_CACHE_MAX = 512  # FIFO bound: many-matching schedules rarely repeat

    def _epoch_matrix_cached(self, edges: Tuple[Arc, ...], ei: int) -> np.ndarray:
        key = (edges, ei)
        W = self._sched_cache.get(key)
        if W is None:
            W = _epoch_matrix(self.epochs[ei], self.tp, edges)
            idx = np.arange(W.shape[-1])
            diag = W[idx, idx]
            W[idx, idx] = np.where(missing_mask(diag), 0.0, diag)
            if len(self._sched_cache) >= self._SCHED_CACHE_MAX:
                self._sched_cache.pop(next(iter(self._sched_cache)))
            self._sched_cache[key] = W
        return W

    def attach_recorder(self, recorder) -> None:
        """Emit an ``epoch`` trace record (index, start time, active set)
        whenever the plant's round front crosses into a new network
        epoch, starting with the epoch it is in right now."""
        self.recorder = recorder
        self._emit_epochs_through(
            int(_epoch_of(self.starts, np.array([self.now_ms]))[0])
        )

    def _emit_epochs_through(self, ei: int) -> None:
        for k in range(self._epoch_emitted + 1, ei + 1):
            ep = self.epochs[k]
            self.recorder.emit(
                "epoch",
                index=k,
                t_start_ms=ep.t_start_ms,  # a host float by construction
                active=list(ep.active),
            )
        self._epoch_emitted = max(self._epoch_emitted, ei)

    def current_epoch(self) -> NetworkEpoch:
        """Epoch containing the current round front — what a measurement
        service would report if probed right now."""
        e = int(_epoch_of(self.starts, np.array([self.now_ms]))[0])
        return self.epochs[e]

    def current_active(self) -> Tuple[int, ...]:
        """Active silo labels of the current epoch — the control-plane
        membership signal (``SiloJoin``/``SiloLeave`` are *known*, not
        inferred from timings).  Feed this as the controller's
        ``membership_provider`` to drive elastic mesh/state rebuilds."""
        return self.current_epoch().active

    def step(self) -> float:
        """Advance one communication round; return its realized duration."""
        if self._Weff is None and (
            self._schedule is None or not self._schedule.is_randomized
        ):
            raise RuntimeError("set_overlay()/set_schedule() before stepping")
        e = _epoch_of(self.starts, self.t)  # [N] epoch per sender
        if self._Weff is not None:
            e0 = int(e[0])
            if np.all(e == e0):
                # Common case: every sender sits in the same epoch, so the
                # per-sender gather reduces to a view of one epoch matrix.
                Wk = self._Weff[e0]
            else:
                Wk = self._Weff[e, np.arange(len(self.t)), :]
        else:
            edges = tuple(self._schedule.round_edges(self.rounds_done))
            Wk = np.empty((len(self.t), len(self.t)))
            for ei in np.unique(e):
                rows = e == ei
                Wk[rows] = self._epoch_matrix_cached(edges, int(ei))[rows]
        self.t = np.max(self.t[:, None] + Wk, axis=0)
        finish = float(self.t.max())
        duration = finish - self.round_finish_ms[-1]
        self.round_finish_ms.append(finish)
        if self.recorder is not None:
            self._emit_epochs_through(
                int(_epoch_of(self.starts, np.array([finish]))[0])
            )
        return duration
