"""Scenario model: typed network events over a piecewise-constant underlay.

The paper measures the network once and designs the overlay for that
snapshot.  This module is the *scenario* layer of the dynamics subsystem:
a sorted stream of typed events (:class:`LinkDegraded`, :class:`LinkFailed`,
:class:`LinkRestored`, :class:`SiloJoin`, :class:`SiloLeave`,
:class:`ComputeStraggler`) rewrites an :class:`~repro.core.underlay.Underlay`
into a sequence of :class:`NetworkEpoch` segments, each carrying the
re-derived :class:`~repro.core.delays.ConnectivityGraph` (re-routed
shortest paths, degraded available bandwidths, scaled computation times,
shrunken/grown silo set) that holds on ``[t_start, t_end)``.

Every epoch keeps the *full* silo universe of the underlay so that the
per-epoch Eq. 3 delay matrices stack into one ``[E, N, N]`` array (the
shape the batched max-plus engine consumes); a silo that has left (or not
yet joined) is marked inactive — no overlay arcs touch it and its
self-loop computation delay is zeroed, so it contributes no circuit.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.delays import ConnectivityGraph, SiloParams
from ..core.underlay import Underlay, haversine_km

LinkKey = Tuple[int, int]


def _link_key(link: Sequence[int]) -> LinkKey:
    u, v = link
    return (u, v) if u <= v else (v, u)


# ---------------------------------------------------------------------------
# Event types


@dataclass(frozen=True)
class NetworkEvent:
    """Base event; ``t_ms`` is the instant the change takes effect."""

    t_ms: float


@dataclass(frozen=True)
class LinkDegraded(NetworkEvent):
    """Core link keeps operating at ``factor`` of its nominal capacity.

    ``factor=1.0`` *clears* a previous degradation (the link returns to
    full capacity) — the only way to undo one: degradation and failure
    are orthogonal state dimensions, and :class:`LinkRestored` touches
    only the failure."""

    link: LinkKey
    factor: float

    def __post_init__(self):
        if not (0.0 < self.factor <= 1.0):
            raise ValueError(f"degrade factor must be in (0, 1], got {self.factor}")


@dataclass(frozen=True)
class LinkFailed(NetworkEvent):
    """Core link goes down; traffic re-routes over surviving links."""

    link: LinkKey


@dataclass(frozen=True)
class LinkRestored(NetworkEvent):
    """Core link comes back up, undoing a :class:`LinkFailed`.

    Restore-to-degraded semantics: a :class:`LinkDegraded` factor applied
    before (or during) the outage *persists* after the restore — repairing
    a fiber cut does not also fix congestion.  A degrade → fail → restore
    interleaving therefore lands on the degraded capacity, not nominal;
    only ``LinkDegraded(factor=1.0)`` returns the link to full capacity
    (tested in ``tests/test_dynamics.py``)."""

    link: LinkKey


@dataclass(frozen=True)
class SiloLeave(NetworkEvent):
    """Silo departs training (its router keeps forwarding core traffic)."""

    silo: int


@dataclass(frozen=True)
class SiloJoin(NetworkEvent):
    """Silo (re-)joins training and syncs from its overlay neighbours."""

    silo: int


@dataclass(frozen=True)
class ComputeStraggler(NetworkEvent):
    """Silo's computation time is scaled by ``factor`` (1.0 clears it)."""

    silo: int
    factor: float

    def __post_init__(self):
        if self.factor <= 0.0:
            raise ValueError(f"straggler factor must be positive, got {self.factor}")


# ---------------------------------------------------------------------------
# Network state folding


@dataclass(frozen=True)
class NetworkState:
    """Underlay + the cumulative effect of all events applied so far."""

    underlay: Underlay
    comp_time_ms: float
    active: FrozenSet[int]
    failed_links: FrozenSet[LinkKey] = frozenset()
    capacity_factor: Mapping[LinkKey, float] = dataclasses.field(default_factory=dict)
    comp_factor: Mapping[int, float] = dataclasses.field(default_factory=dict)

    def apply(self, ev: NetworkEvent) -> "NetworkState":
        if isinstance(ev, LinkFailed):
            key = _link_key(ev.link)
            self._check_link(key)
            return dataclasses.replace(self, failed_links=self.failed_links | {key})
        if isinstance(ev, LinkRestored):
            key = _link_key(ev.link)
            self._check_link(key)
            # restore-to-degraded: only the failure is undone; a prior
            # LinkDegraded factor survives the outage (see the event's
            # docstring for the decided semantics)
            return dataclasses.replace(self, failed_links=self.failed_links - {key})
        if isinstance(ev, LinkDegraded):
            key = _link_key(ev.link)
            self._check_link(key)
            caps = dict(self.capacity_factor)
            if ev.factor == 1.0:
                caps.pop(key, None)  # factor 1.0 = back to nominal capacity
            else:
                caps[key] = ev.factor
            return dataclasses.replace(self, capacity_factor=caps)
        if isinstance(ev, SiloLeave):
            self._check_silo(ev.silo)
            return dataclasses.replace(self, active=self.active - {ev.silo})
        if isinstance(ev, SiloJoin):
            self._check_silo(ev.silo)
            return dataclasses.replace(self, active=self.active | {ev.silo})
        if isinstance(ev, ComputeStraggler):
            self._check_silo(ev.silo)
            factors = dict(self.comp_factor)
            if ev.factor == 1.0:
                factors.pop(ev.silo, None)
            else:
                factors[ev.silo] = ev.factor
            return dataclasses.replace(self, comp_factor=factors)
        raise TypeError(f"unknown event type {type(ev).__name__}")

    def _check_link(self, key: LinkKey) -> None:
        if key not in {_link_key(e) for e in self.underlay.core_edges}:
            raise ValueError(f"{key} is not a core link of {self.underlay.name}")

    def _check_silo(self, silo: int) -> None:
        if not (0 <= silo < self.underlay.num_silos):
            raise ValueError(f"silo {silo} outside universe of {self.underlay.name}")

    def connectivity(self) -> ConnectivityGraph:
        """Derive the connectivity graph of this state over the *full*
        silo universe (inactive silos carry no pairs and zero computation).

        Re-runs distance-routed Dijkstra on the surviving core links, so a
        failure both re-routes latency and re-prices available bandwidth
        (min surviving-link capacity along the new path)."""
        u = self.underlay
        n = u.num_silos
        alive = tuple(
            e for e in u.core_edges if _link_key(e) not in self.failed_links
        )
        routed = dataclasses.replace(u, core_edges=alive)
        cap: Dict[LinkKey, float] = {
            key: u.core_capacity_gbps * factor
            for key, factor in self.capacity_factor.items()
        }
        # One pricing implementation: re-route + re-price through the
        # underlay itself; partitioned pairs simply vanish from G_c.
        latency, avail = routed.pair_metrics(
            core_capacity_gbps=cap if cap else None,
            silos=sorted(self.active),
            skip_unreachable=True,
        )
        params: Dict[int, SiloParams] = {}
        for v in range(n):
            if v in self.active:
                ct = self.comp_time_ms * self.comp_factor.get(v, 1.0)
            else:
                ct = 0.0  # no self-loop circuit for inactive silos
            params[v] = SiloParams(
                comp_time_ms=ct,
                uplink_gbps=u.access_capacity_gbps,
                downlink_gbps=u.access_capacity_gbps,
            )
        return ConnectivityGraph(
            silos=tuple(range(n)),
            latency_ms=latency,
            available_bw_gbps=avail,
            silo_params=params,
        )


# ---------------------------------------------------------------------------
# Scenario = initial state + event stream -> piecewise-constant epochs


@dataclass(frozen=True)
class NetworkEpoch:
    """One constant segment of the time-varying network."""

    t_start_ms: float
    t_end_ms: float  # +inf for the final epoch
    gc: ConnectivityGraph  # full silo universe; inactive silos isolated
    active: Tuple[int, ...]

    @property
    def duration_ms(self) -> float:
        return self.t_end_ms - self.t_start_ms


@dataclass(frozen=True)
class Scenario:
    """A named, reproducible time-varying network."""

    name: str
    underlay: Underlay
    comp_time_ms: float
    events: Tuple[NetworkEvent, ...]
    horizon_ms: float
    initially_inactive: Tuple[int, ...] = ()

    @property
    def num_silos(self) -> int:
        return self.underlay.num_silos

    def initial_state(self) -> NetworkState:
        active = frozenset(range(self.num_silos)) - set(self.initially_inactive)
        return NetworkState(
            underlay=self.underlay, comp_time_ms=self.comp_time_ms, active=active
        )

    def segments(self) -> List[NetworkEpoch]:
        """Fold the event stream into piecewise-constant epochs.

        Events at the same instant merge into one boundary; events at
        ``t <= 0`` fold into the initial epoch."""
        state = self.initial_state()
        pending = sorted(self.events, key=lambda ev: ev.t_ms)
        k = 0
        while k < len(pending) and pending[k].t_ms <= 0.0:
            state = state.apply(pending[k])
            k += 1
        epochs: List[NetworkEpoch] = []
        t_start = 0.0
        for t_ms, group in itertools.groupby(pending[k:], key=lambda ev: ev.t_ms):
            epochs.append(
                NetworkEpoch(
                    t_start_ms=t_start,
                    t_end_ms=t_ms,
                    gc=state.connectivity(),
                    active=tuple(sorted(state.active)),
                )
            )
            for ev in group:
                state = state.apply(ev)
            t_start = t_ms
        epochs.append(
            NetworkEpoch(
                t_start_ms=t_start,
                t_end_ms=math.inf,
                gc=state.connectivity(),
                active=tuple(sorted(state.active)),
            )
        )
        return epochs


def active_subgraph(gc: ConnectivityGraph, active: Sequence[int]) -> ConnectivityGraph:
    """Restrict a full-universe epoch graph to its active silos — the view
    the topology designers (and the online controller) operate on."""
    keep = set(active)
    return ConnectivityGraph(
        silos=tuple(sorted(keep)),
        latency_ms={e: v for e, v in gc.latency_ms.items() if set(e) <= keep},
        available_bw_gbps={
            e: v for e, v in gc.available_bw_gbps.items() if set(e) <= keep
        },
        silo_params={v: p for v, p in gc.silo_params.items() if v in keep},
    )


# ---------------------------------------------------------------------------
# Seeded scenario generators


def static_scenario(
    underlay: Underlay, comp_time_ms: float, horizon_ms: float = 60_000.0
) -> Scenario:
    """No events: the degenerate scenario that must reproduce the static
    dense recursion exactly (tested)."""
    return Scenario(
        name=f"{underlay.name}-static",
        underlay=underlay,
        comp_time_ms=comp_time_ms,
        events=(),
        horizon_ms=horizon_ms,
    )


def link_failure_scenario(
    underlay: Underlay,
    comp_time_ms: float,
    *,
    t_fail_ms: float,
    link: Optional[LinkKey] = None,
    overlay_edges: Optional[Sequence[Tuple[int, int]]] = None,
    horizon_ms: float = 60_000.0,
) -> Scenario:
    """Fail one core link mid-training.

    With ``link=None`` the busiest link is chosen: the core link carrying
    the most routed overlay arcs (or, without an overlay, the most
    shortest paths) — the failure an SDN monitor would flag first."""
    if link is None:
        link = busiest_core_link(underlay, overlay_edges)
    return Scenario(
        name=f"{underlay.name}-linkfail",
        underlay=underlay,
        comp_time_ms=comp_time_ms,
        events=(LinkFailed(t_ms=t_fail_ms, link=_link_key(link)),),
        horizon_ms=horizon_ms,
    )


def busiest_core_link(
    underlay: Underlay,
    overlay_edges: Optional[Sequence[Tuple[int, int]]] = None,
) -> LinkKey:
    """Core link traversed by the most routed silo pairs (ties broken by
    link length, longest first — the transcontinental hop, not the short
    local one)."""
    sp = underlay.shortest_paths()
    load: Dict[LinkKey, int] = {_link_key(e): 0 for e in underlay.core_edges}
    if overlay_edges is None:
        n = underlay.num_silos
        pairs = [(i, j) for i in range(n) for j in range(n) if i != j]
    else:
        pairs = [tuple(e) for e in overlay_edges if e[0] != e[1]]
    for (i, j) in pairs:
        _, pred = sp[i]
        path = underlay.path_nodes(pred, i, j)
        for hop in zip(path[:-1], path[1:]):
            load[_link_key(hop)] += 1
    def length(key: LinkKey) -> float:
        return haversine_km(underlay.coords[key[0]], underlay.coords[key[1]])
    return max(load, key=lambda k: (load[k], length(k)))


def silo_degrade_scenario(
    underlay: Underlay,
    comp_time_ms: float,
    *,
    silo: int,
    t_ms: float,
    factor: float = 0.02,
    horizon_ms: float = 60_000.0,
) -> Scenario:
    """Severely degrade every core link incident to one silo.

    Every path to ``silo`` ends on one of its (all degraded) incident
    links, so no re-routing escapes the ``M / (factor · C)`` transfer —
    the drift that stresses *schedules* hardest: a fixed overlay absorbs
    the slow silo into its critical circuit (amortized over the circuit
    length by max-plus pipelining), while a randomized plan stalls both
    endpoints of every sampled matching that touches it.  The online
    controller must react either way: re-design the overlay around the
    slow region, or — with ``ControllerConfig.schedule_family="matcha"``
    — re-fit the plan distribution (budget re-swept on the degraded
    estimate) and hot-swap it through the :class:`ScheduleSlot`.
    """
    if not (0 <= silo < underlay.num_silos):
        raise ValueError(f"silo {silo} outside universe of {underlay.name}")
    events = tuple(
        LinkDegraded(t_ms=t_ms, link=_link_key(e), factor=factor)
        for e in underlay.core_edges
        if silo in e
    )
    if not events:
        raise ValueError(f"silo {silo} has no core links in {underlay.name}")
    return Scenario(
        name=f"{underlay.name}-silodegrade",
        underlay=underlay,
        comp_time_ms=comp_time_ms,
        events=events,
        horizon_ms=horizon_ms,
    )


def churn_scenario(
    underlay: Underlay,
    comp_time_ms: float,
    *,
    silo: int,
    t_leave_ms: float,
    t_rejoin_ms: float,
    horizon_ms: float = 60_000.0,
) -> Scenario:
    """One silo leaves training and later rejoins — the minimal elastic-
    membership scenario: the training loop must rebuild its mesh/state on
    the :class:`SiloLeave` and again on the paired :class:`SiloJoin`."""
    if not (0 <= silo < underlay.num_silos):
        raise ValueError(f"silo {silo} outside universe of {underlay.name}")
    if not (0.0 < t_leave_ms < t_rejoin_ms):
        raise ValueError(
            f"need 0 < t_leave_ms < t_rejoin_ms, got {t_leave_ms}, {t_rejoin_ms}"
        )
    return Scenario(
        name=f"{underlay.name}-churn",
        underlay=underlay,
        comp_time_ms=comp_time_ms,
        events=(
            SiloLeave(t_ms=t_leave_ms, silo=silo),
            SiloJoin(t_ms=t_rejoin_ms, silo=silo),
        ),
        horizon_ms=horizon_ms,
    )


def random_scenario(
    underlay: Underlay,
    comp_time_ms: float,
    *,
    seed: int,
    horizon_ms: float = 60_000.0,
    n_events: int = 6,
    p_degrade: float = 0.35,
    p_fail: float = 0.25,
    p_straggler: float = 0.25,
    p_churn: float = 0.15,
    min_degrade: float = 0.02,
    min_active: int = 3,
) -> Scenario:
    """Seeded random event stream over ``(0, horizon_ms)``.

    Event mix: capacity degradations, link failures (each later restored
    with probability 1/2), compute stragglers, and silo leave/rejoin
    churn.  The same (underlay, seed) always yields the same scenario.

    Churn keeps at least ``max(1, min_active)`` silos active at every
    instant: each :class:`SiloLeave` schedules its paired
    :class:`SiloJoin` inside the horizon, the candidate pool tracks those
    rejoin times (a silo whose rejoin has fired may be picked to leave
    again — the pool does not shrink monotonically), and a leave that
    would cross the floor is converted into a straggler instead."""
    rng = np.random.default_rng(seed)
    probs = np.array([p_degrade, p_fail, p_straggler, p_churn])
    probs = probs / probs.sum()
    links = [_link_key(e) for e in underlay.core_edges]
    events: List[NetworkEvent] = []
    away: Dict[int, float] = {}  # silo -> scheduled rejoin time
    floor = max(1, min(min_active, underlay.num_silos))
    times = np.sort(rng.uniform(0.05 * horizon_ms, 0.95 * horizon_ms, n_events))
    for t in times:
        for v in [v for v, t_back in away.items() if t_back <= t]:
            del away[v]  # rejoin fired: back in the candidate pool
        kind = int(rng.choice(4, p=probs))
        if kind == 3 and underlay.num_silos - len(away) <= floor:
            kind = 2  # at the active floor: churn becomes a straggler
        if kind == 0:
            link = links[int(rng.integers(len(links)))]
            factor = float(rng.uniform(min_degrade, 0.5))
            events.append(LinkDegraded(t_ms=float(t), link=link, factor=factor))
        elif kind == 1:
            link = links[int(rng.integers(len(links)))]
            events.append(LinkFailed(t_ms=float(t), link=link))
            if rng.random() < 0.5:
                t_back = float(rng.uniform(t, horizon_ms))
                events.append(LinkRestored(t_ms=t_back, link=link))
        elif kind == 2:
            silo = int(rng.integers(underlay.num_silos))
            factor = float(rng.uniform(2.0, 10.0))
            events.append(ComputeStraggler(t_ms=float(t), silo=silo, factor=factor))
        else:
            candidates = [v for v in range(underlay.num_silos) if v not in away]
            silo = candidates[int(rng.integers(len(candidates)))]
            t_back = float(rng.uniform(t, horizon_ms))
            away[silo] = t_back
            events.append(SiloLeave(t_ms=float(t), silo=silo))
            events.append(SiloJoin(t_ms=t_back, silo=silo))
    return Scenario(
        name=f"{underlay.name}-random-{seed}",
        underlay=underlay,
        comp_time_ms=comp_time_ms,
        events=tuple(sorted(events, key=lambda ev: ev.t_ms)),
        horizon_ms=horizon_ms,
    )
