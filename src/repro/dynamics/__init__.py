"""Dynamic-network subsystem: time-varying underlays, event-driven
simulation, and online topology re-design.

The paper's pipeline (Sect. 2-4) is *open-loop*: measure the network
(Sect. 2.2), price every connectivity edge with the Eq. 3 delay model,
design a throughput-optimal overlay via the max-plus cycle time
(Sect. 2.3 / Eq. 5), and train on it forever.  Real cross-silo
deployments drift — bandwidth degrades, core links fail, silos straggle,
join, and leave — so the designed overlay's realized throughput decays
while a better overlay exists on the changed network.  This subsystem
closes the loop, in three layers:

* :mod:`~repro.dynamics.events` — **scenario model**.  A typed event
  stream (:class:`LinkDegraded`, :class:`LinkFailed`, :class:`LinkRestored`,
  :class:`SiloJoin`, :class:`SiloLeave`, :class:`ComputeStraggler`, plus
  seeded random generators) folds over an
  :class:`~repro.core.underlay.Underlay` into piecewise-constant
  :class:`NetworkEpoch` segments, each with a freshly re-routed
  :class:`~repro.core.delays.ConnectivityGraph` — the Sect. 2.2
  measurement pipeline re-run per epoch.

* :mod:`~repro.dynamics.simulate` — **event-driven simulator**.  Extends
  the Eq. 4 max-plus timing recursion (Sect. 2.3) from one delay matrix
  to an ``[E, N, N]`` per-epoch stack, batched over whole scenario sweeps
  through :func:`repro.core.maxplus_vec.batched_timing_recursion_piecewise`.
  Reports realized round times, per-epoch cycle times, and throughput
  loss against the static-optimal overlay.

* :mod:`~repro.dynamics.controller` — **online controller**.  Watches
  measured round durations against the max-plus prediction, and on
  sustained regression re-runs topology design (Sect. 3/4 designers, a
  batched random-ring search — hundreds of candidates in one
  ``batched_cycle_time`` call — and the device-side sparse-rewire hill
  climb :func:`~repro.core.topologies.search_overlays_jit` seeded from
  the incumbent overlay) on the updated connectivity estimate, explains
  the new bottleneck via the critical circuit, and hot-swaps the
  resulting :class:`~repro.fed.gossip.GossipPlan` through a
  :class:`~repro.fed.gossip.PlanSlot`.

Randomized plan distributions (:mod:`repro.core.schedule`) are
first-class throughout: :func:`~repro.dynamics.simulate.schedule_epoch_estimates`
prices a MATCHA schedule's τ̄ on every epoch of a scenario,
:meth:`DynamicTimeline.set_schedule` steps the plant on per-round
sampled topologies, and the controller
(:attr:`~repro.dynamics.controller.ControllerConfig.matcha_budgets`,
``schedule_family``) re-fits the distribution on drift and hot-swaps
fixed ↔ randomized through a :class:`~repro.fed.gossip.ScheduleSlot`.

Membership is elastic end-to-end: ``SiloJoin``/``SiloLeave`` churn flows
from the scenario (:meth:`DynamicTimeline.current_active`) through the
controller's ``membership_provider`` — churn is control-plane knowledge,
so it triggers an *immediate* re-design over the surviving universe,
bypassing the strike detector — into a
:class:`~repro.fed.gossip.MembershipSlot` the training loop watches to
rebuild its device mesh and migrate the silo-stacked state
(:func:`repro.fed.dpasgd.migrate_silo_state`: survivors bit-identical,
joiners at the survivors' consensus average).

``examples/dynamic_topology.py`` runs the whole stack on a Gaia
core-link failure; ``benchmarks/dynamics_bench.py`` tracks re-design
latency (candidates/sec) and simulator throughput (scenario-rounds/sec).
"""

from .events import (
    ComputeStraggler,
    LinkDegraded,
    LinkFailed,
    LinkRestored,
    NetworkEpoch,
    NetworkEvent,
    NetworkState,
    Scenario,
    SiloJoin,
    SiloLeave,
    active_subgraph,
    busiest_core_link,
    churn_scenario,
    link_failure_scenario,
    random_scenario,
    silo_degrade_scenario,
    static_scenario,
)
from .simulate import (
    DynamicRun,
    DynamicTimeline,
    epoch_delay_matrices,
    schedule_epoch_estimates,
    simulate_dynamic,
    simulate_scenarios_batched,
)
from .controller import (
    ControllerConfig,
    OnlineTopologyController,
    Redesign,
    design_best_overlay,
    design_best_schedule,
    design_schedule_portfolio,
    search_ring_candidates,
)
