"""Per-function control-flow + dataflow analysis for repro-lint.

The PR 6 lint rules are syntactic: they pattern-match single AST nodes
and cannot tell ``float(rng.uniform(...))`` (a host value — never a
device sync) from ``float(step_fn(x))`` (a per-iteration device→host
sync).  This module adds the machinery the *protocol* rules need:

* :class:`CFG` — a statement-granularity control-flow graph of one
  function, with loop back edges, ``break``/``continue``, ``return``/
  ``raise`` to exit, and try/except edges (any statement of a ``try``
  body may jump to any handler);
* :func:`reaching_definitions` — classic forward may-analysis over the
  CFG (which assignments may reach each statement);
* :class:`FunctionAnalysis` — def-use chains on top of the reaching
  definitions, plus :meth:`FunctionAnalysis.host_only`, a transitive
  origin query: does *every* definition chain of this expression
  bottom out in host-side sources (numpy calls, stdlib, literals,
  seeded ``np.random`` generators) rather than function parameters or
  jax values?
* :func:`propagate` — a generic forward abstract-state fixpoint used by
  :mod:`repro.analysis.protocols` to run typestate machines over the
  CFG.

Everything here is pure stdlib ``ast`` — no imports of the linted code.
"""

from __future__ import annotations

import ast
from typing import (Callable, Dict, Iterable, List, Optional, Set, Tuple,
                    TypeVar)

__all__ = ["CFG", "Entry", "reaching_definitions", "FunctionAnalysis",
           "analyze_function", "propagate", "assigned_names",
           "names_loaded"]


class Entry:
    """Synthetic CFG entry node: the definition site of every parameter."""

    lineno = 0
    col_offset = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<cfg-entry>"


class _Exit:
    lineno = 0
    col_offset = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<cfg-exit>"


class CFG:
    """Statement-granularity control-flow graph of one function body.

    Nodes are the function's ``ast.stmt`` objects themselves (identity
    hashing), plus a synthetic :class:`Entry` and exit.  Compound
    statements (``if``/``while``/``for``/``try``/``with``) are nodes in
    their own right — they evaluate their test/iterable — with edges
    into their bodies.  Nested function/class definitions are single
    nodes (their bodies belong to *their* CFGs).
    """

    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.entry: Entry = Entry()
        self.exit: _Exit = _Exit()
        self.succs: Dict[ast.AST, Set[ast.AST]] = {self.entry: set(),
                                                   self.exit: set()}
        self.preds: Dict[ast.AST, Set[ast.AST]] = {self.entry: set(),
                                                   self.exit: set()}
        self._build()

    # -- construction ------------------------------------------------------

    def _edge(self, a: ast.AST, b: ast.AST) -> None:
        self.succs.setdefault(a, set()).add(b)
        self.preds.setdefault(b, set()).add(a)
        self.succs.setdefault(b, set())
        self.preds.setdefault(a, set())

    def _build(self) -> None:
        body = getattr(self.fn, "body", [])
        # loop stack entries: (continue_target, break_targets:list)
        exits = self._seq(body, [self.entry], loops=[])
        for e in exits:
            self._edge(e, self.exit)

    def _seq(self, stmts: List[ast.stmt], frontier: List[ast.AST],
             loops: List[Tuple[ast.AST, List[ast.AST]]]) -> List[ast.AST]:
        """Wire a statement list after ``frontier``; return its exits."""
        for stmt in stmts:
            for f in frontier:
                self._edge(f, stmt)
            frontier = self._stmt(stmt, loops)
            if not frontier:        # return/raise/break/continue: dead end
                return []
        return frontier

    def _stmt(self, stmt: ast.stmt,
              loops: List[Tuple[ast.AST, List[ast.AST]]]) -> List[ast.AST]:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            self._edge(stmt, self.exit)
            return []
        if isinstance(stmt, ast.Break):
            if loops:
                loops[-1][1].append(stmt)
            return []
        if isinstance(stmt, ast.Continue):
            if loops:
                self._edge(stmt, loops[-1][0])
            return []
        if isinstance(stmt, ast.If):
            then_exits = self._seq(stmt.body, [stmt], loops)
            if stmt.orelse:
                else_exits = self._seq(stmt.orelse, [stmt], loops)
            else:
                else_exits = [stmt]
            return then_exits + else_exits
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            breaks: List[ast.AST] = []
            loops.append((stmt, breaks))
            body_exits = self._seq(stmt.body, [stmt], loops)
            loops.pop()
            for e in body_exits:
                self._edge(e, stmt)          # back edge
            after: List[ast.AST] = [stmt]    # loop may run zero times
            after.extend(breaks)
            if stmt.orelse:
                after = self._seq(stmt.orelse, [stmt], loops) + breaks
            return after
        if isinstance(stmt, ast.Try):
            body_exits = self._seq(stmt.body, [stmt], loops)
            # Any statement of the try body (or the Try header itself)
            # may raise into any handler.
            raisers: List[ast.AST] = [stmt] + [
                n for n in stmt.body for n in self._all_stmts(n)]
            handler_exits: List[ast.AST] = []
            for handler in stmt.handlers:
                h_frontier = list(dict.fromkeys(raisers))
                handler_exits.extend(
                    self._seq(handler.body, h_frontier, loops))
            if stmt.orelse:
                body_exits = self._seq(stmt.orelse, body_exits, loops)
            exits = body_exits + handler_exits
            if stmt.finalbody:
                exits = self._seq(stmt.finalbody, exits or [stmt], loops)
            return exits
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return self._seq(stmt.body, [stmt], loops)
        # simple statement (incl. nested FunctionDef/ClassDef as one node)
        self.succs.setdefault(stmt, set())
        self.preds.setdefault(stmt, set())
        return [stmt]

    def _all_stmts(self, stmt: ast.stmt) -> List[ast.stmt]:
        """stmt plus every statement nested inside it (not nested defs)."""
        out = [stmt]
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return out
        for child in ast.walk(stmt):
            if child is not stmt and isinstance(child, ast.stmt) and \
                    not isinstance(child, (ast.FunctionDef,
                                           ast.AsyncFunctionDef,
                                           ast.ClassDef)):
                out.append(child)
        return out

    # -- queries -----------------------------------------------------------

    @property
    def nodes(self) -> List[ast.AST]:
        return list(self.succs)

    def statements(self) -> List[ast.stmt]:
        return [n for n in self.succs
                if not isinstance(n, (Entry, _Exit))]


# ---------------------------------------------------------------------------
# Definitions and uses
# ---------------------------------------------------------------------------

def _comp_targets(node: ast.AST) -> Set[str]:
    """Names bound by comprehension generators (scope-local, not defs)."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.comprehension):
            for n in ast.walk(sub.target):
                if isinstance(n, ast.Name):
                    out.add(n.id)
    return out


def _target_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx,
                                                  (ast.Store, ast.Del)):
            out.add(n.id)
    return out


def assigned_names(stmt: ast.AST) -> Set[str]:
    """Variable names this single statement (re)binds."""
    out: Set[str] = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            out |= _target_names(t)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        out |= _target_names(stmt.target)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        out |= _target_names(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                out |= _target_names(item.optional_vars)
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        out.add(stmt.name)
    elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
        for alias in stmt.names:
            out.add((alias.asname or alias.name).split(".")[0])
    elif isinstance(stmt, ast.Try):
        for handler in stmt.handlers:
            if handler.name:
                out.add(handler.name)
    # walrus targets anywhere in the statement's expressions
    skip_defs = isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))
    if not skip_defs:
        for n in _own_exprs(stmt):
            for sub in ast.walk(n):
                if isinstance(sub, ast.NamedExpr):
                    out |= _target_names(sub.target)
    return out


def _own_exprs(stmt: ast.AST) -> List[ast.AST]:
    """Expressions evaluated *by this statement itself* (not by the
    statements nested in its body/orelse/handlers)."""
    if isinstance(stmt, ast.If):
        return [stmt.test]
    if isinstance(stmt, ast.While):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter, stmt.target]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in stmt.items] + [
            i.optional_vars for i in stmt.items
            if i.optional_vars is not None]
    if isinstance(stmt, ast.Try):
        return []
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return list(stmt.decorator_list)
    return [c for c in ast.iter_child_nodes(stmt)
            if isinstance(c, ast.expr)]


def names_loaded(stmt: ast.AST) -> Set[str]:
    """Names this statement reads (Load context), excluding
    comprehension-local targets and nested-def bodies."""
    out: Set[str] = set()
    for expr in _own_exprs(stmt):
        local = _comp_targets(expr)
        for n in ast.walk(expr):
            if isinstance(n, (ast.Lambda,)):
                continue
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id not in local:
                out.add(n.id)
    # AugAssign reads its target too
    if isinstance(stmt, ast.AugAssign):
        out |= _target_names(stmt.target)
    return out


Def = Tuple[str, ast.AST]  # (variable, defining node)


def reaching_definitions(cfg: CFG) -> Dict[ast.AST, Set[Def]]:
    """IN[n] for every CFG node: the (var, def-site) pairs that may
    reach the entry of n.  The synthetic entry node defines every
    parameter."""
    params: Set[str] = set()
    args = getattr(cfg.fn, "args", None)
    if args is not None:
        for a in (args.args + args.kwonlyargs
                  + getattr(args, "posonlyargs", [])):
            params.add(a.arg)
        if args.vararg:
            params.add(args.vararg.arg)
        if args.kwarg:
            params.add(args.kwarg.arg)

    gen: Dict[ast.AST, Set[Def]] = {}
    kill: Dict[ast.AST, Set[str]] = {}
    for node in cfg.nodes:
        if isinstance(node, Entry):
            gen[node] = {(p, node) for p in params}
            kill[node] = set(params)
        else:
            names = assigned_names(node) if isinstance(node, ast.stmt) \
                else set()
            gen[node] = {(v, node) for v in names}
            kill[node] = set(names)

    out_sets: Dict[ast.AST, Set[Def]] = {n: set(gen[n]) for n in cfg.nodes}
    in_sets: Dict[ast.AST, Set[Def]] = {n: set() for n in cfg.nodes}
    work = list(cfg.nodes)
    while work:
        n = work.pop()
        new_in: Set[Def] = set()
        for p in cfg.preds.get(n, ()):
            new_in |= out_sets[p]
        if new_in != in_sets[n]:
            in_sets[n] = new_in
        new_out = gen[n] | {(v, d) for (v, d) in new_in
                            if v not in kill[n]}
        if new_out != out_sets[n]:
            out_sets[n] = new_out
            work.extend(cfg.succs.get(n, ()))
    return in_sets


# ---------------------------------------------------------------------------
# Host-origin inference
# ---------------------------------------------------------------------------

#: Module roots whose call results live on host, never on device.
HOST_MODULES = frozenset({
    "np", "numpy", "math", "os", "sys", "time", "random", "itertools",
    "functools", "collections", "json", "re", "pathlib", "string",
})

#: Builtins whose result is host-only iff all arguments are host-only.
_HOST_BUILTINS = frozenset({
    "float", "int", "bool", "str", "len", "abs", "min", "max", "sum",
    "sorted", "list", "tuple", "dict", "set", "frozenset", "range",
    "enumerate", "zip", "reversed", "round", "repr", "format", "any",
    "all",
})


class FunctionAnalysis:
    """Def-use chains + origin inference for one function."""

    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.cfg = CFG(fn)
        self.reach_in = reaching_definitions(self.cfg)
        self._stmt_of: Dict[ast.AST, ast.AST] = {}
        for stmt in self.cfg.statements():
            for expr in _own_exprs(stmt):
                for sub in ast.walk(expr):
                    self._stmt_of.setdefault(sub, stmt)
            self._stmt_of.setdefault(stmt, stmt)

    def enclosing_stmt(self, node: ast.AST) -> Optional[ast.AST]:
        """The CFG statement that evaluates ``node`` (None if the node
        belongs to a nested def this CFG treats as opaque)."""
        return self._stmt_of.get(node)

    def defs_of(self, var: str, at: ast.AST) -> Set[ast.AST]:
        """Definition sites of ``var`` that may reach statement ``at``."""
        return {d for (v, d) in self.reach_in.get(at, ()) if v == var}

    def chains(self) -> Dict[Tuple[ast.AST, str], Set[ast.AST]]:
        """(use-stmt, var) -> possible defining nodes, for every load."""
        out: Dict[Tuple[ast.AST, str], Set[ast.AST]] = {}
        for stmt in self.cfg.statements():
            for var in names_loaded(stmt):
                out[(stmt, var)] = self.defs_of(var, stmt)
        return out

    # -- origin inference --------------------------------------------------

    def host_only(self, expr: ast.AST, at: Optional[ast.AST] = None) -> bool:
        """True when every dataflow chain of ``expr`` bottoms out in a
        host-side source.  Conservative: parameters, unresolved globals
        and unknown calls are *not* host-only."""
        if at is None:
            at = self.enclosing_stmt(expr)
            if at is None:
                return False
        return self._host(expr, at, frozenset())

    def _host(self, expr: ast.AST, at: ast.AST,
              seen: frozenset) -> bool:
        if isinstance(expr, ast.Constant):
            return True
        if isinstance(expr, ast.JoinedStr):
            return True
        if isinstance(expr, (ast.List, ast.Tuple, ast.Set)):
            return all(self._host(e, at, seen) for e in expr.elts
                       if not isinstance(e, ast.Starred))
        if isinstance(expr, ast.Dict):
            return all(self._host(v, at, seen) for v in expr.values)
        if isinstance(expr, ast.Starred):
            return self._host(expr.value, at, seen)
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            if not all(self._host(g.iter, at, seen)
                       for g in expr.generators):
                return False
            # over a host iterable the comprehension targets are host
            local = seen | {("comp-local", n)
                            for n in _comp_targets(expr)}
            if isinstance(expr, ast.DictComp):
                return (self._host(expr.key, at, local)
                        and self._host(expr.value, at, local))
            return self._host(expr.elt, at, local)
        if isinstance(expr, ast.BinOp):
            return (self._host(expr.left, at, seen)
                    and self._host(expr.right, at, seen))
        if isinstance(expr, ast.UnaryOp):
            return self._host(expr.operand, at, seen)
        if isinstance(expr, ast.BoolOp):
            return all(self._host(v, at, seen) for v in expr.values)
        if isinstance(expr, ast.Compare):
            return self._host(expr.left, at, seen) and all(
                self._host(c, at, seen) for c in expr.comparators)
        if isinstance(expr, ast.IfExp):
            return (self._host(expr.body, at, seen)
                    and self._host(expr.orelse, at, seen))
        if isinstance(expr, (ast.Subscript, ast.Attribute)):
            return self._host(expr.value, at, seen)
        if isinstance(expr, ast.NamedExpr):
            return self._host(expr.value, at, seen)
        if isinstance(expr, ast.Call):
            return self._host_call(expr, at, seen)
        if isinstance(expr, ast.Name):
            return self._host_name(expr.id, at, seen)
        return False

    def _host_call(self, call: ast.Call, at: ast.AST,
                   seen: frozenset) -> bool:
        func = call.func
        # np.foo(...) / math.foo(...) / os.path.join(...): host result.
        base = func
        while isinstance(base, ast.Attribute):
            base = base.value
        if isinstance(func, ast.Attribute) and isinstance(base, ast.Name):
            if base.id in HOST_MODULES:
                return True
            # method on a host-only object (rng.uniform, list.pop, ...)
            if self._host_name(base.id, at, seen):
                return True
            return False
        if isinstance(func, ast.Name):
            if func.id in ("range", "len"):
                # always a host int/range, whatever the argument is
                return True
            if func.id in _HOST_BUILTINS:
                args: List[ast.AST] = list(call.args)
                args.extend(kw.value for kw in call.keywords)
                return all(self._host(a, at, seen) for a in args)
            return False
        return False

    def _host_name(self, var: str, at: ast.AST, seen: frozenset) -> bool:
        if ("comp-local", var) in seen:
            return True
        key = (var, id(at))
        if key in seen:
            # Cycle through a loop-carried binding: this chain adds no
            # non-host source of its own.
            return True
        seen = seen | {key}
        defs = self.defs_of(var, at)
        if not defs:
            return False  # parameter-at-entry handled below, or global
        for d in defs:
            if isinstance(d, Entry):
                return False  # function parameter: may be a device value
            if not self._host_def(var, d, seen):
                return False
        return True

    def _host_def(self, var: str, d: ast.AST, seen: frozenset) -> bool:
        if isinstance(d, (ast.Import, ast.ImportFrom)):
            # Imported *names* are code objects/modules, not device data.
            return True
        if isinstance(d, ast.Assign):
            return self._host(d.value, d, seen)
        if isinstance(d, ast.AnnAssign):
            return d.value is not None and self._host(d.value, d, seen)
        if isinstance(d, ast.AugAssign):
            # x += v: old x reaches this statement too
            return (self._host(d.value, d, seen)
                    and self._host_name(var, d, seen))
        if isinstance(d, (ast.For, ast.AsyncFor)):
            return self._host(d.iter, d, seen)
        if isinstance(d, (ast.With, ast.AsyncWith)):
            return all(self._host(i.context_expr, d, seen)
                       for i in d.items)
        if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return True  # a def object, not data
        return False


def analyze_function(fn: ast.AST) -> FunctionAnalysis:
    """Build (and cache on the node) the per-function analysis."""
    cached = getattr(fn, "_repro_dataflow", None)
    if cached is None:
        cached = FunctionAnalysis(fn)
        try:
            fn._repro_dataflow = cached  # type: ignore[attr-defined]
        except (AttributeError, TypeError):  # pragma: no cover
            pass
    return cached


# ---------------------------------------------------------------------------
# Generic forward abstract-state fixpoint (typestate driver)
# ---------------------------------------------------------------------------

S = TypeVar("S")


def propagate(cfg: CFG, init: S,
              transfer: Callable[[ast.AST, S], S],
              join: Callable[[Iterable[S]], S],
              ) -> Dict[ast.AST, S]:
    """Run a forward dataflow pass to fixpoint.

    ``init`` seeds the synthetic entry node; ``transfer(node, state)``
    returns the state *after* executing ``node``; ``join`` merges the
    out-states of multiple predecessors.  Returns the IN state of every
    node (the state the typestate machine is in when the statement
    starts executing).  ``transfer`` must be monotone and states must
    support ``==``; the driver re-queues successors until nothing
    changes."""
    in_states: Dict[ast.AST, S] = {cfg.entry: init}
    out_states: Dict[ast.AST, S] = {}
    work: List[ast.AST] = [cfg.entry]
    iterations = 0
    limit = 50 * max(1, len(cfg.nodes)) * max(1, len(cfg.nodes))
    while work:
        iterations += 1
        if iterations > limit:  # pragma: no cover - non-monotone transfer
            break
        n = work.pop()
        preds = cfg.preds.get(n, ())
        if preds:
            state = join([out_states[p] for p in preds
                          if p in out_states] or [init])
        else:
            state = in_states.get(n, init)
        in_states[n] = state
        new_out = transfer(n, state)
        if out_states.get(n) != new_out:
            out_states[n] = new_out
            work.extend(cfg.succs.get(n, ()))
    return in_states
