"""edgebatch-provenance: padded EdgeBatch fields are masked before use.

``EdgeBatch.w`` uses ``-inf`` as the "absent arc" sentinel and
``EdgeBatch.src``/``.dst`` hold garbage in the padded tail, so raw
arithmetic on either silently corrupts cycle times (``-inf - -inf`` is
NaN; summing a padded column counts ghost arcs).  The PR 6 sentinel
rule catches *literal* ``NEG_INF`` arithmetic; this rule is its
dataflow upgrade: it follows values that *flow out of* ``.w``/``.src``
on a tracked batch and flags arithmetic or reductions on them unless
the value passed through ``missing_mask``/``isneginf`` masking (or was
handed to an engine entry point, which masks internally) first.

Tracked batches are ``EdgeBatch(...)`` constructor results or names
containing ``batch``/``eb``; the engine modules that implement the
masking are the protocol home and exempt.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from ..dataflow import CFG, Entry, _own_exprs, propagate
from ..lint import FileCtx, Violation, dotted_name
from ..protocols import AttrEvent, Protocol, Transition
from .trace_safety import in_hot_path

RULE_ID = "edgebatch-provenance"

_HOME = ("src/repro/core/maxplus_sparse.py",
         "src/repro/core/maxplus_vec.py",
         "src/repro/kernels/segment_max.py")

#: Declarative face of the protocol (docs table); the field-flow pass
#: below implements it over def-use chains.
EDGEBATCH_PROTOCOL = Protocol(
    name="edgebatch",
    rule_id=RULE_ID,
    description="values read from EdgeBatch.w/.src are masked via "
                "missing_mask/isneginf (or consumed by an engine entry "
                "point) before raw arithmetic or reductions",
    constructors=("EdgeBatch",),
    name_hints=("batch", "eb"),
    home=_HOME,
    initial="raw",
    hint_initial="raw",
    states=("raw", "masked"),
    attr_events=(AttrEvent("w", "read_field"),
                 AttrEvent("src", "read_field")),
    transitions=(Transition("mask", ("*",), "masked"),),
    errors={
        ("raw", "arith"):
            "raw arithmetic on an unmasked EdgeBatch field: the padded "
            "tail is -inf/garbage, so the result is NaN or counts "
            "ghost arcs; apply missing_mask first",
    },
)

_MASKERS = ("missing_mask", "np.isneginf", "numpy.isneginf",
            "jnp.isneginf", "np.isinf", "numpy.isinf", "jnp.isinf",
            "np.isfinite", "numpy.isfinite", "jnp.isfinite")

_REDUCERS = ("sum", "mean", "prod", "cumsum", "max", "min", "dot",
             "matmul", "exp", "log", "sqrt", "abs", "average")

_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv,
              ast.Mod, ast.Pow)

State = Tuple[Tuple[str, FrozenSet[str]], ...]


def _batch_hinted(name: Optional[str]) -> bool:
    if not name:
        return False
    leaf = name.rsplit(".", 1)[-1].lower()
    return "batch" in leaf or leaf in ("eb", "sub")


def _field_read(expr: ast.AST, batch_vars: Iterable[str]
                ) -> Optional[str]:
    """'.w'/'.src' read off a tracked batch -> the field name."""
    if isinstance(expr, ast.Attribute) and expr.attr in ("w", "src"):
        recv = dotted_name(expr.value)
        if recv is not None and (recv in set(batch_vars)
                                 or _batch_hinted(recv)):
            return expr.attr
    return None


def _constructed_batches(fn: ast.AST) -> FrozenSet[str]:
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Call):
            ctor = dotted_name(node.value.func)
            if ctor and ctor.rsplit(".", 1)[-1] == "EdgeBatch":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out.add(tgt.id)
    return frozenset(out)


def _is_masker(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    return name is not None and (
        name in _MASKERS or name.rsplit(".", 1)[-1] == "missing_mask")


class EdgeBatchProvenanceRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if ctx.path in _HOME or ctx.path.startswith(("tests/",
                                                     "benchmarks/")):
            return []
        if not in_hot_path(ctx):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._check_fn(ctx, node))
        return out

    def _check_fn(self, ctx: FileCtx, fn: ast.AST) -> List[Violation]:
        batches = _constructed_batches(fn)
        # quick reject: no tracked field read anywhere in the function
        if not any(_field_read(n, batches) for n in ast.walk(fn)):
            return []
        cfg = CFG(fn)
        init: State = ()

        def _events(m: Dict[str, FrozenSet[str]], node: ast.stmt,
                    report: Optional[List[ast.AST]] = None) -> None:
            # 1. track `v = batch.w` bindings
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                tgt = node.targets[0].id
                if _field_read(node.value, batches):
                    m[tgt] = frozenset({"raw"})
                elif tgt in m:
                    del m[tgt]  # rebound to something else
            for expr in _own_exprs(node):
                for sub in ast.walk(expr):
                    # 2. masking marks the operand var masked
                    if isinstance(sub, ast.Call) and _is_masker(sub):
                        for arg in sub.args:
                            if isinstance(arg, ast.Name) and \
                                    arg.id in m:
                                m[arg.id] = frozenset({"masked"})
                    # 3. raw arithmetic / reductions on tracked vars or
                    #    inline field reads
                    elif isinstance(sub, ast.BinOp) and isinstance(
                            sub.op, _ARITH_OPS):
                        for side in (sub.left, sub.right):
                            if self._raw_operand(side, m, batches):
                                if report is not None:
                                    report.append(sub)
                    elif isinstance(sub, ast.Call):
                        name = dotted_name(sub.func) or ""
                        leaf = name.rsplit(".", 1)[-1]
                        if leaf in _REDUCERS:
                            for arg in sub.args:
                                if self._raw_operand(arg, m, batches):
                                    if report is not None:
                                        report.append(sub)
                        else:
                            # obligation transfers to the callee
                            for arg in list(sub.args) + [
                                    kw.value for kw in sub.keywords]:
                                if isinstance(arg, ast.Name) and \
                                        arg.id in m:
                                    del m[arg.id]

        def transfer(node: ast.AST, state: State) -> State:
            if isinstance(node, Entry) or not isinstance(node, ast.stmt):
                return state
            m = dict(state)
            _events(m, node)
            return tuple(sorted(m.items()))

        def join(states: Iterable[State]) -> State:
            merged: Dict[str, FrozenSet[str]] = {}
            for st in states:
                for k, v in st:
                    merged[k] = merged.get(k, frozenset()) | v
            return tuple(sorted(merged.items()))

        in_states = propagate(cfg, init, transfer, join)

        out: List[Violation] = []
        seen = set()
        for stmt in cfg.statements():
            state = in_states.get(stmt)
            if state is None:
                continue
            sites: List[ast.AST] = []
            _events(dict(state), stmt, report=sites)
            for site in sites:
                if id(site) in seen:
                    continue
                seen.add(id(site))
                out.append(ctx.violation(
                    self.id, site,
                    EDGEBATCH_PROTOCOL.errors[("raw", "arith")]))
        return out

    def _raw_operand(self, expr: ast.AST,
                     m: Dict[str, FrozenSet[str]],
                     batches: FrozenSet[str]) -> bool:
        """unmasked on every path: a tracked var whose state is exactly
        {'raw'}, or an inline `batch.w` field read."""
        if isinstance(expr, ast.Name):
            return m.get(expr.id) == frozenset({"raw"})
        if isinstance(expr, ast.Subscript):
            return self._raw_operand(expr.value, m, batches)
        return _field_read(expr, batches) is not None
