"""Rule registry for repro-lint.

Each rule is a class with an ``id`` string and a
``check(ctx: FileCtx) -> List[Violation]`` method.  Adding a rule means
writing a module here and appending the class to ``ALL_RULES``.

The four ``*-protocol``/provenance/purity rules added in PR 10 are
*temporal*: they run typestate machines from
:mod:`repro.analysis.protocols` over the per-function CFGs of
:mod:`repro.analysis.dataflow` instead of pattern-matching single
nodes.  ``PROTOCOL_RULES`` maps their ids to the declarative machines
so the docs gate can assert the architecture table matches the code.
"""

from .trace_safety import TraceSafetyRule
from .rng_discipline import RngDisciplineRule
from .sentinel import SentinelDisciplineRule
from .dtype_discipline import DtypeDisciplineRule
from .contracts_rule import EngineContractRule
from .obs_purity import ObsPurityRule
from .effect_purity import EffectPurityRule
from .slot_protocol import SLOT_PROTOCOL, SlotProtocolRule
from .pricer_protocol import PRICER_PROTOCOL, PricerProtocolRule
from .edgebatch_provenance import EDGEBATCH_PROTOCOL, \
    EdgeBatchProvenanceRule

ALL_RULES = [
    TraceSafetyRule,
    RngDisciplineRule,
    SentinelDisciplineRule,
    DtypeDisciplineRule,
    EngineContractRule,
    ObsPurityRule,
    EffectPurityRule,
    SlotProtocolRule,
    PricerProtocolRule,
    EdgeBatchProvenanceRule,
]

#: rule id -> declarative typestate machine (docs table + replay).
PROTOCOL_RULES = {
    SLOT_PROTOCOL.rule_id: SLOT_PROTOCOL,
    PRICER_PROTOCOL.rule_id: PRICER_PROTOCOL,
    EDGEBATCH_PROTOCOL.rule_id: EDGEBATCH_PROTOCOL,
}

__all__ = ["ALL_RULES", "PROTOCOL_RULES", "TraceSafetyRule",
           "RngDisciplineRule", "SentinelDisciplineRule",
           "DtypeDisciplineRule", "EngineContractRule", "ObsPurityRule",
           "EffectPurityRule", "SlotProtocolRule", "PricerProtocolRule",
           "EdgeBatchProvenanceRule"]
