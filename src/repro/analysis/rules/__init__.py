"""Rule registry for repro-lint.

Each rule is a class with an ``id`` string and a
``check(ctx: FileCtx) -> List[Violation]`` method.  Adding a rule means
writing a module here and appending the class to ``ALL_RULES``.
"""

from .trace_safety import TraceSafetyRule
from .rng_discipline import RngDisciplineRule
from .sentinel import SentinelDisciplineRule
from .dtype_discipline import DtypeDisciplineRule
from .contracts_rule import EngineContractRule
from .obs_purity import ObsPurityRule

ALL_RULES = [
    TraceSafetyRule,
    RngDisciplineRule,
    SentinelDisciplineRule,
    DtypeDisciplineRule,
    EngineContractRule,
    ObsPurityRule,
]

__all__ = ["ALL_RULES", "TraceSafetyRule", "RngDisciplineRule",
           "SentinelDisciplineRule", "DtypeDisciplineRule",
           "EngineContractRule", "ObsPurityRule"]
