"""pricer-protocol: DeltaPricer certificate discipline.

``DeltaPricer.price`` returns a :class:`PricedMove` — a *certificate*
(cycle time + potentials + critical arcs) valid against the pricer's
current graph.  ``commit`` applies it and mutates the graph, so the
temporal contract is:

* ``commit`` only with a live certificate — committing before any
  ``price``, or committing a ``PricedMove`` after an intervening
  ``price``/``update``/``reanchor``/``commit`` changed the graph,
  silently corrupts the Eq. 3/4 incremental max-cycle-mean state;
* ``force_full=True`` (a literal) defeats the delta path and belongs in
  tests/benchmarks only — production callers thread a variable so the
  CLI can choose.

Tracking is per-object over the CFG: variables bound from
``DeltaPricer(...)`` (or whose name contains ``pricer``) are followed;
``schedule.price(...)`` — a different, stateless ``price`` — is never
tracked.  Reporting is "must"-style: a certificate is flagged only when
it is stale on *every* path into the commit.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from ..dataflow import CFG, Entry, _own_exprs, propagate
from ..lint import FileCtx, Violation, dotted_name
from ..protocols import MethodEvent, Protocol, Transition
from .trace_safety import in_hot_path

RULE_ID = "pricer-protocol"

_HOME = ("src/repro/core/maxplus_sparse.py",)

#: Declarative machine (docs table + runtime replay); the static pass
#: below adds per-certificate tracking on top of it.
PRICER_PROTOCOL = Protocol(
    name="pricer",
    rule_id=RULE_ID,
    description="DeltaPricer.price -> commit pairing; no stale "
                "PricedMove commits after an intervening "
                "price/update/reanchor; literal force_full=True only "
                "in tests/benchmarks",
    constructors=("DeltaPricer",),
    name_hints=("pricer",),
    home=_HOME,
    initial="anchored",
    hint_initial="external",
    states=("anchored", "priced"),
    method_events=(
        MethodEvent("price", "price"),
        MethodEvent("update", "update"),
        MethodEvent("commit", "commit"),
        MethodEvent("reanchor", "reanchor"),
    ),
    transitions=(
        Transition("price", ("*",), "priced"),
        Transition("update", ("*",), "anchored"),
        Transition("commit", ("*",), "anchored"),
        Transition("reanchor", ("*",), "anchored"),
    ),
    errors={
        ("anchored", "commit"):
            "commit with no live certificate: nothing was priced "
            "against the current graph",
    },
)

# abstract value domain for tracked keys --------------------------------
# pricer key "p"            -> subset of {"anchored", "priced"}
# certificate key "p::c"    -> subset of {"live", "stale"}
State = Tuple[Tuple[str, FrozenSet[str]], ...]


def _receiver(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Attribute):
        return dotted_name(call.func.value)
    return None


def _is_pricer_key(key: str) -> bool:
    return "::" not in key


def _tracked_pricers(fn: ast.AST) -> Dict[str, bool]:
    """pricer key -> constructed-here?  Keys are constructor-bound
    targets plus any ``*pricer*`` receivers of protocol methods."""
    out: Dict[str, bool] = {}
    methods = {ev.method for ev in PRICER_PROTOCOL.method_events}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Call):
            ctor = dotted_name(node.value.func)
            if ctor and ctor.rsplit(".", 1)[-1] in \
                    PRICER_PROTOCOL.constructors:
                for tgt in node.targets:
                    key = dotted_name(tgt) if isinstance(
                        tgt, (ast.Name, ast.Attribute)) else None
                    if key:
                        out[key] = True
        elif isinstance(node, ast.Call) and isinstance(node.func,
                                                       ast.Attribute):
            if node.func.attr in methods:
                recv = dotted_name(node.func.value)
                if recv and "pricer" in recv.rsplit(
                        ".", 1)[-1].lower():
                    out.setdefault(recv, False)
    return out


class PricerProtocolRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if ctx.path in _HOME:
            return []
        out: List[Violation] = []
        if not ctx.path.startswith(("tests/", "benchmarks/")):
            out.extend(self._check_force_full(ctx))
        if not in_hot_path(ctx):
            return out
        if not ctx.path.startswith(("tests/", "benchmarks/")):
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    out.extend(self._check_certificates(ctx, node))
        return out

    # -- facet: literal force_full=True outside tests/benchmarks -----------

    def _check_force_full(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("price", "update")):
                continue
            for kw in node.keywords:
                if kw.arg == "force_full" and isinstance(
                        kw.value, ast.Constant) and kw.value.value is True:
                    out.append(ctx.violation(
                        self.id, node,
                        "literal force_full=True defeats the delta "
                        "pricing path; production callers must thread "
                        "a variable (tests/benchmarks are exempt)"))
        return out

    # -- facet: price -> commit pairing, stale certificates ----------------

    def _check_certificates(self, ctx: FileCtx, fn: ast.AST
                            ) -> List[Violation]:
        pricers = _tracked_pricers(fn)
        if not pricers:
            return []
        cfg = CFG(fn)
        init_map = {
            p: frozenset({"anchored" if constructed else "priced"})
            for p, constructed in pricers.items()}
        # externally owned pricers start "priced" so a bare commit on
        # them is never a must-error (their history is unknown)
        init: State = tuple(sorted(init_map.items()))

        def _apply(m: Dict[str, FrozenSet[str]], node: ast.stmt) -> None:
            # escape: pricer passed as a call argument drops tracking
            for expr in _own_exprs(node):
                for sub in ast.walk(expr):
                    if isinstance(sub, ast.Call):
                        for arg in list(sub.args) + [
                                kw.value for kw in sub.keywords]:
                            key = dotted_name(arg) if isinstance(
                                arg, (ast.Name, ast.Attribute)) else None
                            if key in m and _is_pricer_key(key):
                                for k in [k for k in m
                                          if k == key or
                                          k.startswith(key + "::")]:
                                    del m[k]
            bind_target: Optional[str] = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                bind_target = node.targets[0].id
            for expr in _own_exprs(node):
                for sub in ast.walk(expr):
                    if not (isinstance(sub, ast.Call) and isinstance(
                            sub.func, ast.Attribute)):
                        continue
                    p = _receiver(sub)
                    if p not in m or not _is_pricer_key(p):
                        continue
                    method = sub.func.attr
                    if method == "price":
                        for k in list(m):
                            if k.startswith(p + "::"):
                                m[k] = frozenset({"stale"})
                        m[p] = frozenset({"priced"})
                        if bind_target and sub is node.value:
                            m[f"{p}::{bind_target}"] = frozenset({"live"})
                    elif method in ("update", "reanchor", "commit"):
                        for k in list(m):
                            if k.startswith(p + "::"):
                                m[k] = frozenset({"stale"})
                        m[p] = frozenset({"anchored"})
            # rebinding a certificate variable to anything else unbinds it
            if bind_target is not None and not (
                    isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Attribute)
                    and node.value.func.attr == "price"):
                for k in list(m):
                    if k.endswith("::" + bind_target):
                        del m[k]

        def transfer(node: ast.AST, state: State) -> State:
            if isinstance(node, Entry) or not isinstance(node, ast.stmt):
                return state
            m = dict(state)
            _apply(m, node)
            return tuple(sorted(m.items()))

        def join(states: Iterable[State]) -> State:
            merged: Dict[str, FrozenSet[str]] = {}
            for st in states:
                for k, v in st:
                    merged[k] = merged.get(k, frozenset()) | v
            return tuple(sorted(merged.items()))

        in_states = propagate(cfg, init, transfer, join)

        out: List[Violation] = []
        for stmt in cfg.statements():
            state = in_states.get(stmt)
            if state is None:
                continue
            m = dict(state)
            for expr in _own_exprs(stmt):
                for sub in ast.walk(expr):
                    if not (isinstance(sub, ast.Call) and isinstance(
                            sub.func, ast.Attribute)
                            and sub.func.attr == "commit"):
                        continue
                    p = _receiver(sub)
                    if p not in m or not _is_pricer_key(p):
                        continue
                    ck = (f"{p}::{sub.args[0].id}"
                          if sub.args and isinstance(sub.args[0], ast.Name)
                          else None)
                    if ck is not None and m.get(ck) == \
                            frozenset({"stale"}):
                        out.append(ctx.violation(
                            self.id, sub,
                            f"committing stale certificate "
                            f"'{sub.args[0].id}': an intervening "
                            f"price/update/reanchor/commit changed "
                            f"{p}'s graph since it was priced; "
                            f"re-price against the current graph"))
                    elif m[p] == frozenset({"anchored"}):
                        out.append(ctx.violation(
                            self.id, sub,
                            f"{p}.commit(...) "
                            + PRICER_PROTOCOL.errors[
                                ("anchored", "commit")]))
            _apply(m, stmt)
        return out
