"""obs-purity: observability never reaches inside traced code.

``repro.obs`` spans read host wall clocks, metrics mutate process-local
registries under a lock, and the flight recorder writes JSONL — all
host effects.  Under ``jax.jit`` they either run once at trace time
(recording nothing and timing the *trace*, not the computation) or
force host syncs into the compiled path.  The supported pattern is
host-level only: a ``@span_fn`` decorator *above* the entry point (the
wrapper body never traces) or a ``with span(...)`` around the call that
launches the traced work.  This rule flags, in hot modules:

* any call through a name imported from ``repro.obs`` (``span``,
  ``obs_metrics.counter``, a recorder's ``emit``...) inside a traced
  body;
* an ``import``/``from ... import`` of an obs module inside a traced
  body (lazy imports don't make host effects trace-safe);
* a ``span_fn``/``span`` decorator on a function the project marks as
  traced — decorating a ``*_jax`` variant would bake the wrapper's
  clock reads into every caller's jit.
"""

from __future__ import annotations

import ast
from typing import List, Set

from ..lint import FileCtx, Violation, body_nodes, dotted_name, \
    traced_functions
from .trace_safety import in_hot_path

RULE_ID = "obs-purity"

_SPAN_DECORATORS = ("span", "span_fn")


def _is_obs_module(module: str) -> bool:
    """True for 'repro.obs', 'repro.obs.spans', 'obs.metrics' (relative
    ``from ..obs.metrics import ...`` resolves to module='obs.metrics')."""
    parts = module.split(".")
    return "obs" in parts and (parts[0] in ("repro", "obs")
                               or parts == ["obs"] or "repro" in parts)


def obs_bound_names(tree: ast.AST) -> Set[str]:
    """Local names bound to repro.obs imports anywhere in the file."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and _is_obs_module(node.module):
            for alias in node.names:
                names.add(alias.asname or alias.name)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if _is_obs_module(alias.name):
                    names.add((alias.asname or alias.name).split(".")[0])
    return names


def _base_name(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return ""


class ObsPurityRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if not in_hot_path(ctx):
            return []
        bound = obs_bound_names(ctx.tree)
        out: List[Violation] = []
        for fn in traced_functions(ctx):
            for node in body_nodes(fn):
                if isinstance(node, ast.Call):
                    base = _base_name(node.func)
                    if base in bound:
                        out.append(ctx.violation(
                            self.id, node,
                            f"obs call '{dotted_name(node.func) or base}"
                            f"(...)' inside traced function '{fn.name}': "
                            f"span clocks / metric locks / recorder "
                            f"writes are host effects — instrument the "
                            f"host-level caller instead"))
                elif isinstance(node, ast.ImportFrom):
                    if node.module and _is_obs_module(node.module):
                        out.append(ctx.violation(
                            self.id, node,
                            f"repro.obs imported inside traced function "
                            f"'{fn.name}'; a lazy import does not make "
                            f"host effects trace-safe"))
                elif isinstance(node, ast.Import):
                    if any(_is_obs_module(a.name) for a in node.names):
                        out.append(ctx.violation(
                            self.id, node,
                            f"repro.obs imported inside traced function "
                            f"'{fn.name}'; a lazy import does not make "
                            f"host effects trace-safe"))
            for dec in fn.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = dotted_name(target) or ""
                if name.rsplit(".", 1)[-1] in _SPAN_DECORATORS:
                    out.append(ctx.violation(
                        self.id, dec,
                        f"span decorator on traced function '{fn.name}' "
                        f"bakes host clock reads into every caller's "
                        f"jit; decorate the host-level entry point "
                        f"(never the *_jax variant)"))
        return out
