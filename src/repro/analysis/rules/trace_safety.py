"""trace-safety: host syncs and Python control flow inside traced code.

A ``float()``/``.item()``/``np.*`` call on a traced value inside a
``jax.jit``/``lax.scan``/``lax.fori_loop`` body either fails at trace
time or — worse — silently constant-folds a value that should be
data-dependent.  Python ``if``/``while`` on a tracer raises a
concretization error only on the untested branch shape.  (The host-
loop sync heuristics that used to live here moved to the dataflow-
based ``effect-purity`` rule, which can tell host scalars from device
values and so no longer needs grandfathering.)
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..lint import (FileCtx, Violation, body_nodes, dotted_name,
                    traced_functions)

RULE_ID = "trace-safety"

_KNOWN_SRC_PREFIXES = ("src/", "tests/", "benchmarks/", "scripts/",
                       "examples/", "docs/")


def in_hot_path(ctx: FileCtx) -> bool:
    """Hot modules per config; bare snippets (tests) count as hot."""
    if ctx.path.startswith(ctx.config.hot_prefixes):
        return True
    return not ctx.path.startswith(_KNOWN_SRC_PREFIXES)


def _is_np(name: str) -> bool:
    return name in ("np", "numpy")


def _base_name(node: ast.AST) -> str:
    """Leftmost Name of an expression like ``a[i].b`` -> 'a'."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return ""


class TraceSafetyRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if not in_hot_path(ctx):
            return []
        out: List[Violation] = []
        traced = traced_functions(ctx)
        for fn in traced:
            out.extend(self._check_traced_body(ctx, fn))
        return out

    # -- facet 1+2+3: inside traced bodies ---------------------------------

    def _check_traced_body(self, ctx: FileCtx, fn: ast.AST
                           ) -> List[Violation]:
        out: List[Violation] = []
        params = _param_names(fn)
        for node in body_nodes(fn):
            if isinstance(node, ast.Call):
                out.extend(self._check_call_in_trace(ctx, fn, node))
            elif isinstance(node, (ast.If, ast.While)):
                bad = _tracer_names_in_test(node.test, params)
                if bad:
                    names = ", ".join(sorted(bad))
                    out.append(ctx.violation(
                        self.id, node,
                        f"Python branch on possibly-traced value(s) "
                        f"{names} inside traced function "
                        f"'{fn.name}'; use jnp.where/lax.cond or hoist "
                        f"the decision out of the traced body"))
        return out

    def _check_call_in_trace(self, ctx: FileCtx, fn: ast.AST,
                             node: ast.Call) -> List[Violation]:
        out: List[Violation] = []
        callee = node.func
        if isinstance(callee, ast.Name):
            if callee.id in ("float", "bool") and node.args and \
                    not isinstance(node.args[0], ast.Constant):
                out.append(ctx.violation(
                    self.id, node,
                    f"{callee.id}() on a non-constant inside traced "
                    f"function '{fn.name}' forces a host sync (or a "
                    f"concretization error); keep the value on device"))
            elif callee.id == "int" and node.args and isinstance(
                    node.args[0], (ast.Subscript, ast.Call)):
                out.append(ctx.violation(
                    self.id, node,
                    f"int() on a computed value inside traced function "
                    f"'{fn.name}' is a host sync; static ints should "
                    f"arrive as arguments"))
        elif isinstance(callee, ast.Attribute):
            if callee.attr in ("item", "tolist") and not node.args:
                out.append(ctx.violation(
                    self.id, node,
                    f".{callee.attr}() inside traced function "
                    f"'{fn.name}' is a host sync"))
            else:
                name = dotted_name(callee)
                if name and "." in name:
                    base, leaf = name.split(".", 1)
                    if _is_np(base) and "." not in leaf and \
                            leaf not in ctx.config.np_trace_constants:
                        out.append(ctx.violation(
                            self.id, node,
                            f"np.{leaf}(...) inside traced function "
                            f"'{fn.name}' executes on host at trace "
                            f"time; use jnp.{leaf} so it stays in the "
                            f"traced graph"))
        return out

def _param_names(fn: ast.AST) -> Set[str]:
    args = fn.args
    names = {a.arg for a in args.args + args.kwonlyargs
             + getattr(args, "posonlyargs", [])}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names


_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_EXEMPT_CALLS = {"len", "isinstance", "getattr", "hasattr", "callable"}


def _tracer_names_in_test(test: ast.AST, params: Set[str]) -> Set[str]:
    """Param names used as values (not via shape/ndim/len) in a branch
    test.  ``is None`` / ``is not None`` comparisons are exempt."""
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return set()

    offending: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return  # x.shape[...] is static under trace
        if isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else ""
            if fname in _EXEMPT_CALLS:
                return
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return
        if isinstance(node, ast.Name) and node.id in params:
            offending.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(test)
    return offending
