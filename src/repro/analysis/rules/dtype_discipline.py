"""dtype-discipline: f64 is load-bearing, defaults are not.

The bit-for-bit equivalence chain (legacy == dense == sparse) and the
survivor-migration identity (PR 5) hold only in float64.  A dtype-less
``np.zeros`` in an engine module inherits whatever the platform
default is; a dtype-less ``jnp.zeros`` is *float32*.  And any f32 cast
inside the bit-identity consensus/migration functions breaks the
identity silently — the result is merely *close*, which is exactly the
failure mode the equivalence tests exist to rule out.
"""

from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation, dotted_name

RULE_ID = "dtype-discipline"

_CTORS = {"zeros", "ones", "empty", "full"}
_F32_TOKENS = {"float32", "f32", "bfloat16", "bf16", "float16", "fp16"}


class DtypeDisciplineRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        if ctx.path in ctx.config.engine_modules:
            out.extend(self._check_ctors(ctx))
        out.extend(self._check_bit_identity(ctx))
        return out

    def _check_ctors(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None or "." not in name:
                continue
            base, leaf = name.rsplit(".", 1)
            if base not in ("np", "numpy", "jnp") or leaf not in _CTORS:
                continue
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
            # np.full(shape, fill, dtype) — third positional counts too.
            if leaf == "full" and len(node.args) >= 3:
                has_dtype = True
            elif leaf != "full" and len(node.args) >= 2:
                has_dtype = True
            if not has_dtype:
                out.append(ctx.violation(
                    self.id, node,
                    f"{base}.{leaf}(...) without dtype= in an engine "
                    f"module: the f64 bit-identity chain must not "
                    f"depend on platform defaults"
                    + (" (jnp defaults to float32!)"
                       if base == "jnp" else "")))
        return out

    def _check_bit_identity(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        targets = set(ctx.config.bit_identity_funcs)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node.name not in targets:
                continue
            for sub in ast.walk(node):
                token = None
                if isinstance(sub, ast.Attribute) and \
                        sub.attr in _F32_TOKENS:
                    token = sub.attr
                elif isinstance(sub, ast.Name) and sub.id in _F32_TOKENS:
                    token = sub.id
                elif isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str) and \
                        sub.value in _F32_TOKENS:
                    token = sub.value
                if token:
                    out.append(ctx.violation(
                        self.id, sub,
                        f"'{token}' inside bit-identity function "
                        f"'{node.name}': consensus/migration must stay "
                        f"f64 end-to-end or the bit-for-bit migration "
                        f"identity breaks"))
        return out
