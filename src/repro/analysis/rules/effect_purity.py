"""effect-purity: dataflow-inferred host effects where they cost.

The PR 6 trace-safety rule flagged every ``float()``/``.item()``/
``np.asarray()`` inside a host loop, because syntactically it cannot
tell ``float(rng.uniform())`` (host value, free) from
``float(step_fn(x))`` (a per-iteration device→host sync).  That
imprecision grandfathered ~a dozen baseline fingerprints.  This rule
replaces those heuristics with :mod:`repro.analysis.dataflow` origin
inference:

* **loop syncs** — a scalar-sync call inside a host loop is flagged
  only when the operand is *not* provably host-only (some definition
  chain reaches a function parameter or an unknown call);
* **unbatched transfers** — two-plus separate host transfers from one
  tuple-unpacked device computation are flagged only when the
  transferred names are not host-only;
* **traced host effects** — ``print``/``open``/file-system/clock/
  logging calls and ``global`` writes inside traced roots and their
  ``*_jax`` twins run once at trace time and never again, which is a
  silent logic change, not just a slowdown.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..dataflow import FunctionAnalysis, analyze_function
from ..lint import FileCtx, Violation, body_nodes, dotted_name, \
    traced_functions
from .trace_safety import _base_name, in_hot_path

RULE_ID = "effect-purity"

#: Module roots whose calls are host effects inside a traced body.
_EFFECT_MODULES = {"os", "sys", "time", "logging", "subprocess",
                   "socket", "shutil", "tempfile"}
_EFFECT_BUILTINS = {"print", "open", "input", "breakpoint"}


def _host(an: FunctionAnalysis, expr: ast.AST) -> bool:
    """host_only, but conservative (False) when the expression is not
    reachable from the function's own CFG (nested lambdas etc.)."""
    if an.enclosing_stmt(expr) is None:
        return False
    return an.host_only(expr)


class EffectPurityRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if not in_hot_path(ctx):
            return []
        out: List[Violation] = []
        traced = traced_functions(ctx)
        jax_twins = {
            n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name.endswith("_jax")}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node in traced or node in jax_twins:
                out.extend(self._check_traced_effects(ctx, node))
            if node not in traced:
                an = analyze_function(node)
                out.extend(self._check_loop_syncs(ctx, node, an))
                out.extend(self._check_unbatched_transfers(ctx, node, an))
        return out

    # -- facet 1: per-iteration device syncs in host loops -----------------

    def _check_loop_syncs(self, ctx: FileCtx, fn: ast.AST,
                          an: FunctionAnalysis) -> List[Violation]:
        out: List[Violation] = []
        seen: Set[int] = set()
        for node in body_nodes(fn):
            if not isinstance(node, (ast.For, ast.While)):
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call) or id(sub) in seen:
                    continue
                seen.add(id(sub))
                flagged = None
                operand: Optional[ast.AST] = None
                if isinstance(sub.func, ast.Name) and \
                        sub.func.id == "float" and sub.args and \
                        not isinstance(sub.args[0], ast.Constant):
                    flagged, operand = "float(...)", sub.args[0]
                elif isinstance(sub.func, ast.Attribute):
                    if sub.func.attr == "item" and not sub.args:
                        flagged, operand = ".item()", sub.func.value
                    else:
                        name = dotted_name(sub.func)
                        if name in ("np.asarray", "numpy.asarray") \
                                and sub.args:
                            flagged = "np.asarray(...)"
                            operand = sub.args[0]
                if flagged is None or operand is None:
                    continue
                if _host(an, operand):
                    continue  # host-origin scalar: no device sync
                out.append(ctx.violation(
                    self.id, sub,
                    f"{flagged} inside a loop in hot function "
                    f"'{fn.name}' syncs a possibly-device value every "
                    f"iteration; batch the transfer outside the loop "
                    f"or keep the reduction on device"))
        return out

    # -- facet 2: unbatched device→host transfers --------------------------

    def _check_unbatched_transfers(self, ctx: FileCtx, fn: ast.AST,
                                   an: FunctionAnalysis
                                   ) -> List[Violation]:
        out: List[Violation] = []
        stmts = list(body_nodes(fn))
        groups: List[tuple] = []  # (assign node, {unpacked names})
        for node in stmts:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Tuple) \
                    and isinstance(node.value, ast.Call):
                if _host(an, node.value):
                    continue  # host computation: transfers are free
                names = {elt.id for elt in node.targets[0].elts
                         if isinstance(elt, ast.Name)}
                if len(names) >= 2:
                    groups.append((node, names))
        if not groups:
            return out
        sync_counts: Dict[int, Set[str]] = {i: set()
                                            for i in range(len(groups))}
        for node in stmts:
            if not isinstance(node, ast.Call):
                continue
            target = None
            callee = dotted_name(node.func)
            if callee in ("np.asarray", "numpy.asarray", "np.array",
                          "numpy.array", "np.copy", "numpy.copy") \
                    and node.args:
                target = _base_name(node.args[0])
            elif isinstance(node.func, ast.Name) and \
                    node.func.id == "float" and node.args:
                target = _base_name(node.args[0])
            if not target:
                continue
            for i, (assign, names) in enumerate(groups):
                if target in names and node.lineno > assign.lineno:
                    sync_counts[i].add(target)
        for i, (assign, names) in enumerate(groups):
            hit = sync_counts[i]
            if len(hit) >= 2:
                out.append(ctx.violation(
                    self.id, assign,
                    f"{len(hit)} separate host transfers "
                    f"({', '.join(sorted(hit))}) from one device "
                    f"computation in '{fn.name}'; fetch them together "
                    f"with a single jax.device_get((...))"))
        return out

    # -- facet 3: host effects inside traced bodies ------------------------

    def _check_traced_effects(self, ctx: FileCtx, fn: ast.AST
                              ) -> List[Violation]:
        out: List[Violation] = []
        for node in body_nodes(fn):
            if isinstance(node, ast.Global):
                out.append(ctx.violation(
                    self.id, node,
                    f"'global' write inside traced function "
                    f"'{fn.name}' runs once at trace time, not per "
                    f"call; thread the state through arguments"))
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func) or ""
                base = name.split(".", 1)[0]
                if name in _EFFECT_BUILTINS or (
                        "." in name and base in _EFFECT_MODULES):
                    out.append(ctx.violation(
                        self.id, node,
                        f"host effect '{name}(...)' inside traced "
                        f"function '{fn.name}' executes at trace time "
                        f"only — it silently disappears from every "
                        f"subsequent call; use jax.debug.* or hoist "
                        f"it out of the traced body"))
        return out
