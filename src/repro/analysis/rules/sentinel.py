"""sentinel-discipline: the -inf "absent arc" sentinel is structural.

``NEG_INF`` marks a *missing* edge in the padded engines, not a number:
``NEG_INF - NEG_INF`` (and ``0 * NEG_INF``) are NaN, and under f32 a
finite pipeline can *produce* -inf by overflow, at which point a raw
``== NEG_INF`` comparison silently misclassifies a real arc as padding.
Arithmetic on the sentinel and raw equality tests are therefore flagged
(``maxplus_vec.missing_mask`` is the sanctioned test); so is any
redefinition of the sentinel outside its home module — there must be
exactly one ``NEG_INF`` object in the project.
"""

from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation

RULE_ID = "sentinel-discipline"

_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv,
              ast.Mod, ast.Pow)


def _is_sentinel(node: ast.AST, names) -> bool:
    return isinstance(node, ast.Name) and node.id in names


class SentinelDisciplineRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        names = set(ctx.config.sentinel_names)
        is_home = ctx.path == ctx.config.sentinel_home
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, _ARITH_OPS):
                if _is_sentinel(node.left, names) or _is_sentinel(
                        node.right, names):
                    out.append(ctx.violation(
                        self.id, node,
                        "arithmetic on the NEG_INF sentinel: "
                        "-inf - -inf and 0 * -inf are NaN (and f32 "
                        "pipelines overflow to -inf); mask absent "
                        "arcs instead of computing through them"))
            elif isinstance(node, ast.UnaryOp) and isinstance(
                    node.op, ast.USub) and _is_sentinel(node.operand,
                                                        names):
                out.append(ctx.violation(
                    self.id, node,
                    "negating NEG_INF produces +inf, which the "
                    "max-plus engines never expect in a weight slot"))
            elif isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                for op, lhs, rhs in zip(node.ops, operands,
                                        operands[1:]):
                    if isinstance(op, (ast.Eq, ast.NotEq)) and (
                            _is_sentinel(lhs, names)
                            or _is_sentinel(rhs, names)):
                        out.append(ctx.violation(
                            self.id, node,
                            "raw ==/!= NEG_INF comparison; use "
                            "maxplus_vec.missing_mask(x) — equality "
                            "reads as a value test and misfires when "
                            "f32 overflow manufactures a -inf"))
                        break
            elif isinstance(node, (ast.Assign, ast.AnnAssign,
                                   ast.AugAssign)):
                targets = (node.targets
                           if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, ast.Name) \
                            and "NEG_INF" in tgt.id and not is_home:
                        out.append(ctx.violation(
                            self.id, node,
                            f"redefinition of sentinel '{tgt.id}' "
                            f"outside {ctx.config.sentinel_home}; "
                            f"import the canonical "
                            f"maxplus_vec.NEG_INF"))
        return out
