"""rng-discipline: all randomness must thread explicit state.

MATCHA stream identity (PR 4) and cross-silo determinism depend on
every random draw flowing through a seeded ``np.random.Generator``, a
``random.Random(seed)`` instance, or a jax PRNG key.  Global
``np.random.*`` mutates hidden process state; an argless
``default_rng()`` seeds from the OS.  Both make runs irreproducible and
— worse — *silently* order-dependent across silos.
"""

from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation, dotted_name

RULE_ID = "rng-discipline"

# stdlib `random` module functions that draw from the global stream.
_GLOBAL_RANDOM_FNS = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "seed", "gauss", "normalvariate", "betavariate",
    "expovariate", "getrandbits", "triangular", "vonmisesvariate"}


class RngDisciplineRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        allowed = set(ctx.config.allowed_np_random)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute):
                name = dotted_name(node)
                if name is None:
                    continue
                parts = name.split(".")
                if len(parts) == 3 and parts[0] in ("np", "numpy") \
                        and parts[1] == "random" \
                        and parts[2] not in allowed:
                    out.append(ctx.violation(
                        self.id, node,
                        f"global-state RNG '{name}': draws mutate the "
                        f"hidden numpy global stream; thread an "
                        f"explicit np.random.default_rng(seed) "
                        f"Generator instead"))
                elif len(parts) == 2 and parts[0] == "random" \
                        and parts[1] in _GLOBAL_RANDOM_FNS:
                    out.append(ctx.violation(
                        self.id, node,
                        f"global-state RNG '{name}': use a "
                        f"random.Random(seed) instance so the stream "
                        f"is owned by the caller"))
            elif isinstance(node, ast.Call):
                callee = dotted_name(node.func)
                if callee is None:
                    continue
                leaf = callee.rsplit(".", 1)[-1]
                if leaf == "default_rng" and not node.args \
                        and not node.keywords:
                    out.append(ctx.violation(
                        self.id, node,
                        "default_rng() without a seed draws entropy "
                        "from the OS; pass an explicit seed or "
                        "SeedSequence"))
        return out
