"""slot-protocol: the versioned hot-swap discipline of fed.gossip.

The training loop rebuilds its jitted step from ``slot.plan``/``
slot.version``; the invariants that keep that sound are temporal:

* on a membership-change path, ``MembershipSlot.swap`` must
  happen-before any resizing swap (``PlanSlot.swap(...,
  allow_resize=True)`` or ``ScheduleSlot.swap_schedule(...,
  silos=...)``) — otherwise the loop re-lowers against a mesh whose
  membership it has not observed;
* a slot's fields (``plan``/``schedule``/``active``/``version``) are
  mutated only by the ``swap*`` methods in the protocol's home module
  (``fed/gossip.py``) — direct stores skip versioning, metrics and
  rollback;
* ``version`` is meaningful only after a swap: reading it off a
  freshly constructed slot observes the pre-protocol ``0``.

Reporting is "must"-style on top of a union join: the ordering facet
fires only when *no* path into the resize performed a membership swap,
so a swap under ``if self.membership_slot is not None:`` keeps the
shared continuation legal exactly like the runtime does.
"""

from __future__ import annotations

import ast
from typing import FrozenSet, Iterable, List, Optional

from ..dataflow import CFG, Entry, propagate, _own_exprs
from ..lint import FileCtx, Violation, dotted_name
from ..protocols import AttrEvent, MethodEvent, Protocol, Replay, \
    Transition, run_protocol
from .trace_safety import in_hot_path

RULE_ID = "slot-protocol"

_HOME = ("src/repro/fed/gossip.py",)

_SLOT_FIELDS = ("plan", "schedule", "active", "version", "history")

#: Per-object machine: a slot constructed in this function must swap
#: before its version is read.  Name-hinted (externally owned) slots
#: carry unknown history and are never flagged.
SLOT_PROTOCOL = Protocol(
    name="slot",
    rule_id=RULE_ID,
    description="MembershipSlot.swap happens-before resizing "
                "PlanSlot/ScheduleSlot swaps; slot fields mutate only "
                "via swap*; version reads only after a swap",
    constructors=("MembershipSlot", "PlanSlot", "ScheduleSlot"),
    name_hints=(),
    home=_HOME,
    initial="fresh",
    states=("fresh", "swapped"),
    method_events=(
        MethodEvent("swap", "swap"),
        MethodEvent("swap_schedule", "swap"),
    ),
    attr_events=(AttrEvent("version", "read_version"),),
    transitions=(Transition("swap", ("*",), "swapped"),),
    errors={
        ("fresh", "read_version"):
            "version read on a never-swapped slot observes the "
            "pre-protocol 0; swap first (or branch on the slot, not "
            "its version)",
    },
)


#: The cross-object ordering machine.  The static facet below
#: interprets it over each function's CFG; :func:`replay_slot_trace`
#: runs the *same* tables over a FlightRecorder event stream, so the
#: runtime cross-check in ``tests/test_protocol_rules.py`` pins the
#: static and dynamic verdicts together.
ORDERING_PROTOCOL = Protocol(
    name="slot-ordering",
    rule_id=RULE_ID,
    description="membership swap happens-before any resizing swap "
                "within one actuation",
    home=_HOME,
    initial="idle",
    states=("idle", "membership_fresh"),
    transitions=(
        Transition("membership_swap", ("*",), "membership_fresh"),
        Transition("resize", ("membership_fresh",), "membership_fresh"),
        Transition("redesign", ("*",), "idle"),
    ),
    errors={
        ("idle", "resize"):
            "resizing swap with no membership swap in this actuation: "
            "the training loop would re-lower against an unobserved "
            "mesh",
    },
)


def trace_record_event(record) -> Optional[str]:
    """Map a FlightRecorder record (dict) to an ordering-machine event.

    ``membership`` records are membership swaps; ``swap`` records count
    as resizes only when their ``resized`` extra field is truthy (plain
    same-universe swaps are always legal); ``redesign`` closes the
    actuation.  Other kinds carry no protocol meaning."""
    kind = record.get("kind")
    if kind == "membership":
        return "membership_swap"
    if kind == "swap" and record.get("resized"):
        return "resize"
    if kind == "redesign":
        return "redesign"
    return None


def replay_slot_trace(records, *, strict: bool = True) -> Replay:
    """Run a runtime event stream through :data:`ORDERING_PROTOCOL`.

    ``records`` is an iterable of FlightRecorder dicts (e.g. from
    ``repro.obs.events.validate_trace``).  Raises
    :class:`~repro.analysis.protocols.ReplayError` on the first
    protocol violation when ``strict``; otherwise collects them on the
    returned replay's ``errors``."""
    replay = Replay(ORDERING_PROTOCOL)
    for record in records:
        event = trace_record_event(record)
        if event is not None:
            replay.feed(event, strict=strict)
    return replay


def _leaf(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _kwarg_active(call: ast.Call, key: str) -> bool:
    """True when ``key=`` is passed and is not a literal False/None."""
    for kw in call.keywords:
        if kw.arg == key:
            if isinstance(kw.value, ast.Constant) and \
                    kw.value.value in (False, None):
                return False
            return True
    return False


def _is_membership_swap(call: ast.Call) -> bool:
    if not (isinstance(call.func, ast.Attribute)
            and call.func.attr == "swap"):
        return False
    recv = dotted_name(call.func.value)
    return recv is not None and "membership" in _leaf(recv).lower()


def _is_resize(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    if call.func.attr == "swap" and _kwarg_active(call, "allow_resize"):
        recv = dotted_name(call.func.value)
        # membership slots have no resize concept; don't double-count
        return not (recv and "membership" in _leaf(recv).lower())
    if call.func.attr == "swap_schedule" and _kwarg_active(call, "silos"):
        return True
    return False


class SlotProtocolRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if ctx.path in _HOME or ctx.path.startswith(("tests/",
                                                     "benchmarks/")):
            return []
        if not in_hot_path(ctx):
            return []
        out: List[Violation] = []
        out.extend(self._check_direct_mutation(ctx))
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._check_ordering(ctx, node))
                for finding in run_protocol(SLOT_PROTOCOL, node):
                    out.append(ctx.violation(
                        self.id, finding.node,
                        f"{finding.key}: {finding.message}"))
        return out

    # -- facet: direct mutation of slot fields -----------------------------

    def _check_direct_mutation(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                if not isinstance(tgt, ast.Attribute):
                    continue
                if tgt.attr not in _SLOT_FIELDS:
                    continue
                recv = dotted_name(tgt.value)
                if recv is None or "slot" not in _leaf(recv).lower():
                    continue
                out.append(ctx.violation(
                    self.id, node,
                    f"direct store to {recv}.{tgt.attr} bypasses the "
                    f"swap protocol (no version bump, no metrics, no "
                    f"rollback); go through swap/swap_schedule"))
        return out

    # -- facet: membership swap happens-before resize ----------------------

    def _check_ordering(self, ctx: FileCtx, fn: ast.AST
                        ) -> List[Violation]:
        cfg = CFG(fn)
        # abstract state: the subset of ORDERING_PROTOCOL states the
        # machine may be in when the statement starts
        init: FrozenSet[str] = frozenset({ORDERING_PROTOCOL.initial})

        def transfer(node: ast.AST, state: FrozenSet[str]
                     ) -> FrozenSet[str]:
            if isinstance(node, Entry) or not isinstance(node, ast.stmt):
                return state
            for expr in _own_exprs(node):
                for sub in ast.walk(expr):
                    if isinstance(sub, ast.Call) and \
                            _is_membership_swap(sub):
                        return ORDERING_PROTOCOL.step(
                            state, "membership_swap")
            return state

        def join(states: Iterable[FrozenSet[str]]) -> FrozenSet[str]:
            merged: FrozenSet[str] = frozenset()
            for s in states:
                merged |= s
            return merged

        in_states = propagate(cfg, init, transfer, join)

        out: List[Violation] = []
        for stmt in cfg.statements():
            state = in_states.get(stmt)
            if state is None or "membership_fresh" in state:
                continue
            own = [sub for expr in _own_exprs(stmt)
                   for sub in ast.walk(expr)]
            for sub in own:
                if not isinstance(sub, ast.Call) or not _is_resize(sub):
                    continue
                out.append(ctx.violation(
                    self.id, sub,
                    f"resizing swap with no MembershipSlot.swap on any "
                    f"path into it in '{fn.name}'; the training loop "
                    f"would re-lower against an unobserved mesh — swap "
                    f"membership first (or record an audit note instead "
                    f"of resizing)"))
        return out
