"""engine-contract: every public engine entry point declares its shapes.

The engines move ``[B,N,N]`` dense batches, ``[B,E]`` padded edge
batches and ``[R+1,N]`` timing tables through each other; a silent
rank/axis mixup usually *runs* (numpy broadcasts) and produces garbage
cycle times.  The ``@contract`` decorator documents the shape algebra
at the signature and — under ``REPRO_CHECK_CONTRACTS=1`` — enforces it.
This rule makes the decorator mandatory on public top-level functions
of the four engine modules so new entry points cannot skip it.
"""

from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation, dotted_name

RULE_ID = "engine-contract"


def _has_contract(fn: ast.AST) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name and name.rsplit(".", 1)[-1] == "contract":
            return True
    return False


class EngineContractRule:
    id = RULE_ID

    def check(self, ctx: FileCtx) -> List[Violation]:
        if ctx.path not in ctx.config.engine_modules:
            return []
        out: List[Violation] = []
        for node in ctx.tree.body:  # top-level defs only, not methods
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node.name.startswith("_"):
                continue
            if not _has_contract(node):
                out.append(ctx.violation(
                    self.id, node,
                    f"public engine function '{node.name}' has no "
                    f"@contract decorator; declare its shape spec "
                    f"(see repro.analysis.contracts)"))
        return out
