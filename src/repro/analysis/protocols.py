"""Declarative typestate machines for the project's temporal protocols.

PR 6's ``@contract`` layer checks *per-call* shapes; the invariants that
broke loose since are *temporal* and spread across statements: a
``MembershipSlot`` must swap before a plan resize, a ``DeltaPricer``
certificate is only valid between ``price`` and ``commit``, an
``EdgeBatch`` weight column is only a number after masking.  This module
gives the lint rules one vocabulary for such protocols:

* a :class:`Protocol` is a declarative state machine — which
  constructors/names it tracks, how method calls and attribute reads map
  to events (:class:`MethodEvent` / :class:`AttrEvent`, optionally gated
  on a keyword flag), the transition table, and the ``(state, event) ->
  explanation`` error table;
* :func:`run_protocol` interprets a machine abstractly over a function's
  :class:`~repro.analysis.dataflow.CFG` (union join at merges, fixpoint
  over loops) and reports an error only when *every* path reaches the
  statement in an erroneous state — "may" facts, "must" reporting, so a
  swap on one branch keeps the other branch's resize legal exactly like
  the runtime does;
* :class:`Replay` runs the same transition/error tables over a *runtime*
  event stream (e.g. a FlightRecorder trace), so a dynamic run can be
  checked against the identical machine the static rule used —
  ``tests/test_protocol_rules.py`` pins static and dynamic verdicts
  together.

Objects escape (state ``ESCAPED``, never erroneous) when they are passed
to a call, stored into a container/attribute, returned, or yielded:
protocol obligations transfer to the receiver, which this
function-at-a-time analysis does not see.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Mapping, Optional, \
    Sequence, Tuple

from .dataflow import CFG, Entry, analyze_function, assigned_names, \
    _own_exprs  # type: ignore[attr-defined]

__all__ = ["MethodEvent", "AttrEvent", "Transition", "Protocol",
           "ProtocolFinding", "run_protocol", "protocol_table_row",
           "Replay", "ReplayError", "ESCAPED"]

#: Pseudo-state of an object whose obligations left this function.
ESCAPED = "<escaped>"


@dataclass(frozen=True)
class MethodEvent:
    """Maps a method call on a tracked object to a machine event.

    ``when_kwarg`` gates the mapping on a keyword argument being present
    and not a literal ``False``/``None`` (a *variable* flag counts as
    present — the analysis cannot prove it false, and "must" reporting
    keeps that sound).  The first matching event in declaration order
    wins, so list the gated variant before the bare one."""

    method: str
    event: str
    when_kwarg: Optional[str] = None


@dataclass(frozen=True)
class AttrEvent:
    """Maps a plain attribute *read* on a tracked object to an event."""

    attr: str
    event: str


@dataclass(frozen=True)
class Transition:
    event: str
    src: Tuple[str, ...]   # ("*",) matches any state
    dst: str


@dataclass(frozen=True)
class Protocol:
    """One temporal protocol: tracking, events, transitions, errors."""

    name: str
    rule_id: str
    description: str
    #: Constructor names whose call results are tracked from ``initial``.
    constructors: Tuple[str, ...] = ()
    #: Substrings of variable / dotted-attribute names tracked from
    #: ``hint_initial`` (objects whose history predates this function).
    name_hints: Tuple[str, ...] = ()
    #: Module paths (repo-relative) that *implement* the protocol and
    #: are exempt from it.
    home: Tuple[str, ...] = ()
    initial: str = "fresh"
    hint_initial: str = "external"
    states: Tuple[str, ...] = ()
    method_events: Tuple[MethodEvent, ...] = ()
    attr_events: Tuple[AttrEvent, ...] = ()
    transitions: Tuple[Transition, ...] = ()
    #: (state, event) -> human explanation; reaching one is a violation.
    errors: Mapping[Tuple[str, str], str] = field(default_factory=dict)

    def classify_call(self, call: ast.Call) -> Optional[str]:
        """Event name of ``<tracked>.method(...)``, or None."""
        if not isinstance(call.func, ast.Attribute):
            return None
        method = call.func.attr
        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
        for ev in self.method_events:
            if ev.method != method:
                continue
            if ev.when_kwarg is None:
                return ev.event
            val = kwargs.get(ev.when_kwarg)
            if val is None:
                continue
            if isinstance(val, ast.Constant) and val.value in (False, None):
                continue
            return ev.event
        return None

    def step(self, states: FrozenSet[str], event: str) -> FrozenSet[str]:
        """Post-states of firing ``event`` from a state set."""
        out = set()
        for s in states:
            if s == ESCAPED:
                out.add(ESCAPED)
                continue
            dst = None
            for t in self.transitions:
                if t.event == event and (t.src == ("*",) or s in t.src):
                    dst = t.dst
                    break
            out.add(dst if dst is not None else s)
        return frozenset(out)

    def error_of(self, states: FrozenSet[str], event: str) -> Optional[str]:
        """Explanation iff *every* non-escaped state is erroneous for
        ``event`` (must semantics).  None when any path is fine."""
        live = [s for s in states if s != ESCAPED]
        if not live:
            return None
        msgs = [self.errors.get((s, event)) for s in live]
        if all(m is not None for m in msgs):
            return msgs[0]
        return None


@dataclass(frozen=True)
class ProtocolFinding:
    node: ast.AST
    key: str
    event: str
    states: FrozenSet[str]
    message: str


# ---------------------------------------------------------------------------
# Static interpretation over a function CFG
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _hinted(proto: Protocol, key: str) -> bool:
    leaf = key.rsplit(".", 1)[-1]
    return any(h in leaf for h in proto.name_hints)


State = Tuple[Tuple[str, FrozenSet[str]], ...]  # sorted (key, states) pairs


def _to_map(state: State) -> Dict[str, FrozenSet[str]]:
    return dict(state)


def _to_state(m: Mapping[str, FrozenSet[str]]) -> State:
    return tuple(sorted(m.items()))


def _constructor_of(proto: Protocol, value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    name = _dotted(value.func)
    if name is None:
        return False
    return name.rsplit(".", 1)[-1] in proto.constructors


def _tracked_keys_in(proto: Protocol, fn: ast.AST) -> List[str]:
    """Name-hinted keys used anywhere in the function body."""
    keys = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.Attribute, ast.Name)):
            key = _dotted(node)
            if key and _hinted(proto, key):
                keys.add(key)
    return sorted(keys)


def _events_of_stmt(proto: Protocol, stmt: ast.AST,
                    keys: Iterable[str]) -> List[Tuple[str, str, ast.AST]]:
    """(key, event, site) triples this statement fires, in source order."""
    key_set = set(keys)
    out: List[Tuple[str, str, ast.AST]] = []
    call_receivers: List[ast.AST] = []
    for expr in _own_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute):
                call_receivers.append(node.func.value)
                key = _dotted(node.func.value)
                if key in key_set:
                    event = proto.classify_call(node)
                    if event is not None:
                        out.append((key, event, node))
    # attribute reads that are not the receiver of an evented call
    recv_ids = {id(r) for r in call_receivers}
    for expr in _own_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, ast.Attribute) and isinstance(
                    node.ctx, ast.Load) and id(node.value) not in recv_ids:
                key = _dotted(node.value)
                if key in key_set:
                    for ev in proto.attr_events:
                        if ev.attr == node.attr:
                            out.append((key, ev.event, node))
    out.sort(key=lambda t: (getattr(t[2], "lineno", 0),
                            getattr(t[2], "col_offset", 0)))
    return out


def _escaped_keys(stmt: ast.AST, keys: Iterable[str]) -> List[str]:
    """Tracked keys whose object leaves this function at this statement:
    passed as a call argument, stored into an attribute/subscript/
    container, returned, or yielded."""
    key_set = set(keys)
    hits: List[str] = []

    def _key_of(node: ast.AST) -> Optional[str]:
        k = _dotted(node)
        return k if k in key_set else None

    for expr in _own_exprs(stmt):
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    k = _key_of(arg)
                    if k:
                        hits.append(k)
            elif isinstance(node, (ast.List, ast.Tuple, ast.Set)):
                for elt in node.elts:
                    k = _key_of(elt)
                    if k:
                        hits.append(k)
            elif isinstance(node, ast.Dict):
                for v in node.values:
                    k = _key_of(v)
                    if k:
                        hits.append(k)
    if isinstance(stmt, (ast.Return, ast.Expr)):
        value = stmt.value
        if isinstance(value, (ast.Yield, ast.YieldFrom)):
            value = value.value
        if value is not None:
            k = _key_of(value)
            if k:
                hits.append(k)
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            if not isinstance(tgt, ast.Name):  # obj.attr = slot / d[k] = slot
                k = _key_of(stmt.value)
                if k:
                    hits.append(k)
    return hits


def run_protocol(proto: Protocol, fn: ast.AST) -> List[ProtocolFinding]:
    """Interpret ``proto`` over one function (or module) body."""
    analysis = analyze_function(fn)
    cfg: CFG = analysis.cfg
    hinted = _tracked_keys_in(proto, fn)

    init_map: Dict[str, FrozenSet[str]] = {
        k: frozenset({proto.hint_initial}) for k in hinted}
    init = _to_state(init_map)

    def transfer(node: ast.AST, state: State) -> State:
        if isinstance(node, Entry) or not isinstance(node, ast.stmt):
            return state
        m = _to_map(state)
        # (re)bindings first: `x = DeltaPricer(...)` tracks x fresh;
        # `x = something_else` unbinds a constructor-tracked x.
        if isinstance(node, ast.Assign) and len(node.targets) >= 1:
            for tgt in node.targets:
                key = _dotted(tgt) if isinstance(
                    tgt, (ast.Name, ast.Attribute)) else None
                if key is None:
                    continue
                if _constructor_of(proto, node.value):
                    m[key] = frozenset({proto.initial})
                elif key in m:
                    m[key] = (frozenset({proto.hint_initial})
                              if _hinted(proto, key)
                              else frozenset())
        else:
            rebound = assigned_names(node)
            for key in list(m):
                if key.split(".", 1)[0] in rebound and "." not in key:
                    m[key] = (frozenset({proto.hint_initial})
                              if _hinted(proto, key) else frozenset())
        for key, event, _site in _events_of_stmt(proto, node, m):
            m[key] = proto.step(m[key], event)
        for key in _escaped_keys(node, m):
            m[key] = frozenset({ESCAPED})
        return _to_state({k: v for k, v in m.items() if v})

    def join(states: Iterable[State]) -> State:
        merged: Dict[str, FrozenSet[str]] = {}
        for st in states:
            for k, v in st:
                merged[k] = merged.get(k, frozenset()) | v
        return _to_state(merged)

    from .dataflow import propagate

    in_states = propagate(cfg, init, transfer, join)

    findings: List[ProtocolFinding] = []
    seen = set()
    for stmt in cfg.statements():
        state = in_states.get(stmt)
        if state is None:
            continue
        m = _to_map(state)
        # replay the statement's own rebinds before its events, exactly
        # as transfer does, so `pm = dp.price()` sees pre-price states
        if isinstance(stmt, ast.Assign) and _constructor_of(proto,
                                                            stmt.value):
            pass
        for key, event, site in _events_of_stmt(proto, stmt, m):
            states = m.get(key, frozenset())
            msg = proto.error_of(states, event)
            dedup = (id(site), key, event)
            if msg is not None and dedup not in seen:
                seen.add(dedup)
                findings.append(ProtocolFinding(
                    node=site, key=key, event=event, states=states,
                    message=msg))
            m[key] = proto.step(states, event)
    return findings


def protocol_table_row(proto: Protocol) -> Tuple[str, str, str, str]:
    """(rule id, states, error states, description) for the docs table."""
    err_states = sorted({f"{s}--{e}" for (s, e) in proto.errors})
    return (proto.rule_id, " / ".join(proto.states),
            ", ".join(err_states), proto.description)


# ---------------------------------------------------------------------------
# Runtime replay (trace cross-check)
# ---------------------------------------------------------------------------

class ReplayError(Exception):
    """A runtime event stream violated the protocol machine."""


class Replay:
    """Run a protocol's transition/error tables over a runtime event
    stream — one state per tracked key (no abstraction: the runtime
    knows exactly which object did what).

    >>> r = Replay(SLOT_MACHINE)     # doctest: +SKIP
    >>> r.feed("membership_swap")
    >>> r.feed("plan_resize")        # legal: membership swapped first
    """

    def __init__(self, proto: Protocol, start: Optional[str] = None):
        self.proto = proto
        self.state = start if start is not None else proto.initial
        self.log: List[Tuple[str, str, str]] = []  # (before, event, after)
        self.errors: List[str] = []

    def feed(self, event: str, *, strict: bool = True) -> str:
        before = self.state
        msg = self.proto.errors.get((before, event))
        if msg is not None:
            self.errors.append(
                f"{self.proto.name}: event {event!r} in state {before!r}: "
                f"{msg}")
            if strict:
                raise ReplayError(self.errors[-1])
        after = self.proto.step(frozenset({before}), event)
        self.state = next(iter(after))
        self.log.append((before, event, self.state))
        return self.state
