"""Declarative shape contracts for engine entry points.

Every public function in the four engine modules (``maxplus_vec``,
``maxplus_sparse``, ``delays``, ``schedule``) carries a ``@contract``
decorator describing the shapes it accepts and returns.  The decorator
is free when disabled (one dict lookup per call); under
``REPRO_CHECK_CONTRACTS=1`` (the default in the test suite, set by
``tests/conftest.py``) every call is checked against its spec and a
``ContractError`` names the function, argument, expected spec and
observed shape on mismatch.

Spec mini-language (one spec string per argument, positionally; keyword
arguments via ``**kw_specs``; the return value via ``ret=``):

====================  ====================================================
``None``              argument participates in the signature but is
                      unchecked (documented as shape-free)
``"[B,N,N]"``         array-like with that rank; each dim token either
                      binds a name, checks a previously bound name,
                      is a literal int, ``_`` (ignore), or an arithmetic
                      expression over bound names (``"[N+1,B,N]"``)
``"[...,N,N]"``       leading ``...`` allows any number of extra
                      leading dims
``"[]"``              rank-0 (scalar) array
``"N"``               a static Python int; binds ``N``
``"#E"``              any sized sequence; binds ``E = len(arg)``
``"eb[B,E,N]"``       an ``EdgeBatch``-like object: ``src``/``dst``/``w``
                      share a 2-d shape checked against ``[B,E]`` and
                      ``num_nodes`` is checked against ``N``
``"*spec"``           optional — skipped when the argument is ``None``
``"a|b"``             alternation: first matching branch wins
====================  ====================================================

Dim names bind on first sight and must agree at every later use within
one call, across arguments and the return value.  The checker reads only
``.shape``/``len()`` so it is trace-safe: contracts on the ``*_jax``
engine twins evaluate fine on tracers inside ``jax.jit``.
"""

from __future__ import annotations

import functools
import inspect
import os
import re
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["contract", "ContractError", "checking_enabled", "enable",
           "disable"]

_ENV_VAR = "REPRO_CHECK_CONTRACTS"
_FORCED: Optional[bool] = None  # enable()/disable() override for tests


class ContractError(TypeError):
    """A call violated its declared shape contract."""


def checking_enabled() -> bool:
    if _FORCED is not None:
        return _FORCED
    return os.environ.get(_ENV_VAR, "") == "1"


def enable() -> None:
    global _FORCED
    _FORCED = True


def disable() -> None:
    global _FORCED
    _FORCED = False


# ---------------------------------------------------------------------------
# Spec parsing.  A parsed spec is a list of alternatives; each alternative
# is a tuple ("array", ellipsis, tokens) | ("scalar", name) |
# ("seqlen", name) | ("edgebatch", tokens).  Tokens are ("bind", name),
# ("lit", int), ("skip",) or ("expr", source).
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"^[A-Za-z_]\w*$")
_EXPR_RE = re.compile(r"^[\w\s+\-*()]+$")
_PARSE_CACHE: Dict[str, Tuple] = {}


def _parse_token(tok: str) -> Tuple:
    tok = tok.strip()
    if tok == "_":
        return ("skip",)
    if tok.lstrip("-").isdigit():
        return ("lit", int(tok))
    if _NAME_RE.match(tok):
        return ("bind", tok)
    if _EXPR_RE.match(tok):
        return ("expr", tok)
    raise ValueError(f"bad dim token {tok!r} in contract spec")


def _parse_dims(body: str) -> List[Tuple]:
    body = body.strip()
    if not body:
        return []
    return [_parse_token(t) for t in body.split(",")]


def _parse_alt(spec: str) -> Tuple:
    spec = spec.strip()
    if spec.startswith("eb[") and spec.endswith("]"):
        return ("edgebatch", _parse_dims(spec[3:-1]))
    if spec.startswith("[") and spec.endswith("]"):
        body = spec[1:-1]
        ellipsis = False
        if body.startswith("..."):
            ellipsis = True
            body = body[3:].lstrip(",")
        return ("array", ellipsis, _parse_dims(body))
    if spec.startswith("#"):
        name = spec[1:].strip()
        if not _NAME_RE.match(name):
            raise ValueError(f"bad seq-len spec {spec!r}")
        return ("seqlen", name)
    if _NAME_RE.match(spec):
        return ("scalar", spec)
    raise ValueError(f"bad contract spec {spec!r}")


def _parse_spec(spec: str) -> Tuple:
    cached = _PARSE_CACHE.get(spec)
    if cached is None:
        optional = spec.startswith("*")
        body = spec[1:] if optional else spec
        cached = (optional, tuple(_parse_alt(a) for a in body.split("|")))
        _PARSE_CACHE[spec] = cached
    return cached


# ---------------------------------------------------------------------------
# Matching
# ---------------------------------------------------------------------------

def _shape_of(value: Any) -> Optional[Tuple[int, ...]]:
    shape = getattr(value, "shape", None)
    if shape is not None:
        try:
            return tuple(int(d) for d in shape)
        except Exception:  # abstract/polymorphic dims: give up, don't fail
            return None
    if isinstance(value, (list, tuple)):
        import numpy as _np

        try:
            return tuple(_np.shape(value))
        except Exception:
            return None
    if isinstance(value, (int, float, complex, bool)):
        return ()
    return None


def _eval_expr(src: str, env: Dict[str, int]) -> int:
    for name in re.findall(r"[A-Za-z_]\w*", src):
        if name not in env:
            raise _Mismatch(f"dim {name!r} in {src!r} is unbound")
    try:
        return int(eval(src, {"__builtins__": {}}, dict(env)))  # noqa: S307
    except _Mismatch:
        raise
    except Exception as exc:
        raise _Mismatch(f"could not evaluate dim expr {src!r}: {exc}")


class _Mismatch(Exception):
    pass


def _match_dims(tokens: List[Tuple], shape: Tuple[int, ...],
                env: Dict[str, int]) -> None:
    if len(tokens) != len(shape):
        raise _Mismatch(
            f"rank {len(shape)} != expected rank {len(tokens)}")
    # Two passes: bind bare names first, then evaluate expressions, so
    # "[N+1,B,N]" works regardless of token order.
    deferred = []
    for tok, dim in zip(tokens, shape):
        kind = tok[0]
        if kind == "skip":
            continue
        if kind == "lit":
            if dim != tok[1]:
                raise _Mismatch(f"dim {dim} != literal {tok[1]}")
        elif kind == "bind":
            bound = env.get(tok[1])
            if bound is None:
                env[tok[1]] = int(dim)
            elif bound != dim:
                raise _Mismatch(f"dim {tok[1]}={bound} but saw {dim}")
        else:  # expr
            deferred.append((tok[1], dim))
    for src, dim in deferred:
        want = _eval_expr(src, env)
        if dim != want:
            raise _Mismatch(f"dim {dim} != {src} (= {want})")


def _match_alt(alt: Tuple, value: Any, env: Dict[str, int]) -> None:
    kind = alt[0]
    if kind == "array":
        _, ellipsis, tokens = alt
        shape = _shape_of(value)
        if shape is None:
            raise _Mismatch("value has no shape")
        if ellipsis:
            if len(shape) < len(tokens):
                raise _Mismatch(
                    f"rank {len(shape)} < minimum rank {len(tokens)}")
            shape = shape[len(shape) - len(tokens):]
        _match_dims(tokens, shape, env)
    elif kind == "scalar":
        try:
            got = int(value)
        except Exception:
            raise _Mismatch("expected a static Python int")
        name = alt[1]
        bound = env.get(name)
        if bound is None:
            env[name] = got
        elif bound != got:
            raise _Mismatch(f"dim {name}={bound} but saw {got}")
    elif kind == "seqlen":
        try:
            got = len(value)
        except Exception:
            raise _Mismatch("expected a sized sequence")
        name = alt[1]
        bound = env.get(name)
        if bound is None:
            env[name] = got
        elif bound != got:
            raise _Mismatch(f"len {name}={bound} but saw {got}")
    else:  # edgebatch
        tokens = alt[1]
        for attr in ("src", "dst", "w", "num_nodes"):
            if not hasattr(value, attr):
                raise _Mismatch(f"expected an EdgeBatch (missing .{attr})")
        s = _shape_of(value.src)
        d = _shape_of(value.dst)
        w = _shape_of(value.w)
        if s is None or s != d or s != w:
            raise _Mismatch(
                f"EdgeBatch src/dst/w shapes disagree: {s} {d} {w}")
        if len(tokens) != 3:
            raise _Mismatch("eb[...] spec needs exactly [B,E,N] tokens")
        # num_nodes first: it binds N, which edge-count expressions like
        # "E+N" (overlay pool + self-loop slots) may reference.
        _match_dims(tokens[2:], (int(value.num_nodes),), env)
        _match_dims(tokens[:2], s, env)


def _check_value(label: str, spec: Optional[str], value: Any,
                 env: Dict[str, int], fn_name: str) -> None:
    if spec is None:
        return
    optional, alts = _parse_spec(spec)
    if optional and value is None:
        return
    errors = []
    for alt in alts:
        trial = dict(env)
        try:
            _match_alt(alt, value, trial)
        except _Mismatch as exc:
            errors.append(str(exc))
            continue
        env.clear()
        env.update(trial)
        return
    shape = _shape_of(value)
    raise ContractError(
        f"{fn_name}: {label} violates contract {spec!r} "
        f"(observed shape {shape}, type {type(value).__name__}): "
        + "; ".join(errors))


# ---------------------------------------------------------------------------
# Decorator
# ---------------------------------------------------------------------------

def contract(*arg_specs: Optional[str], ret: Optional[str] = None,
             **kw_specs: Optional[str]):
    """Attach a shape contract to a function.

    Positional specs pair with the function's parameters in order (extra
    parameters are unchecked); ``kw_specs`` address parameters by name;
    ``ret`` checks the return value against dims bound by the inputs.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        params = [p.name for p in sig.parameters.values()]
        if len(arg_specs) > len(params):
            raise ValueError(
                f"contract on {fn.__name__}: {len(arg_specs)} specs for "
                f"{len(params)} parameters")
        pairs = [(name, spec) for name, spec in zip(params, arg_specs)
                 if spec is not None]
        pairs += [(name, spec) for name, spec in kw_specs.items()
                  if spec is not None]
        for name, _ in pairs:
            if name not in sig.parameters:
                raise ValueError(
                    f"contract on {fn.__name__}: unknown parameter {name!r}")
        for _, spec in pairs:
            _parse_spec(spec)  # fail at decoration time, not call time
        if ret is not None:
            _parse_spec(ret)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not checking_enabled():
                return fn(*args, **kwargs)
            try:
                bound = sig.bind(*args, **kwargs)
                bound.apply_defaults()
            except TypeError:
                return fn(*args, **kwargs)  # let the call raise natively
            env: Dict[str, int] = {}
            for name, spec in pairs:
                if name in bound.arguments:
                    _check_value(f"argument {name!r}", spec,
                                 bound.arguments[name], env, fn.__name__)
            result = fn(*args, **kwargs)
            if ret is not None:
                _check_value("return value", ret, result, env, fn.__name__)
            return result

        wrapper.__contract__ = {
            "args": arg_specs, "kwargs": dict(kw_specs), "ret": ret}
        wrapper.__wrapped__ = fn
        return wrapper

    return decorate
