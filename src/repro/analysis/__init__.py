"""Static analysis and runtime-contract tooling for the repro project.

Kept import-light: the engine modules import ``contracts`` at module
load, so nothing here may pull in numpy/jax or the lint machinery.
``repro.analysis.lint`` and ``repro.analysis.recompile`` are imported
on demand by their consumers (``scripts/lint_repro.py``, tests).
"""

from .contracts import ContractError, contract, checking_enabled

__all__ = ["contract", "ContractError", "checking_enabled"]
