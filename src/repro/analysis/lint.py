"""repro-lint: AST-based static checks for the project's invariants.

The headline claims of this repo — bit-identical engine equivalence,
deterministic MATCHA sampling, "sampled topologies never recompile" —
rest on conventions nothing in CPython enforces: no host syncs inside
jitted bodies, no ambient RNG state, no arithmetic on the ``NEG_INF``
sentinel, f64-only bit-identity paths, and a shape contract on every
engine entry point.  This module parses the tree once, shares a
cross-file view of which functions are traced by jax, and runs each
rule in ``repro.analysis.rules`` over every file.

Grandfathering: ``scripts/lint_baseline.txt`` holds fingerprints of
pre-existing violations.  Fingerprints are line-number independent
(path, rule, enclosing function, stripped source line) so unrelated
edits do not invalidate the baseline.  New violations fail the run;
``--update-baseline`` rewrites the file.

Inline suppression: append ``# repro-lint: ignore`` (or
``ignore[rule-id]``) to the offending line.
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["LintConfig", "Violation", "FileCtx", "Project", "lint_paths",
           "lint_files", "lint_source", "load_baseline", "main"]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LintConfig:
    """Project layout knobs consumed by the rules."""

    # Modules whose loops/jitted bodies are throughput-critical: host
    # syncs there are flagged.
    hot_prefixes: Tuple[str, ...] = (
        "src/repro/core/", "src/repro/fed/", "src/repro/dynamics/",
        "src/repro/kernels/", "src/repro/launch/")
    # The engine modules: dtype-less constructions are flagged and
    # every public function must carry a @contract.
    engine_modules: Tuple[str, ...] = (
        "src/repro/core/maxplus_vec.py",
        "src/repro/core/maxplus_sparse.py",
        "src/repro/core/delays.py",
        "src/repro/core/schedule.py",
        "src/repro/core/mixing.py",
        "src/repro/kernels/segment_max.py")
    # The one module allowed to define the -inf sentinel.
    sentinel_home: str = "src/repro/core/maxplus_vec.py"
    sentinel_names: Tuple[str, ...] = ("NEG_INF", "_NEG_INF")
    # Functions on the bit-identity consensus/migration path: any f32
    # mention inside them is a violation.
    bit_identity_funcs: Tuple[str, ...] = (
        "migrate_silo_state", "masked_consensus")
    # np.random attributes that thread explicit state and are allowed.
    allowed_np_random: Tuple[str, ...] = (
        "default_rng", "Generator", "SeedSequence", "BitGenerator",
        "PCG64", "Philox")
    # numpy attributes that are trace-time constants, not host syncs.
    np_trace_constants: Tuple[str, ...] = (
        "float16", "float32", "float64", "int8", "int16", "int32",
        "int64", "uint8", "uint32", "bool_", "dtype", "newaxis", "pi",
        "inf", "nan", "e", "ndarray", "integer", "floating", "shape",
        "ndim")


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str        # repo-relative, forward slashes
    line: int
    col: int
    message: str
    func: str        # enclosing qualname or "<module>"
    line_text: str

    def fingerprint(self) -> str:
        return "::".join(
            (self.path, self.rule, self.func, self.line_text.strip()))

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
                f"{self.message}")


@dataclass
class Project:
    """Cross-file facts shared with every rule."""

    # Bare names passed to jax tracing combinators anywhere in the
    # project: jit/vmap/pmap/grad/..., scan bodies, fori/while bodies.
    traced_root_names: Set[str] = field(default_factory=set)


@dataclass
class FileCtx:
    path: str
    tree: ast.Module
    lines: List[str]
    config: LintConfig
    project: Project
    # node -> enclosing function qualname ("<module>" at top level)
    func_of: Dict[ast.AST, str] = field(default_factory=dict)
    # node -> innermost enclosing FunctionDef (None at module level)
    def_of: Dict[ast.AST, Optional[ast.AST]] = field(default_factory=dict)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def violation(self, rule: str, node: ast.AST, message: str) -> Violation:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Violation(rule=rule, path=self.path, line=line, col=col,
                         message=message,
                         func=self.func_of.get(node, "<module>"),
                         line_text=self.line_text(line))


# ---------------------------------------------------------------------------
# AST helpers shared by the rules
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.random.default_rng' for nested Attribute/Name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_JIT_NAMES = {"jit", "vmap", "pmap", "grad", "value_and_grad",
              "checkpoint", "remat", "shard_map", "custom_vjp",
              "custom_jvp"}


def _is_jit_callee(node: ast.AST) -> bool:
    name = dotted_name(node)
    if name is None:
        return False
    leaf = name.rsplit(".", 1)[-1]
    return leaf == "jit"


def _traced_arg_positions(callee: str) -> Sequence[int]:
    """Which positional args of this callee are traced callables."""
    leaf = callee.rsplit(".", 1)[-1]
    if leaf in _JIT_NAMES:
        return (0,)
    if leaf in ("scan", "associative_scan"):
        return (0,)
    if leaf == "map" and "." in callee:
        return (0,)  # lax.map only — bare map() is the builtin
    if leaf == "fori_loop":
        return (2,)
    if leaf == "while_loop":
        return (0, 1)
    if leaf in ("cond", "switch"):
        return (1, 2, 3)
    return ()


def collect_traced_roots(tree: ast.Module) -> Set[str]:
    """Bare function names handed to jax tracing combinators."""
    roots: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func)
            if callee is None:
                continue
            for pos in _traced_arg_positions(callee):
                if pos < len(node.args) and isinstance(node.args[pos],
                                                       ast.Name):
                    roots.add(node.args[pos].id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _decorator_is_jit(dec):
                    roots.add(node.name)
    return roots


def _decorator_is_jit(dec: ast.AST) -> bool:
    if _is_jit_callee(dec):
        return True
    if isinstance(dec, ast.Call):
        if _is_jit_callee(dec.func):
            return True
        # functools.partial(jax.jit, static_argnums=...)
        callee = dotted_name(dec.func)
        if callee and callee.rsplit(".", 1)[-1] == "partial":
            return bool(dec.args) and _is_jit_callee(dec.args[0])
    return False


def traced_functions(ctx: FileCtx) -> Set[ast.AST]:
    """FunctionDefs in this file that execute under jax tracing.

    Seeds: defs whose name is a project-wide traced root, defs carrying
    a jit decorator, and defs nested inside a traced def (their bodies
    run at trace time).  Closure: defs called by bare name from an
    already-traced def in the same file.
    """
    defs: List[ast.AST] = [n for n in ast.walk(ctx.tree)
                           if isinstance(n, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))]
    by_name: Dict[str, List[ast.AST]] = {}
    for d in defs:
        by_name.setdefault(d.name, []).append(d)
    # Methods are passed to combinators as `self.f` (an Attribute), never
    # by bare name — exclude them from name-based seeding or every method
    # that shares a name with some scan body (`step`...) goes traced.
    methods: Set[ast.AST] = {
        d for cls in ast.walk(ctx.tree) if isinstance(cls, ast.ClassDef)
        for d in cls.body
        if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef))}

    traced: Set[ast.AST] = set()
    for d in defs:
        if d.name in ctx.project.traced_root_names and d not in methods:
            traced.add(d)
        elif any(_decorator_is_jit(dec) for dec in d.decorator_list):
            traced.add(d)

    def _mark_nested(d: ast.AST) -> None:
        for child in ast.walk(d):
            if child is not d and isinstance(child, (ast.FunctionDef,
                                                     ast.AsyncFunctionDef)):
                traced.add(child)

    changed = True
    while changed:
        changed = False
        for d in list(traced):
            before = len(traced)
            _mark_nested(d)
            for node in ast.walk(d):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)):
                    for cand in by_name.get(node.func.id, ()):
                        traced.add(cand)
            if len(traced) != before:
                changed = True
    return traced


def body_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a FunctionDef body without descending into nested defs
    (nested defs are analysed as their own traced functions)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*ignore(?:\[([\w\s,-]*)\])?")


def _suppressed(ctx: FileCtx, v: Violation) -> bool:
    m = _SUPPRESS_RE.search(ctx.line_text(v.line))
    if not m:
        return False
    rules = m.group(1)
    if rules is None:
        return True
    return v.rule in {r.strip() for r in rules.split(",") if r.strip()}


def _build_maps(ctx: FileCtx) -> None:
    def visit(node: ast.AST, qual: str, fn: Optional[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child_qual = child.name if qual == "<module>" else (
                    qual + "." + child.name)
                ctx.func_of[child] = qual
                ctx.def_of[child] = fn
                visit(child, child_qual, child)
            else:
                if isinstance(child, ast.ClassDef):
                    child_qual = child.name if qual == "<module>" else (
                        qual + "." + child.name)
                    ctx.func_of[child] = qual
                    ctx.def_of[child] = fn
                    visit(child, child_qual, fn)
                else:
                    ctx.func_of[child] = qual
                    ctx.def_of[child] = fn
                    visit(child, qual, fn)

    visit(ctx.tree, "<module>", None)


def _all_rules():
    from .rules import ALL_RULES

    return [cls() for cls in ALL_RULES]


def _norm(path: str, root: Optional[str]) -> str:
    if root:
        try:
            path = os.path.relpath(path, root)
        except ValueError:
            pass
    return path.replace(os.sep, "/")


def lint_files(files: Sequence[Tuple[str, str]],
               config: Optional[LintConfig] = None) -> List[Violation]:
    """Lint (path, source) pairs sharing one cross-file view."""
    config = config or LintConfig()
    project = Project()
    ctxs: List[FileCtx] = []
    violations: List[Violation] = []
    for path, src in files:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as exc:
            violations.append(Violation(
                rule="parse", path=path, line=exc.lineno or 1,
                col=exc.offset or 0, message=f"syntax error: {exc.msg}",
                func="<module>", line_text=""))
            continue
        ctx = FileCtx(path=path, tree=tree, lines=src.splitlines(),
                      config=config, project=project)
        _build_maps(ctx)
        project.traced_root_names |= collect_traced_roots(tree)
        ctxs.append(ctx)

    rules = _all_rules()
    for ctx in ctxs:
        for rule in rules:
            for v in rule.check(ctx):
                if not _suppressed(ctx, v):
                    violations.append(v)
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations


def lint_source(src: str, path: str = "snippet.py",
                config: Optional[LintConfig] = None,
                extra_files: Optional[Sequence[Tuple[str, str]]] = None,
                ) -> List[Violation]:
    """Lint one source string (the test-suite entry point)."""
    files = list(extra_files or []) + [(path, src)]
    return [v for v in lint_files(files, config) if v.path == path]


def iter_py_files(paths: Sequence[str], root: Optional[str] = None,
                  ) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    out.append(os.path.join(dirpath, fname))
    return out


def lint_paths(paths: Sequence[str], root: Optional[str] = None,
               config: Optional[LintConfig] = None) -> List[Violation]:
    files = []
    for fpath in iter_py_files(paths, root):
        with open(fpath, "r", encoding="utf-8") as fh:
            files.append((_norm(fpath, root), fh.read()))
    return lint_files(files, config)


# ---------------------------------------------------------------------------
# Baseline + CLI
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> Set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, "r", encoding="utf-8") as fh:
        return {line.rstrip("\n") for line in fh
                if line.strip() and not line.startswith("#")}


def write_baseline(path: str, violations: Sequence[Violation]) -> None:
    fingerprints = sorted({v.fingerprint() for v in violations})
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("# repro-lint baseline: grandfathered violations.\n")
        fh.write("# One line-number-independent fingerprint per line;\n")
        fh.write("# regenerate with scripts/lint_repro.py"
                 " --update-baseline.\n")
        for fp in fingerprints:
            fh.write(fp + "\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="lint_repro",
        description="Project-invariant linter (trace safety, RNG, "
                    "sentinel, dtype, contracts).")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src tests)")
    parser.add_argument("--root", default=None,
                        help="repo root for path normalisation "
                             "(default: two levels above this file)")
    parser.add_argument("--baseline", default=None,
                        help="baseline file "
                             "(default: scripts/lint_baseline.txt)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from this run")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report every violation, grandfathered "
                             "or not")
    args = parser.parse_args(argv)

    root = args.root or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    paths = args.paths or [os.path.join(root, "src"),
                           os.path.join(root, "tests")]
    baseline_path = args.baseline or os.path.join(
        root, "scripts", "lint_baseline.txt")

    violations = lint_paths(paths, root=root)

    if args.update_baseline:
        write_baseline(baseline_path, violations)
        print(f"wrote {len({v.fingerprint() for v in violations})} "
              f"fingerprints to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    fresh = [v for v in violations if v.fingerprint() not in baseline]
    stale = baseline - {v.fingerprint() for v in violations}

    for v in fresh:
        print(v.render())
    if fresh:
        by_rule: Dict[str, int] = {}
        for v in fresh:
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1
        summary = ", ".join(f"{k}: {n}" for k, n in sorted(by_rule.items()))
        print(f"repro-lint: {len(fresh)} new violation(s) ({summary}); "
              f"{len(violations) - len(fresh)} grandfathered.")
        return 1
    grandfathered = len(violations)
    msg = f"repro-lint: clean ({grandfathered} grandfathered)"
    if stale:
        msg += (f"; {len(stale)} baseline entr"
                f"{'y is' if len(stale) == 1 else 'ies are'} stale — "
                f"consider --update-baseline")
    print(msg)
    return 0


if __name__ == "__main__":
    sys.exit(main())
