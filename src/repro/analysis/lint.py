"""repro-lint: AST-based static checks for the project's invariants.

The headline claims of this repo — bit-identical engine equivalence,
deterministic MATCHA sampling, "sampled topologies never recompile" —
rest on conventions nothing in CPython enforces: no host syncs inside
jitted bodies, no ambient RNG state, no arithmetic on the ``NEG_INF``
sentinel, f64-only bit-identity paths, and a shape contract on every
engine entry point.  This module parses the tree once, shares a
cross-file view of which functions are traced by jax, and runs each
rule in ``repro.analysis.rules`` over every file.

Grandfathering: ``scripts/lint_baseline.txt`` holds fingerprints of
pre-existing violations.  Fingerprints are line-number independent
(path, rule, enclosing function, stripped source line) so unrelated
edits do not invalidate the baseline.  New violations fail the run;
``--update-baseline`` rewrites the file.

Inline suppression: append ``# repro-lint: ignore`` (or
``ignore[rule-id]``) to the offending line.
"""

from __future__ import annotations

import argparse
import ast
import hashlib
import json
import os
import re
import subprocess
import sys
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["LintConfig", "Violation", "FileCtx", "Project", "lint_paths",
           "lint_files", "lint_source", "load_baseline", "changed_paths",
           "main"]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LintConfig:
    """Project layout knobs consumed by the rules."""

    # Modules whose loops/jitted bodies are throughput-critical: host
    # syncs there are flagged.
    hot_prefixes: Tuple[str, ...] = (
        "src/repro/core/", "src/repro/fed/", "src/repro/dynamics/",
        "src/repro/kernels/", "src/repro/launch/")
    # The engine modules: dtype-less constructions are flagged and
    # every public function must carry a @contract.
    engine_modules: Tuple[str, ...] = (
        "src/repro/core/maxplus_vec.py",
        "src/repro/core/maxplus_sparse.py",
        "src/repro/core/delays.py",
        "src/repro/core/schedule.py",
        "src/repro/core/mixing.py",
        "src/repro/kernels/segment_max.py")
    # The one module allowed to define the -inf sentinel.
    sentinel_home: str = "src/repro/core/maxplus_vec.py"
    sentinel_names: Tuple[str, ...] = ("NEG_INF", "_NEG_INF")
    # Functions on the bit-identity consensus/migration path: any f32
    # mention inside them is a violation.
    bit_identity_funcs: Tuple[str, ...] = (
        "migrate_silo_state", "masked_consensus")
    # np.random attributes that thread explicit state and are allowed.
    allowed_np_random: Tuple[str, ...] = (
        "default_rng", "Generator", "SeedSequence", "BitGenerator",
        "PCG64", "Philox")
    # numpy attributes that are trace-time constants, not host syncs.
    np_trace_constants: Tuple[str, ...] = (
        "float16", "float32", "float64", "int8", "int16", "int32",
        "int64", "uint8", "uint32", "bool_", "dtype", "newaxis", "pi",
        "inf", "nan", "e", "ndarray", "integer", "floating", "shape",
        "ndim")


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str        # repo-relative, forward slashes
    line: int
    col: int
    message: str
    func: str        # enclosing qualname or "<module>"
    line_text: str

    def fingerprint(self) -> str:
        return "::".join(
            (self.path, self.rule, self.func, self.line_text.strip()))

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
                f"{self.message}")


@dataclass
class Project:
    """Cross-file facts shared with every rule."""

    # Bare names passed to jax tracing combinators anywhere in the
    # project: jit/vmap/pmap/grad/..., scan bodies, fori/while bodies.
    traced_root_names: Set[str] = field(default_factory=set)


@dataclass
class FileCtx:
    path: str
    tree: ast.Module
    lines: List[str]
    config: LintConfig
    project: Project
    # node -> enclosing function qualname ("<module>" at top level)
    func_of: Dict[ast.AST, str] = field(default_factory=dict)
    # node -> innermost enclosing FunctionDef (None at module level)
    def_of: Dict[ast.AST, Optional[ast.AST]] = field(default_factory=dict)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def violation(self, rule: str, node: ast.AST, message: str) -> Violation:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Violation(rule=rule, path=self.path, line=line, col=col,
                         message=message,
                         func=self.func_of.get(node, "<module>"),
                         line_text=self.line_text(line))


# ---------------------------------------------------------------------------
# AST helpers shared by the rules
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.random.default_rng' for nested Attribute/Name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_JIT_NAMES = {"jit", "vmap", "pmap", "grad", "value_and_grad",
              "checkpoint", "remat", "shard_map", "custom_vjp",
              "custom_jvp"}


def _is_jit_callee(node: ast.AST) -> bool:
    name = dotted_name(node)
    if name is None:
        return False
    leaf = name.rsplit(".", 1)[-1]
    return leaf == "jit"


def _traced_arg_positions(callee: str) -> Sequence[int]:
    """Which positional args of this callee are traced callables."""
    leaf = callee.rsplit(".", 1)[-1]
    if leaf in _JIT_NAMES:
        return (0,)
    if leaf in ("scan", "associative_scan"):
        return (0,)
    if leaf == "map" and "." in callee:
        return (0,)  # lax.map only — bare map() is the builtin
    if leaf == "fori_loop":
        return (2,)
    if leaf == "while_loop":
        return (0, 1)
    if leaf in ("cond", "switch"):
        return (1, 2, 3)
    return ()


def collect_traced_roots(tree: ast.Module) -> Set[str]:
    """Bare function names handed to jax tracing combinators."""
    roots: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func)
            if callee is None:
                continue
            for pos in _traced_arg_positions(callee):
                if pos < len(node.args) and isinstance(node.args[pos],
                                                       ast.Name):
                    roots.add(node.args[pos].id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _decorator_is_jit(dec):
                    roots.add(node.name)
    return roots


def _decorator_is_jit(dec: ast.AST) -> bool:
    if _is_jit_callee(dec):
        return True
    if isinstance(dec, ast.Call):
        if _is_jit_callee(dec.func):
            return True
        # functools.partial(jax.jit, static_argnums=...)
        callee = dotted_name(dec.func)
        if callee and callee.rsplit(".", 1)[-1] == "partial":
            return bool(dec.args) and _is_jit_callee(dec.args[0])
    return False


def traced_functions(ctx: FileCtx) -> Set[ast.AST]:
    """FunctionDefs in this file that execute under jax tracing.

    Seeds: defs whose name is a project-wide traced root, defs carrying
    a jit decorator, and defs nested inside a traced def (their bodies
    run at trace time).  Closure: defs called by bare name from an
    already-traced def in the same file.
    """
    defs: List[ast.AST] = [n for n in ast.walk(ctx.tree)
                           if isinstance(n, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))]
    by_name: Dict[str, List[ast.AST]] = {}
    for d in defs:
        by_name.setdefault(d.name, []).append(d)
    # Methods are passed to combinators as `self.f` (an Attribute), never
    # by bare name — exclude them from name-based seeding or every method
    # that shares a name with some scan body (`step`...) goes traced.
    methods: Set[ast.AST] = {
        d for cls in ast.walk(ctx.tree) if isinstance(cls, ast.ClassDef)
        for d in cls.body
        if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef))}

    traced: Set[ast.AST] = set()
    for d in defs:
        if d.name in ctx.project.traced_root_names and d not in methods:
            traced.add(d)
        elif any(_decorator_is_jit(dec) for dec in d.decorator_list):
            traced.add(d)

    def _mark_nested(d: ast.AST) -> None:
        for child in ast.walk(d):
            if child is not d and isinstance(child, (ast.FunctionDef,
                                                     ast.AsyncFunctionDef)):
                traced.add(child)

    changed = True
    while changed:
        changed = False
        for d in list(traced):
            before = len(traced)
            _mark_nested(d)
            for node in ast.walk(d):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)):
                    for cand in by_name.get(node.func.id, ()):
                        traced.add(cand)
            if len(traced) != before:
                changed = True
    return traced


def body_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a FunctionDef body without descending into nested defs
    (nested defs are analysed as their own traced functions)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*ignore(?:\[([\w\s,-]*)\])?")


def _suppressed(ctx: FileCtx, v: Violation) -> bool:
    m = _SUPPRESS_RE.search(ctx.line_text(v.line))
    if not m:
        return False
    rules = m.group(1)
    if rules is None:
        return True
    return v.rule in {r.strip() for r in rules.split(",") if r.strip()}


def _build_maps(ctx: FileCtx) -> None:
    def visit(node: ast.AST, qual: str, fn: Optional[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child_qual = child.name if qual == "<module>" else (
                    qual + "." + child.name)
                ctx.func_of[child] = qual
                ctx.def_of[child] = fn
                visit(child, child_qual, child)
            else:
                if isinstance(child, ast.ClassDef):
                    child_qual = child.name if qual == "<module>" else (
                        qual + "." + child.name)
                    ctx.func_of[child] = qual
                    ctx.def_of[child] = fn
                    visit(child, child_qual, fn)
                else:
                    ctx.func_of[child] = qual
                    ctx.def_of[child] = fn
                    visit(child, qual, fn)

    visit(ctx.tree, "<module>", None)


def _all_rules():
    from .rules import ALL_RULES

    return [cls() for cls in ALL_RULES]


def _norm(path: str, root: Optional[str]) -> str:
    if root:
        try:
            path = os.path.relpath(path, root)
        except ValueError:
            pass
    return path.replace(os.sep, "/")


def _parse_ctxs(files: Sequence[Tuple[str, str]], config: LintConfig,
                ) -> Tuple[List[FileCtx], List[Violation]]:
    """Parse every file and build the shared cross-file Project view.

    Parsing is the cheap phase (fractions of a second for the whole
    tree) and MUST cover every file even when only a subset is checked:
    ``traced_root_names`` is a project-wide fact — a function jitted
    from another module is traced no matter which files changed."""
    project = Project()
    ctxs: List[FileCtx] = []
    violations: List[Violation] = []
    for path, src in files:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as exc:
            violations.append(Violation(
                rule="parse", path=path, line=exc.lineno or 1,
                col=exc.offset or 0, message=f"syntax error: {exc.msg}",
                func="<module>", line_text=""))
            continue
        ctx = FileCtx(path=path, tree=tree, lines=src.splitlines(),
                      config=config, project=project)
        _build_maps(ctx)
        project.traced_root_names |= collect_traced_roots(tree)
        ctxs.append(ctx)
    return ctxs, violations


def _check_ctx(ctx: FileCtx, rules: Sequence) -> List[Violation]:
    out: List[Violation] = []
    for rule in rules:
        for v in rule.check(ctx):
            if not _suppressed(ctx, v):
                out.append(v)
    return out


def lint_files(files: Sequence[Tuple[str, str]],
               config: Optional[LintConfig] = None) -> List[Violation]:
    """Lint (path, source) pairs sharing one cross-file view."""
    config = config or LintConfig()
    ctxs, violations = _parse_ctxs(files, config)
    rules = _all_rules()
    for ctx in ctxs:
        violations.extend(_check_ctx(ctx, rules))
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations


def lint_source(src: str, path: str = "snippet.py",
                config: Optional[LintConfig] = None,
                extra_files: Optional[Sequence[Tuple[str, str]]] = None,
                ) -> List[Violation]:
    """Lint one source string (the test-suite entry point)."""
    files = list(extra_files or []) + [(path, src)]
    return [v for v in lint_files(files, config) if v.path == path]


def iter_py_files(paths: Sequence[str], root: Optional[str] = None,
                  ) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    out.append(os.path.join(dirpath, fname))
    return out


def lint_paths(paths: Sequence[str], root: Optional[str] = None,
               config: Optional[LintConfig] = None) -> List[Violation]:
    files = []
    for fpath in iter_py_files(paths, root):
        with open(fpath, "r", encoding="utf-8") as fh:
            files.append((_norm(fpath, root), fh.read()))
    return lint_files(files, config)


# ---------------------------------------------------------------------------
# Incremental mode: hash-keyed result cache + git changed-file selection
# ---------------------------------------------------------------------------

_CACHE_NAME = ".repro_lint_cache.json"


def _rules_digest() -> str:
    """Hash of the analysis package's own sources.

    Any edit to a rule, the engine, dataflow, or the protocol machines
    changes this digest and invalidates every cached result — a stale
    verdict from an older linter must never survive."""
    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, dirnames, filenames in os.walk(pkg_dir):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                h.update(fname.encode("utf-8"))
                with open(os.path.join(dirpath, fname), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def lint_paths_cached(paths: Sequence[str], root: Optional[str] = None,
                      config: Optional[LintConfig] = None,
                      cache_path: Optional[str] = None,
                      only: Optional[Set[str]] = None,
                      ) -> Tuple[List[Violation], int, int]:
    """Like :func:`lint_paths` with per-file result caching.

    Every file is still *parsed* (the cross-file traced-roots view must
    be complete) but rules re-run only for files whose content hash
    missed the cache.  The cache carries a context digest over the
    analysis package sources, the config, and the project traced-root
    set, so a rule edit — or an edit anywhere that changes which
    functions are traced — invalidates everything at once rather than
    serving unsound per-file hits.

    ``only`` restricts which repo-relative paths contribute violations
    (and cache refreshes) — the ``--changed`` mode.  Returns
    ``(violations, checked, cached)``.
    """
    config = config or LintConfig()
    files = []
    for fpath in iter_py_files(paths, root):
        with open(fpath, "r", encoding="utf-8") as fh:
            files.append((_norm(fpath, root), fh.read()))
    src_of = dict(files)
    ctxs, violations = _parse_ctxs(files, config)

    digest = hashlib.sha256()
    digest.update(_rules_digest().encode("utf-8"))
    digest.update(repr(config).encode("utf-8"))
    digest.update(",".join(
        sorted(ctxs[0].project.traced_root_names) if ctxs else []
        ).encode("utf-8"))
    context_digest = digest.hexdigest()

    cached_files: Dict[str, dict] = {}
    if cache_path and os.path.exists(cache_path):
        try:
            with open(cache_path, "r", encoding="utf-8") as fh:
                on_disk = json.load(fh)
            if on_disk.get("digest") == context_digest:
                cached_files = on_disk.get("files", {})
        except (ValueError, OSError):
            cached_files = {}

    rules = None
    next_files: Dict[str, dict] = {}
    checked = cached = 0
    for ctx in ctxs:
        if only is not None and ctx.path not in only:
            continue
        file_hash = hashlib.sha256(
            src_of[ctx.path].encode("utf-8")).hexdigest()
        entry = cached_files.get(ctx.path)
        if entry is not None and entry.get("hash") == file_hash:
            vs = [Violation(**d) for d in entry["violations"]]
            cached += 1
        else:
            if rules is None:
                rules = _all_rules()
            vs = _check_ctx(ctx, rules)
            checked += 1
        next_files[ctx.path] = {
            "hash": file_hash, "violations": [asdict(v) for v in vs]}
        violations.extend(vs)

    if cache_path:
        # keep entries for files outside `only` so a --changed run does
        # not evict the full-lint cache
        merged = dict(cached_files)
        merged.update(next_files)
        try:
            with open(cache_path, "w", encoding="utf-8") as fh:
                json.dump({"digest": context_digest, "files": merged},
                          fh)
        except OSError:
            pass
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations, checked, cached


def changed_paths(root: str, base: Optional[str] = None,
                  ) -> Optional[List[str]]:
    """Repo-relative ``.py`` paths changed vs the merge base.

    Compares the working tree against ``merge-base(HEAD, base)`` (first
    of origin/main, origin/master, main, master when ``base`` is None)
    and adds untracked files.  Returns None when git is unavailable or
    no base ref resolves — the caller falls back to a full lint."""

    def _git(*argv: str):
        try:
            return subprocess.run(["git", "-C", root, *argv],
                                  capture_output=True, text=True,
                                  timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None

    candidates = [base] if base else ["origin/main", "origin/master",
                                      "main", "master"]
    merge_base = None
    for ref in candidates:
        r = _git("merge-base", "HEAD", ref)
        if r is not None and r.returncode == 0:
            merge_base = r.stdout.strip()
            break
    if not merge_base:
        return None
    r = _git("diff", "--name-only", merge_base, "--")
    if r is None or r.returncode != 0:
        return None
    names = set(r.stdout.split())
    r = _git("ls-files", "--others", "--exclude-standard")
    if r is not None and r.returncode == 0:
        names |= set(r.stdout.split())
    return sorted(n for n in names if n.endswith(".py"))


# ---------------------------------------------------------------------------
# Baseline + CLI
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> Set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, "r", encoding="utf-8") as fh:
        return {line.rstrip("\n") for line in fh
                if line.strip() and not line.startswith("#")}


def write_baseline(path: str, violations: Sequence[Violation]) -> None:
    fingerprints = sorted({v.fingerprint() for v in violations})
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("# repro-lint baseline: grandfathered violations.\n")
        fh.write("# One line-number-independent fingerprint per line;\n")
        fh.write("# regenerate with scripts/lint_repro.py"
                 " --update-baseline.\n")
        for fp in fingerprints:
            fh.write(fp + "\n")


def _render_annotation(v: Violation) -> str:
    """One GitHub Actions workflow-command annotation per violation."""
    msg = f"[{v.rule}] {v.message}"
    msg = msg.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")
    return f"::error file={v.path},line={v.line},col={v.col}::{msg}"


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="lint_repro",
        description="Project-invariant linter (trace safety, RNG, "
                    "sentinel, dtype, contracts, protocol typestate).")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src tests)")
    parser.add_argument("--root", default=None,
                        help="repo root for path normalisation "
                             "(default: two levels above this file)")
    parser.add_argument("--baseline", default=None,
                        help="baseline file "
                             "(default: scripts/lint_baseline.txt)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from this run")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report every violation, grandfathered "
                             "or not")
    parser.add_argument("--changed", action="store_true",
                        help="check only files changed vs the git merge "
                             "base (untracked included); the whole tree "
                             "is still parsed for cross-file facts")
    parser.add_argument("--base", default=None,
                        help="git ref for --changed (default: "
                             "origin/main, then main)")
    parser.add_argument("--format", choices=("text", "annotations"),
                        default="text", dest="fmt",
                        help="'annotations' emits GitHub ::error "
                             "workflow commands")
    parser.add_argument("--cache", default=None,
                        help=f"result cache file "
                             f"(default: <root>/{_CACHE_NAME})")
    parser.add_argument("--no-cache", action="store_true",
                        help="ignore and do not write the result cache")
    args = parser.parse_args(argv)

    root = args.root or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    paths = args.paths or [os.path.join(root, "src"),
                           os.path.join(root, "tests")]
    baseline_path = args.baseline or os.path.join(
        root, "scripts", "lint_baseline.txt")
    cache_path = None if args.no_cache else (
        args.cache or os.path.join(root, _CACHE_NAME))

    only: Optional[Set[str]] = None
    if args.changed:
        changed = changed_paths(root, base=args.base)
        if changed is None:
            print("repro-lint: --changed could not resolve a git merge "
                  "base; falling back to a full lint", file=sys.stderr)
        else:
            only = set(changed)

    violations, checked, cached = lint_paths_cached(
        paths, root=root, cache_path=cache_path, only=only)

    if args.update_baseline:
        if only is not None:
            print("repro-lint: refusing --update-baseline with "
                  "--changed (the baseline must cover the whole tree)",
                  file=sys.stderr)
            return 2
        write_baseline(baseline_path, violations)
        print(f"wrote {len({v.fingerprint() for v in violations})} "
              f"fingerprints to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    fresh = [v for v in violations if v.fingerprint() not in baseline]

    render = _render_annotation if args.fmt == "annotations" else \
        Violation.render
    for v in fresh:
        print(render(v))
    scope = (f"{checked} checked, {cached} cached"
             + (f", {len(only)} changed" if only is not None else ""))
    if fresh:
        by_rule: Dict[str, int] = {}
        for v in fresh:
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1
        summary = ", ".join(f"{k}: {n}" for k, n in sorted(by_rule.items()))
        print(f"repro-lint: {len(fresh)} new violation(s) ({summary}); "
              f"{len(violations) - len(fresh)} grandfathered ({scope}).")
        return 1
    msg = f"repro-lint: clean ({len(violations)} grandfathered; {scope})"
    if only is None:
        # stale-baseline detection needs the full-tree violation set
        stale = baseline - {v.fingerprint() for v in violations}
        if stale:
            msg += (f"; {len(stale)} baseline entr"
                    f"{'y is' if len(stale) == 1 else 'ies are'} stale — "
                    f"consider --update-baseline")
    print(msg)
    return 0


if __name__ == "__main__":
    sys.exit(main())
