"""Recompile guards: turn "compiles once" from a claim into an assert.

``jax.jit`` retraces whenever it sees a new static signature — a dtype
drift, a weak-type flip, a shape change, a new hashable static arg.
Each retrace re-runs the Python body, so wrapping the *function being
jitted* in :class:`TraceCounter` counts compilations directly, without
reaching into jax cache internals (which move between versions):

    counter = TraceCounter(step_fn)
    jstep = jax.jit(counter)
    ... run many rounds ...
    assert counter.count == 1

The MATCHA invariant from PR 4 — per-round sampled topologies feed a
*traced* consensus matrix, so ``K`` rounds cost one compilation — is
asserted in ``tests/test_recompile_guard.py`` using this helper.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

__all__ = ["TraceCounter", "assert_max_traces"]


class TraceCounter:
    """Wrap a function so each *trace* (Python-body execution under
    ``jax.jit``) increments ``count``.  Calls through an already
    compiled executable do not re-enter Python, so after warmup the
    count only moves on a retrace."""

    def __init__(self, fn: Callable[..., Any], name: str = ""):
        self.fn = fn
        self.count = 0
        self.name = name or getattr(fn, "__name__", "fn")
        functools.update_wrapper(self, fn)

    def __call__(self, *args, **kwargs):
        self.count += 1
        return self.fn(*args, **kwargs)

    def reset(self) -> None:
        self.count = 0


def assert_max_traces(counter: TraceCounter, limit: int = 1) -> None:
    if counter.count > limit:
        raise AssertionError(
            f"'{counter.name}' traced {counter.count} times "
            f"(limit {limit}): a static signature is varying across "
            f"calls — check dtypes, weak types, shapes and static args")
