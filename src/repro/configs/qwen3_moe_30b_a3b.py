"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8 routing
[hf:Qwen/Qwen3-30B-A3B].  48L d_model=2048 32H (GQA kv=4, head_dim=128)
expert d_ff=768 vocab=151936.  ~3B active / ~30B total parameters."""

from repro.models import ModelConfig
from repro.models.config import MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    moe=MoEConfig(
        n_experts=128,
        top_k=8,
        d_expert=768,
        n_shared=0,
        capacity_factor=1.25,
    ),
)
