"""internvl2-76b [vlm]: InternViT + LLM backbone [arXiv:2404.16821].
Backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision encoder is a STUB per the assignment carve-out:
``input_specs`` supplies 256 precomputed patch embeddings (dim 1024)
that a learned projector maps into the LM embedding space."""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vision_prefix_len=256,
)
