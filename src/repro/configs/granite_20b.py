"""granite-20b [dense]: code model, GPT-BigCode-style MQA
[arXiv:2405.04324].  52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; GELU MLP."""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu",
)
