"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Ten assigned architectures (public-literature pool) spanning dense, MoE,
SSM, hybrid, VLM and audio families — see each module's docstring for the
source citation.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models import ModelConfig

_MODULES: Dict[str, str] = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "xlstm-350m": "xlstm_350m",
    "internvl2-76b": "internvl2_76b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-20b": "granite_20b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-large-v3": "whisper_large_v3",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, **overrides) -> ModelConfig:
    key = arch_id.lower()
    if key not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


# ---------------------------------------------------------------------------
# Input shapes of the assignment.

INPUT_SHAPES = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


def long_context_supported(cfg: ModelConfig) -> bool:
    """long_500k requires sub-quadratic decode (see DESIGN.md §4)."""
    return cfg.is_subquadratic


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return long_context_supported(cfg)
    return True
