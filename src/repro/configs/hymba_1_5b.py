"""hymba-1.5b [hybrid]: parallel attention + mamba heads
[arXiv:2411.13676].  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 ssm_state=16; sliding window 1024 everywhere except
full-attention layers every 16 (first/middle)."""

from repro.models import ModelConfig
from repro.models.config import SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    block_pattern=("hymba",) * 32,
    sliding_window=1024,
    global_attn_every=16,
    ssm=SSMConfig(d_state=16, expand=2),
)
