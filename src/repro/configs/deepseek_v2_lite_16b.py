"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE
[arXiv:2405.04434].  27L d_model=2048 16H d_ff=1408(expert)
vocab=102400; 2 shared + 64 routed experts, top-6; first layer dense
(d_ff=10944) as in the reference model.  The MLA decode path caches
only (c_kv, k_rope) — 576 dims/token instead of 2*16*128."""

from repro.models import ModelConfig
from repro.models.config import MoEConfig, MLAConfig

_PATTERN = ("mla",) + ("mla_moe",) * 26

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense first layer; experts use moe.d_expert
    vocab_size=102400,
    block_pattern=_PATTERN,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        d_shared=1408,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        q_lora_rank=0,
    ),
)
