"""whisper-large-v3 [audio]: encoder-decoder [arXiv:2212.04356].
32L decoder + 32L encoder, d_model=1280 20H d_ff=5120 vocab=51866.
The mel-spectrogram + conv frontend is a STUB per the assignment
carve-out: ``input_specs`` supplies 1500 precomputed frame features
(dim 128) consumed by a learned projection."""

from repro.models import ModelConfig
from repro.models.config import EncoderConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_variant="gelu",
    encoder=EncoderConfig(n_layers=32, seq_len=1500, is_causal=False),
)
