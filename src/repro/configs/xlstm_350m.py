"""xlstm-350m [ssm]: sLSTM + mLSTM block stack [arXiv:2405.04517].
24L d_model=1024 4H d_ff=0 (mLSTM blocks carry an internal 2x
up-projection instead of a separate FFN) vocab=50304.
Pattern: sLSTM at every 6th position (xLSTM[~7:1] ratio)."""

from repro.models import ModelConfig
from repro.models.config import SSMConfig

_PATTERN = tuple(
    "slstm" if (i % 6 == 3) else "mlstm" for i in range(24)
)

CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
    ssm=SSMConfig(d_state=16, expand=2),
)
