from .optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    clip_by_global_norm,
    inverse_sqrt_decay,
)
