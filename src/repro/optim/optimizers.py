"""Minimal pytree optimizers (paper uses SGD and Adam — Appendix G.3)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _tree_zeros_like(params, dtype=None):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params
    )


def sgd(lr: float | Callable[[jax.Array], jax.Array]) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        eta = lr_fn(step)
        new = jax.tree_util.tree_map(lambda p, g: p - eta * g.astype(p.dtype),
                                     params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float | Callable, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return _tree_zeros_like(params)

    def update(grads, state, params, step):
        eta = lr_fn(step)
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: beta * m + g, new_m, grads)
        else:
            upd = new_m
        new_p = jax.tree_util.tree_map(lambda p, u: p - eta * u.astype(p.dtype),
                                       params, upd)
        return new_p, new_m

    return Optimizer(init, update)


def adam(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "mu": _tree_zeros_like(params, jnp.float32),
            "nu": _tree_zeros_like(params, jnp.float32),
        }

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        eta = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * step_).astype(p.dtype)

        new_p = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_p, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def inverse_sqrt_decay(base_lr: float, warmup: int = 0):
    """The paper decays lr with the inverse square root of the round count."""

    def lr(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        val = base_lr / jnp.sqrt(s)
        if warmup:
            val = jnp.where(step < warmup, base_lr * (step + 1) / warmup, val)
        return val

    return lr
