"""Synthetic data pipeline: deterministic, infinite, shardable.

Generates next-token-prediction batches from per-silo Markov-ish token
distributions (non-iid across silos via ``dirichlet_vocab_partition``).
Batch layout matches DPASGD: [n_silos?, s_local, batch, seq].
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig
from .partition import dirichlet_vocab_partition


@dataclass
class SyntheticLMStream:
    """Per-silo synthetic LM stream.  Tokens are drawn from the silo's
    Dirichlet vocab distribution with a bigram twist (token t+1 depends on
    t mod a small table) so the LM has learnable structure."""

    vocab_size: int
    seq_len: int
    n_silos: int = 1
    alpha: float = 0.3
    seed: int = 0

    def __post_init__(self):
        self.probs = dirichlet_vocab_partition(
            self.n_silos, self.vocab_size, self.alpha, self.seed
        )
        rng = np.random.default_rng(self.seed + 1)
        # shared bigram shift table: next ~ P_silo shifted by table[t % 17]
        self.shift = rng.integers(0, self.vocab_size, size=17)

    def sample(self, silo: int, batch: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + silo * 7919 + step) % (2 ** 63)
        )
        p = self.probs[silo]
        base = rng.choice(self.vocab_size, size=(batch, self.seq_len + 1), p=p)
        # inject bigram structure on half the positions
        mix = rng.random((batch, self.seq_len + 1)) < 0.5
        shifted = (base[:, :-1] + self.shift[base[:, :-1] % 17]) % self.vocab_size
        seq = base.copy()
        seq[:, 1:] = np.where(mix[:, 1:], shifted, base[:, 1:])
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }


@dataclass
class FederatedBatcher:
    """Yields DPASGD batches [n_silos, s, B, S] (or [s, B, S] if 1 silo)."""

    stream: SyntheticLMStream
    local_steps: int
    batch_per_silo: int

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def batch(
        self, step: int, silos: Optional[Tuple[int, ...]] = None
    ) -> Dict[str, np.ndarray]:
        """One DPASGD batch.

        ``silos`` restricts (and orders) the stacked silo dimension to a
        subset of the stream's silo universe — under elastic membership
        the mesh hosts only the active silos, but each silo label keeps
        its own data distribution across leaves/rejoins (row k of the
        batch is silo ``silos[k]``, not "the k-th mesh position's
        stream").  Default: every silo, in label order."""
        s, B = self.local_steps, self.batch_per_silo
        labels = tuple(range(self.stream.n_silos)) if silos is None else tuple(silos)
        per_silo = []
        for i in labels:
            if not (0 <= i < self.stream.n_silos):
                raise ValueError(
                    f"silo {i} outside stream universe 0..{self.stream.n_silos - 1}"
                )
            micro = [self.stream.sample(i, B, step * s + m) for m in range(s)]
            per_silo.append(
                {k: np.stack([m[k] for m in micro]) for k in micro[0]}
            )
        if self.stream.n_silos == 1 and silos is None:
            return per_silo[0]
        return {k: np.stack([ps[k] for ps in per_silo]) for k in per_silo[0]}


def make_batch_specs(
    cfg: ModelConfig,
    global_batch: int,
    seq_len: int,
    local_steps: int,
    *,
    dtype=jnp.int32,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a DPASGD training batch (used by the
    dry-run; mirrors ``input_specs``)."""
    n = cfg.n_silos
    per = global_batch // max(n, 1)
    lead: Tuple[int, ...] = (n, local_steps) if n > 1 else (local_steps,)
    shape = lead + (per, seq_len)
    out = {
        "tokens": jax.ShapeDtypeStruct(shape, dtype),
        "labels": jax.ShapeDtypeStruct(shape, dtype),
    }
    return out
