"""Federated data partitioning (Appendix G).

The paper builds non-iid silo datasets two ways: label-skew splits of
LEAF datasets (lognormal writer counts) and geo-assignment of iNaturalist
images.  For synthetic LM streams we reproduce the *statistical* shape:
per-silo token distributions drawn from a Dirichlet over vocab buckets
(label-skew analogue) and lognormal silo dataset sizes.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def lognormal_sizes(n_silos: int, total: int, mean: float = 5.0,
                    sigma: float = 1.5, seed: int = 0) -> np.ndarray:
    """Silo dataset sizes ~ lognormal(mean, sigma), normalized to ``total``
    (the paper associates a lognormal number of writers/roles per silo)."""
    rng = np.random.default_rng(seed)
    raw = rng.lognormal(mean, sigma, n_silos)
    sizes = np.maximum(1, (raw / raw.sum() * total).astype(np.int64))
    return sizes


def dirichlet_vocab_partition(
    n_silos: int, vocab_size: int, alpha: float = 0.3, seed: int = 0
) -> np.ndarray:
    """Per-silo token sampling distributions [n_silos, vocab].

    Lower alpha -> more skew (more non-iid), mirroring the pathological
    splits used for LEAF in [57].
    """
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.full(vocab_size, alpha), size=n_silos)
    return probs.astype(np.float64)


def jensen_shannon(p: np.ndarray, q: np.ndarray) -> float:
    """JS divergence between silo label distributions (Appendix H.4
    diagnostic, Fig. 25)."""
    p = p / p.sum()
    q = q / q.sum()
    m = 0.5 * (p + q)

    def kl(a, b):
        mask = a > 0
        return float(np.sum(a[mask] * np.log(a[mask] / b[mask])))

    return 0.5 * kl(p, m) + 0.5 * kl(q, m)
