from .pipeline import SyntheticLMStream, FederatedBatcher, make_batch_specs
from .partition import dirichlet_vocab_partition, lognormal_sizes
