from .io import (
    load_checkpoint,
    save_checkpoint,
    save_silo_checkpoint,
    tree_from_bytes,
    tree_to_bytes,
)
