from .io import save_checkpoint, load_checkpoint, tree_to_bytes, tree_from_bytes
