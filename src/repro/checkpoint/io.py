"""Checkpointing: msgpack-serialized pytrees with shape/dtype manifest.

Works with sharded arrays (gathers addressable shards to host), supports
partial restore (structure validated leaf-by-leaf), atomic writes.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def tree_to_bytes(tree) -> bytes:
    flat = _flatten_with_paths(tree)
    payload = {
        k: {
            "dtype": str(v.dtype),
            "shape": list(v.shape),
            "data": v.tobytes(),
        }
        for k, v in flat.items()
    }
    return msgpack.packb(payload, use_bin_type=True)


def tree_from_bytes(blob: bytes, like) -> Any:
    payload = msgpack.unpackb(blob, raw=False)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(
            rec["shape"]
        )
        expect = jnp.asarray(leaf)
        if tuple(arr.shape) != tuple(expect.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs model {expect.shape}"
            )
        leaves.append(jnp.asarray(arr, dtype=expect.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(path: str, state, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    blob = tree_to_bytes(state)
    d = os.path.dirname(os.path.abspath(path))
    with tempfile.NamedTemporaryFile(dir=d, delete=False) as f:
        f.write(blob)
        tmp = f.name
    os.replace(tmp, path)
    meta = {"step": int(step) if step is not None else None, "bytes": len(blob)}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str, like) -> Any:
    with open(path, "rb") as f:
        blob = f.read()
    return tree_from_bytes(blob, like)


def save_silo_checkpoint(directory: str, silo: int, state, step: int) -> str:
    """Checkpoint one departing silo's shard under elastic membership.

    ``state`` is the silo-stacked train state *sliced to this silo's row*
    (every leaf without its leading silo dimension) — the leaver's
    parameters and optimizer slots at the instant its shard is dropped
    from the mesh, so a later rejoin (or audit) can recover exactly what
    the silo had trained.  Returns the written path
    ``<directory>/silo<label>_step<step>.msgpack``."""
    path = os.path.join(directory, f"silo{int(silo)}_step{int(step)}.msgpack")
    save_checkpoint(path, state, step=step)
    return path
