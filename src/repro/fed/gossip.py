"""Gossip (consensus) step of DPASGD as TPU collective schedules.

The consensus matrix A (doubly stochastic, support = overlay edges) is
compiled to one of three implementations:

* ``einsum``   — w <- einsum('ij,j...->i...', A, w) over the leading silo
                 dimension.  Reference semantics; XLA lowers it to an
                 all-gather over the silo axis (cost independent of the
                 overlay sparsity — this is the *naive* schedule).
* ``ppermute`` — Birkhoff-von Neumann decomposition of A into
                 permutations; each permutation becomes one
                 ``jax.lax.ppermute`` inside a ``shard_map`` over the silo
                 axis.  Communication volume = (#non-identity permutations)
                 x |params| — proportional to the overlay degree, exactly
                 the dependence the paper's delay model (Eq. 3) rewards.
                 RING topologies need a single ppermute.
* ``pallas``   — same transfers as ``ppermute`` but the K-way weighted
                 combine runs through the fused ``gossip_mix`` kernel.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.birkhoff import birkhoff_decomposition
from repro.obs import metrics as obs_metrics

if hasattr(jax, "shard_map"):  # jax >= 0.6

    def _shard_map(fn, mesh, in_specs, out_specs):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )

else:  # jax 0.4.x: experimental API, check_rep instead of check_vma
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(fn, mesh, in_specs, out_specs):
        return _exp_shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )


@dataclass(frozen=True)
class GossipPlan:
    """Compiled consensus schedule: one overlay's mixing as collectives.

    Attributes
    ----------
    matrix:
        ``[n, n]`` doubly-stochastic consensus matrix A (support = the
        overlay's arcs + self loops).
    terms:
        The Birkhoff decomposition of A as ``(coeff, perm)`` pairs, where
        ``perm[i]`` is the silo that destination i *receives from*; each
        non-identity term lowers to one ``jax.lax.ppermute``.
    n_silos:
        n, the silo count (== the silo mesh-axis size at runtime).
    """

    matrix: np.ndarray                       # [n, n] doubly stochastic
    terms: Tuple[Tuple[float, Tuple[int, ...]], ...]  # (coeff, recv-from perm)
    n_silos: int

    @staticmethod
    def from_matrix(A: np.ndarray) -> "GossipPlan":
        """Decompose a doubly-stochastic ``[n, n]`` matrix into a plan."""
        terms = birkhoff_decomposition(np.asarray(A, np.float64))
        packed = tuple((float(c), tuple(int(x) for x in p)) for c, p in terms)
        return GossipPlan(matrix=np.asarray(A), terms=packed, n_silos=A.shape[0])

    @property
    def num_transfers(self) -> int:
        """Non-identity permutations = point-to-point transfers per round."""
        ident = tuple(range(self.n_silos))
        return sum(1 for (_, p) in self.terms if p != ident)


class PlanSlot:
    """Hot-swap hook for the active gossip plan.

    A ``GossipPlan`` is baked into the jitted train step (its Birkhoff
    terms decide which ``ppermute`` calls are traced), so it cannot change
    under a compiled function's feet.  The slot makes the swap explicit:
    the training loop builds its step from ``slot.plan`` and re-lowers
    whenever ``slot.version`` moves; an online controller (see
    :mod:`repro.dynamics.controller`) calls :meth:`swap` between rounds.
    ``on_swap`` callbacks fire synchronously inside :meth:`swap` — e.g. to
    drop a cached compiled step.  ``history`` keeps an audit trail of
    (version, label) swaps.
    """

    _slot_kind = "plan"  # metric namespace; subclasses override

    def __init__(self, plan: GossipPlan):
        self._plan = plan
        self.version = 0
        self.history: List[Tuple[int, str]] = [(0, "init")]
        self._callbacks: List[Any] = []

    @property
    def plan(self) -> GossipPlan:
        return self._plan

    def on_swap(self, callback) -> Any:
        """Register ``callback(plan, version)``; returns it (decorator use)."""
        self._callbacks.append(callback)
        return callback

    def swap(self, plan: GossipPlan, label: str = "", *,
             allow_resize: bool = False) -> int:
        """Install ``plan`` and bump ``version``.

        A plan over a different silo count is rejected unless
        ``allow_resize=True`` — the caller asserting that the silo mesh
        axis is being rebuilt too (elastic membership: the controller
        resizes the plan only after swapping a
        :class:`MembershipSlot`, and the training loop migrates
        mesh/state before re-lowering on the resized plan)."""
        if not allow_resize and plan.n_silos != self._plan.n_silos:
            raise ValueError(
                f"plan spans {plan.n_silos} silos, slot holds {self._plan.n_silos}"
            )
        self._plan = plan
        self.version += 1
        self.history.append((self.version, label))
        obs_metrics.counter(f"slot.{self._slot_kind}_swaps").inc()
        obs_metrics.gauge(f"slot.{self._slot_kind}_version").set(self.version)
        for cb in self._callbacks:
            cb(plan, self.version)
        return self.version


class ScheduleSlot(PlanSlot):
    """Hot-swap slot for *schedule*-valued state (randomized plans).

    Extends :class:`PlanSlot` from one fixed :class:`GossipPlan` to a
    :class:`repro.core.schedule.Schedule`: every communication round the
    active schedule samples that round's overlay
    (``schedule.round_edges(k)``) and the slot materializes it as a
    consensus matrix / :class:`GossipPlan`.  Because ``round_edges`` is a
    pure function of (schedule state, round counter), **every silo
    holding an equal slot derives the identical plan for round k from the
    shared round counter alone** — no cross-silo coordination, the
    property MATCHA deployments rely on (Appendix G.3) and that
    ``tests/test_schedule.py`` pins down.

    Plans are cached per sampled edge set, bounded FIFO at
    ``max_cached_plans`` (a MATCHA schedule over few matchings revisits a
    small subset family; over many matchings almost every round is fresh
    and an unbounded cache would grow for the process lifetime), and
    ``version`` moves only on :meth:`swap_schedule` — per-round sampling
    is expected churn, not a topology change.  For a deterministic
    :class:`FixedSchedule` the slot degenerates to a :class:`PlanSlot`
    whose plan never varies.
    """

    _slot_kind = "schedule"

    def __init__(self, schedule, n_silos: int, silos: Optional[Sequence] = None,
                 max_cached_plans: int = 512):
        from repro.core.consensus import local_degree_matrix

        self._local_degree_matrix = local_degree_matrix
        self._n = int(n_silos)
        self._silos = tuple(silos) if silos is not None else None
        self._schedule = schedule
        self._plan_cache: dict = {}
        self._max_cached = int(max_cached_plans)
        super().__init__(self.plan_for_round(0))

    @property
    def schedule(self):
        return self._schedule

    def swap_schedule(self, schedule, label: str = "",
                      silos: Optional[Sequence] = None) -> int:
        """Install a new schedule (fixed or randomized); bumps ``version``
        and fires the ``on_swap`` callbacks with the round-0 plan.

        ``silos`` re-pins the label -> mesh-position order — pass it when
        elastic membership changed the active universe (the new schedule
        spans different silos than the old one); the round-0 plan is then
        allowed to change silo count, and the caller must rebuild the
        mesh/state to match (see :class:`MembershipSlot`)."""
        resized = silos is not None
        rollback = (self._schedule, self._silos, self._n, self._plan_cache,
                    self._plan, self.version, list(self.history))
        if resized:
            self._silos = tuple(silos)
            self._n = len(self._silos)
        self._schedule = schedule
        self._plan_cache = {}
        try:
            return self.swap(self.plan_for_round(0), label=label,
                             allow_resize=resized)
        except Exception:
            # failed swaps leave the slot untouched (PlanSlot invariant) —
            # including the base-class plan/version/history, which a
            # raising on_swap callback would otherwise leave half-moved
            (self._schedule, self._silos, self._n, self._plan_cache,
             self._plan, self.version, history) = rollback
            self.history[:] = history
            raise

    def _index(self, label) -> int:
        if self._silos is not None:
            return self._silos.index(label)
        return int(label)

    def plan_for_round(self, round_idx: int) -> GossipPlan:
        """The (deterministic) gossip plan of communication round
        ``round_idx`` under the active schedule."""
        edges = self._schedule.round_edges(round_idx)
        idx_edges = tuple(
            sorted(
                (self._index(i), self._index(j)) for (i, j) in edges if i != j
            )
        )
        plan = self._plan_cache.get(idx_edges)
        if plan is None:
            A = self._local_degree_matrix(self._n, list(idx_edges))
            plan = GossipPlan.from_matrix(A)
            if len(self._plan_cache) >= self._max_cached:  # FIFO bound
                self._plan_cache.pop(next(iter(self._plan_cache)))
            self._plan_cache[idx_edges] = plan
        return plan

    def matrix_for_round(self, round_idx: int) -> np.ndarray:
        """Consensus matrix of round ``round_idx`` — the array fed to a
        traced-consensus train step (no re-lowering between rounds)."""
        return self.plan_for_round(round_idx).matrix


class MembershipSlot:
    """Versioned active-silo set — the elastic-membership sibling of
    :class:`PlanSlot` / :class:`ScheduleSlot`.

    The silo *universe* (labels ``0..n_universe-1``, the underlay's full
    silo set) is fixed at launch; the *active* subset changes on
    ``SiloJoin`` / ``SiloLeave`` churn.  The device mesh axis and the
    silo-stacked train state are sized to ``active``, so unlike a plan
    swap a membership swap cannot be absorbed by re-lowering alone: the
    training loop watches ``version`` and on a move re-builds the mesh,
    migrates the state (gather → re-stack → re-shard; survivors keep
    their rows bit-identical, joiners enter at the survivors' consensus
    average — :func:`repro.fed.dpasgd.migrate_silo_state`), and re-lowers
    the train step over the new silo count.  The online controller calls
    :meth:`swap` when its membership signal drifts, *before* resizing the
    plan/schedule slots, so consumers always observe membership first.

    ``swap`` with an unchanged active set is a no-op (version does not
    move); ``history`` keeps the (version, label) audit trail and
    ``on_swap`` callbacks fire synchronously with ``(active, version)``.
    """

    def __init__(self, active: Sequence[int], n_universe: int):
        self._universe = int(n_universe)
        self._active = self._validate(active)
        self.version = 0
        self.history: List[Tuple[int, str]] = [(0, "init")]
        self._callbacks: List[Any] = []

    def _validate(self, active: Sequence[int]) -> Tuple[int, ...]:
        act = tuple(sorted(int(v) for v in active))
        if not act:
            raise ValueError("membership cannot be empty: >= 1 active silo")
        if len(set(act)) != len(act):
            raise ValueError(f"duplicate silos in membership {act}")
        if act[0] < 0 or act[-1] >= self._universe:
            raise ValueError(
                f"membership {act} outside universe 0..{self._universe - 1}"
            )
        return act

    @property
    def active(self) -> Tuple[int, ...]:
        """Sorted active silo labels; index k is mesh position k."""
        return self._active

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_universe(self) -> int:
        return self._universe

    def on_swap(self, callback) -> Any:
        """Register ``callback(active, version)``; returns it."""
        self._callbacks.append(callback)
        return callback

    def swap(self, active: Sequence[int], label: str = "") -> int:
        """Install a new active set; returns the (possibly unmoved)
        version.  No-op when the set is unchanged."""
        act = self._validate(active)
        if act == self._active:
            return self.version
        self._active = act
        self.version += 1
        self.history.append((self.version, label))
        obs_metrics.counter("slot.membership_swaps").inc()
        obs_metrics.gauge("slot.membership_version").set(self.version)
        obs_metrics.gauge("slot.membership_active").set(len(act))
        for cb in self._callbacks:
            cb(act, self.version)
        return self.version


def gossip_einsum(params: Any, A: jax.Array) -> Any:
    """Reference gossip: dense mixing over the leading silo dimension.

    ``params`` is a pytree whose leaves carry a leading silo dim of size
    n; ``A`` is the ``[n, n]`` consensus matrix.  Returns the same pytree
    with every leaf replaced by ``einsum('ij,j...->i...', A, leaf)`` —
    XLA lowers this to an all-gather over the silo axis, so its traffic
    is overlay-independent (the naive baseline the ppermute schedule
    beats)."""
    return jax.tree_util.tree_map(
        lambda w: jnp.einsum("ij,j...->i...", A.astype(w.dtype), w), params
    )


def _perm_to_pairs(perm: Sequence[int]) -> List[Tuple[int, int]]:
    """perm[i] = source silo for destination i -> ppermute (src, dst) pairs."""
    return [(int(s), int(d)) for d, s in enumerate(perm)]


def gossip_shard_map(
    params: Any,
    plan: GossipPlan,
    mesh: jax.sharding.Mesh,
    axis: str,
    *,
    use_pallas: bool = False,
    pallas_interpret: Optional[bool] = None,  # None = auto (TPU compiled)
    extra_spec: Tuple = (),
) -> Any:
    """Apply the Birkhoff ppermute schedule over mesh axis ``axis``.

    ``params`` leaves have a leading silo dim of size n_silos sharded over
    ``axis`` (plus whatever ``extra_spec`` shards the remaining dims).
    """
    ident = tuple(range(plan.n_silos))

    def local_mix(w):
        # inside shard_map: w has leading silo dim of local size 1
        acc = None
        for (coeff, perm) in plan.terms:
            if perm == ident:
                contrib = coeff * w.astype(jnp.float32)
            else:
                recv = jax.lax.ppermute(w, axis, _perm_to_pairs(perm))
                contrib = coeff * recv.astype(jnp.float32)
            acc = contrib if acc is None else acc + contrib
        return acc.astype(w.dtype)

    def mix_tree(tree):
        if use_pallas:
            return _pallas_mix_tree(tree, plan, axis, interpret=pallas_interpret)
        return jax.tree_util.tree_map(local_mix, tree)

    spec = P(axis, *extra_spec) if extra_spec else P(axis)
    # Build per-leaf specs preserving each leaf's rank.
    leaves, treedef = jax.tree_util.tree_flatten(params)
    specs = [P(axis, *([None] * (l.ndim - 1))) for l in leaves]
    in_spec = jax.tree_util.tree_unflatten(treedef, specs)
    fn = _shard_map(mix_tree, mesh, (in_spec,), in_spec)
    return fn(params)


def _pallas_mix_tree(
    tree: Any, plan: GossipPlan, axis: str, *, interpret: Optional[bool] = None
) -> Any:
    """Gather neighbour copies via ppermute, then run the fused Pallas
    K-way combine over the flattened parameter vector.

    ``interpret=None`` auto-selects: compiled on TPU, interpreter on CPU.
    """
    from repro.kernels import ops as kops

    ident = tuple(range(plan.n_silos))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    stack = []
    weights = []
    for (coeff, perm) in plan.terms:
        if perm == ident:
            stack.append(flat)
        else:
            stack.append(jax.lax.ppermute(flat, axis, _perm_to_pairs(perm)))
        weights.append(coeff)
    mixed = kops.gossip_mix(jnp.stack(stack), jnp.asarray(weights, jnp.float32),
                            interpret=interpret)
    out = []
    offset = 0
    for shape, size in zip(shapes, sizes):
        out.append(mixed[offset : offset + size].reshape(shape))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, out)


def collective_bytes_per_round(plan: GossipPlan, param_bytes: int) -> int:
    """Predicted gossip traffic per communication round per silo — used to
    cross-check the HLO-derived collective bytes in the roofline."""
    return plan.num_transfers * param_bytes
