"""Bridge: designed overlay (repro.core) -> runtime gossip plan (JAX).

Given N silos mapped onto a mesh axis, design the overlay with the
paper's algorithms, derive the consensus matrix, and compile it into a
``GossipPlan`` of ppermute rounds via Birkhoff decomposition.
"""

from __future__ import annotations

from typing import Dict, Hashable, Optional, Sequence, Tuple

import numpy as np

from repro.core.consensus import local_degree_matrix, ring_matrix
from repro.core.topologies import Overlay
from .gossip import GossipPlan

Node = Hashable


def _silo_index(overlay: Overlay, n_silos: int,
                silos: Optional[Sequence[Node]]) -> Dict[Node, int]:
    """Map silo labels -> mesh positions 0..n-1.

    Silo ids need not be a 0-based contiguous int range (string labels,
    sparse ids).  The caller can fix the mesh order via ``silos``;
    otherwise the labels found on the overlay edges are sorted for a
    deterministic assignment.
    """
    labels = {v for e in overlay.edges for v in e}
    if silos is None:
        try:
            silos = sorted(labels)
        except TypeError:  # mixed label types
            silos = sorted(labels, key=repr)
    else:
        missing = labels - set(silos)
        if missing:
            raise ValueError(
                f"overlay uses silo labels not in `silos`: {sorted(missing, key=repr)}"
            )
    if len(silos) != n_silos:
        raise ValueError(
            f"overlay spans {len(silos)} silos but n_silos={n_silos}"
        )
    return {v: k for k, v in enumerate(silos)}


def _ring_tour(edges: Sequence[Tuple[int, int]], n_silos: int) -> list:
    """Recover the tour order of a directed ring from its edge list.

    Starts from ``edges[0][0]`` (node 0 may not exist), walks the
    successor map, and validates that the walk closes into a single
    Hamiltonian cycle covering every silo.
    """
    nxt: Dict[int, int] = {}
    for (i, j) in edges:
        if i in nxt:
            raise ValueError(
                f"not a ring overlay: silo {i} has out-degree > 1"
            )
        nxt[i] = j
    if len(nxt) != n_silos:
        raise ValueError(
            f"not a ring overlay: {len(nxt)} edges for {n_silos} silos"
        )
    start = edges[0][0]
    tour = [start]
    cur = start
    for _ in range(n_silos):
        cur = nxt.get(cur)
        if cur is None:
            raise ValueError(f"broken ring: no successor for silo {tour[-1]}")
        if cur == start:
            break
        tour.append(cur)
    else:
        raise ValueError("broken ring: walk does not close into a cycle")
    if len(tour) != n_silos:
        raise ValueError(
            f"ring tour covers {len(tour)} of {n_silos} silos "
            "(disconnected sub-rings?)"
        )
    return tour


def plan_from_overlay(overlay: Overlay, n_silos: int,
                      kind: Optional[str] = None,
                      silos: Optional[Sequence[Node]] = None) -> GossipPlan:
    """Consensus matrix per Appendix G.3 -> Birkhoff ppermute schedule.

    ``silos`` optionally pins the silo-label -> mesh-position order;
    by default labels are taken from the overlay edges and sorted.
    """
    name = kind or overlay.name
    index = _silo_index(overlay, n_silos, silos)
    edges = [(index[i], index[j]) for (i, j) in overlay.edges]
    if name.startswith("ring"):
        tour = _ring_tour(edges, n_silos)
        A = ring_matrix(n_silos, tour)
    elif name == "star":
        # FedAvg: full averaging each (two-phase) round
        A = np.full((n_silos, n_silos), 1.0 / n_silos)
    else:
        A = local_degree_matrix(n_silos, edges)
    return GossipPlan.from_matrix(A)


def plan_for_n_silos(kind: str, n_silos: int) -> GossipPlan:
    """Topology plans for a bare silo count (no network measurements) —
    used when the silo axis is a TPU mesh axis with homogeneous links.
    The design insight still applies: ring = 1 transfer, star = O(N)."""
    if kind.startswith("ring"):
        A = ring_matrix(n_silos, list(range(n_silos)))
    elif kind == "star":
        A = np.full((n_silos, n_silos), 1.0 / n_silos)
    elif kind in ("chain", "mst"):
        edges = []
        for i in range(n_silos - 1):
            edges += [(i, i + 1), (i + 1, i)]
        A = local_degree_matrix(n_silos, edges)
    elif kind == "none":
        A = np.eye(n_silos)
    else:
        raise KeyError(kind)
    return GossipPlan.from_matrix(A)
