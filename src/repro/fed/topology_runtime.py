"""Bridge: designed overlay (repro.core) -> runtime gossip plan (JAX).

Given N silos mapped onto a mesh axis, design the overlay with the
paper's algorithms, derive the consensus matrix, and compile it into a
``GossipPlan`` of ppermute rounds via Birkhoff decomposition.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.consensus import local_degree_matrix, ring_matrix
from repro.core.topologies import Overlay
from .gossip import GossipPlan


def plan_from_overlay(overlay: Overlay, n_silos: int,
                      kind: Optional[str] = None) -> GossipPlan:
    """Consensus matrix per Appendix G.3 -> Birkhoff ppermute schedule."""
    name = kind or overlay.name
    edges = [(int(i), int(j)) for (i, j) in overlay.edges]
    if name.startswith("ring"):
        # recover the tour order from the directed edges
        nxt = {i: j for (i, j) in edges}
        tour = [0]
        while len(tour) < n_silos:
            tour.append(nxt[tour[-1]])
        A = ring_matrix(n_silos, tour)
    elif name == "star":
        # FedAvg: full averaging each (two-phase) round
        A = np.full((n_silos, n_silos), 1.0 / n_silos)
    else:
        A = local_degree_matrix(n_silos, edges)
    return GossipPlan.from_matrix(A)


def plan_for_n_silos(kind: str, n_silos: int) -> GossipPlan:
    """Topology plans for a bare silo count (no network measurements) —
    used when the silo axis is a TPU mesh axis with homogeneous links.
    The design insight still applies: ring = 1 transfer, star = O(N)."""
    if kind.startswith("ring"):
        A = ring_matrix(n_silos, list(range(n_silos)))
    elif kind == "star":
        A = np.full((n_silos, n_silos), 1.0 / n_silos)
    elif kind in ("chain", "mst"):
        edges = []
        for i in range(n_silos - 1):
            edges += [(i, i + 1), (i + 1, i)]
        A = local_degree_matrix(n_silos, edges)
    elif kind == "none":
        A = np.eye(n_silos)
    else:
        raise KeyError(kind)
    return GossipPlan.from_matrix(A)
