"""Federated runtime: DPASGD training over designed overlays.

Public surface:

* :class:`~repro.fed.gossip.GossipPlan` / :class:`~repro.fed.gossip.PlanSlot`
  — a consensus matrix compiled into a ppermute schedule, and the
  versioned hot-swap hook the online controller actuates through;
* :class:`~repro.fed.gossip.ScheduleSlot` — the schedule-valued slot for
  randomized plans: samples one :class:`~repro.fed.gossip.GossipPlan`
  per communication round from a shared round counter (every silo
  derives the identical plan with no coordination);
* :class:`~repro.fed.gossip.MembershipSlot` — the versioned active-silo
  set under elastic membership; the training loop rebuilds mesh/state
  (via :func:`~repro.fed.dpasgd.migrate_silo_state`) whenever it moves;
* :func:`~repro.fed.gossip.gossip_einsum` /
  :func:`~repro.fed.gossip.gossip_shard_map` /
  :func:`~repro.fed.gossip.collective_bytes_per_round` — the gossip
  lowerings and their traffic model;
* :class:`~repro.fed.dpasgd.DPASGDConfig`,
  :func:`~repro.fed.dpasgd.make_train_step`,
  :func:`~repro.fed.dpasgd.init_state`,
  :func:`~repro.fed.dpasgd.local_sgd_steps` — the Eq. 2 train step;
* :func:`~repro.fed.topology_runtime.plan_from_overlay` — the bridge
  from a designed :class:`~repro.core.topologies.Overlay` to a runtime
  plan.
"""

from .gossip import (
    GossipPlan,
    MembershipSlot,
    PlanSlot,
    ScheduleSlot,
    collective_bytes_per_round,
    gossip_einsum,
    gossip_shard_map,
)
from .dpasgd import (
    DPASGDConfig,
    init_state,
    local_sgd_steps,
    make_train_step,
    masked_consensus,
    migrate_silo_state,
    slice_silo_row,
)
from .topology_runtime import plan_from_overlay
