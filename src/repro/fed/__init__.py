from .gossip import (
    GossipPlan,
    PlanSlot,
    collective_bytes_per_round,
    gossip_einsum,
    gossip_shard_map,
)
from .dpasgd import DPASGDConfig, make_train_step, init_state, local_sgd_steps
from .topology_runtime import plan_from_overlay
