from .gossip import GossipPlan, gossip_einsum, gossip_shard_map, collective_bytes_per_round
from .dpasgd import DPASGDConfig, make_train_step, init_state, local_sgd_steps
from .topology_runtime import plan_from_overlay
