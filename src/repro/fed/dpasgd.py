"""DPASGD (Eq. 2) — decentralized periodic averaging SGD.

Each silo performs ``s`` local mini-batch steps, then mixes its model with
its overlay in-neighbours through the consensus matrix A:

    w_i(k+1) = sum_{j in N_i^+ u {i}} A_ij w_j(k)        (mix rounds)
    w_i(k+1) = w_i(k) - alpha * grad f_i(w_i(k))          (local rounds)

Federation axes (see DESIGN.md §3):
* ``n_silos == 1``      — degenerate: centralized data-parallel training
                          (the STAR-inside-one-pod baseline).
* ``n_silos == |axis|`` — every index of the silo mesh axis ("data" on a
                          single pod, "pod" across pods) hosts one silo;
                          params carry a leading silo dim sharded over that
                          axis and the gossip runs as ppermute schedules.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import ModelConfig
from repro.models import transformer as T
from repro.optim import Optimizer
from .gossip import GossipPlan, gossip_einsum, gossip_shard_map


@dataclass(frozen=True)
class DPASGDConfig:
    """Federation knobs of the DPASGD train step.

    ``local_steps`` is the paper's s (local mini-batch steps between
    mixes); ``gossip_impl`` picks the consensus lowering (see module
    docstring); ``silo_axis`` names the mesh axis hosting one silo per
    index; ``mix_every``/``accum_steps`` are runtime extensions (gossip
    every k-th step, gradient accumulation within a local step).
    """

    local_steps: int = 1            # s
    gossip_impl: str = "ppermute"   # "einsum" | "ppermute" | "pallas" | "none"
    silo_axis: Optional[str] = None  # mesh axis hosting silo replicas
    mix_every: int = 1              # gossip every k-th call (paper: 1)
    accum_steps: int = 1            # gradient-accumulation chunks per local step


def make_loss_fn(cfg: ModelConfig):
    def loss(params, batch):
        return T.loss_fn(params, cfg, batch)

    return loss


def masked_consensus(A, active_mask):
    """Renormalize a consensus matrix over the active silos.

    ``A`` is ``[n, n]`` row-stochastic, ``active_mask`` is ``[n]``
    (bool/0-1).  Arcs touching an inactive silo are dropped and each
    surviving row is renormalized to sum to 1, so the weight a silo gave
    its departed in-neighbours is returned to the survivors
    proportionally — consensus keeps averaging over exactly the silos
    still training.  Inactive rows (and active rows whose in-neighbours
    all left) become identity: a departed silo's stale parameters are
    frozen, not pulled toward the survivors.  Pure jnp, so it can run on
    a *traced* mask inside the ``consensus_arg`` train step."""
    A = jnp.asarray(A)
    m = (jnp.asarray(active_mask) > 0).astype(A.dtype)
    Am = A * m[None, :] * m[:, None]
    rows = Am.sum(axis=1, keepdims=True)
    keep = rows > 0
    out = Am / jnp.where(keep, rows, 1.0)
    return jnp.where(keep, out, jnp.eye(A.shape[0], dtype=A.dtype))


def _is_silo_stacked(x, n_silos: int) -> bool:
    """One rule for "does this leaf carry the leading silo dimension":
    shared by the migration and the leaver-row slicer so they cannot
    drift apart."""
    return getattr(x, "ndim", 0) > 0 and x.shape[0] == n_silos


def slice_silo_row(state, active, silo):
    """One silo's row of a silo-stacked train state (host numpy).

    ``active`` is the label tuple the state's leading dim is stacked by.
    Stacked leaves are indexed at the silo's mesh position; shared leaves
    (the step counter) pass through — the shape a leaver's shard is
    checkpointed in (:func:`repro.checkpoint.save_silo_checkpoint`)."""
    row = tuple(active).index(silo)
    n = len(active)

    def pick(x):
        x = np.asarray(jax.device_get(x))
        return x[row] if _is_silo_stacked(x, n) else x

    return jax.tree_util.tree_map(pick, state)


def migrate_silo_state(state, old_active, new_active):
    """Re-stack the silo-stacked train state from one active set to another.

    ``old_active`` / ``new_active`` are the sorted silo-label tuples the
    state's leading dimension is (was / will be) stacked by — mesh
    position k holds silo ``active[k]``.  Gathers every leaf to host and
    re-indexes the silo dimension:

    * **survivors** (labels in both sets) keep their rows *bit-identical*
      — parameters and optimizer slots migrate untouched;
    * **leavers'** rows are dropped (checkpoint them first if wanted —
      see ``launch/train.py --churn-checkpoint``);
    * **joiners** are initialized at the survivors' consensus average
      (uniform mean, accumulated in float64 and cast back to the leaf
      dtype) — the model a silo syncing from its overlay neighbours
      would converge to.

    Leaves without a leading ``len(old_active)`` dimension (the shared
    step counter) pass through unchanged.  Returns
    ``(new_state, joined, left)`` with host-numpy leaves; the caller
    re-shards onto the rebuilt mesh."""
    old_active = tuple(old_active)
    new_active = tuple(new_active)
    old_index = {v: k for k, v in enumerate(old_active)}
    survivors = [v for v in new_active if v in old_index]
    if not survivors:
        raise ValueError(
            f"no surviving silos between {old_active} and {new_active}: "
            "cannot migrate state"
        )
    joined = tuple(v for v in new_active if v not in old_index)
    left = tuple(v for v in old_active if v not in set(new_active))
    surv_rows = [old_index[v] for v in survivors]

    def move(x):
        x = np.asarray(jax.device_get(x))
        if not _is_silo_stacked(x, len(old_active)):
            return x  # shared (unstacked) leaf, e.g. the step counter
        if joined:  # consensus average only needed when someone joins
            avg = x[surv_rows].mean(axis=0, dtype=np.float64).astype(x.dtype)
            rows = [
                x[old_index[v]] if v in old_index else avg for v in new_active
            ]
            return np.stack(rows)
        return x[surv_rows]  # fancy indexing: already a fresh array

    return jax.tree_util.tree_map(move, state), joined, left


def local_sgd_steps(
    loss_fn,
    optimizer: Optimizer,
    params,
    opt_state,
    microbatches,  # pytree with leading dim s (+ optional accum dim)
    step,
    accum_steps: int = 1,
    grad_pspecs=None,
):
    """Run s local optimizer steps via lax.scan over microbatches.

    With ``accum_steps > 1`` each local step's batch carries an extra
    leading accumulation dim [s, A, B_micro, ...]: gradients are averaged
    over the A chunks before the (single) optimizer update — numerically
    identical to one step on the full local batch, but with peak
    activation memory divided by A.
    """

    def _constrain_grads(g):
        # Keep the fp32 accumulators sharded exactly like the params —
        # without this, GSPMD keeps them only model-sharded (fp32 full-
        # FSDP-axis replicas: +7.5 GB/device on qwen3-30B).
        if grad_pspecs is None:
            return g
        from repro.models.act_sharding import constrain

        return jax.tree_util.tree_map(
            lambda x, sp: constrain(x, sp), g, grad_pspecs)

    def one(carry, micro):
        p, o, st = carry
        if accum_steps > 1:
            def acc_fn(g_acc_loss, chunk):
                g_acc, l_acc = g_acc_loss
                l, g = jax.value_and_grad(loss_fn)(p, chunk)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (_constrain_grads(g_acc), l_acc + l), None

            g0 = _constrain_grads(jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p))
            (g, l), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            g = jax.tree_util.tree_map(lambda x: x / accum_steps, g)
            l = l / accum_steps
        else:
            l, g = jax.value_and_grad(loss_fn)(p, micro)
        p, o = optimizer.update(g, o, p, st)
        return (p, o, st + 1), l

    (params, opt_state, step), losses = jax.lax.scan(
        one, (params, opt_state, step), microbatches
    )
    return params, opt_state, step, losses.mean()


def make_train_step(
    cfg: ModelConfig,
    fed: DPASGDConfig,
    optimizer: Optimizer,
    plan: Optional[GossipPlan],
    mesh: Optional[jax.sharding.Mesh] = None,
    grad_pspecs=None,
    *,
    consensus_arg: bool = False,
) -> Callable:
    """Build the jittable DPASGD train step.

    state  = {"params", "opt_state", "step"}; when n_silos > 1 every leaf
    has a leading silo dimension.
    batch  = {"tokens": [n_silos?, s, B, S], "labels": ...}

    With ``consensus_arg=True`` the step takes the consensus matrix as a
    *traced* third argument — ``step_fn(state, batch, A)`` — and mixes
    via :func:`gossip_einsum`.  That is the lowering for randomized
    schedules (:class:`~repro.fed.gossip.ScheduleSlot`): the sampled
    topology changes every round, so it must be data, not a baked
    constant, or every round would recompile.  ``plan`` is ignored then.

    The traced path also takes an optional fourth argument —
    ``step_fn(state, batch, A, active_mask)`` — an ``[n]`` 0/1 mask that
    renormalizes the consensus over the active silos
    (:func:`masked_consensus`): under elastic membership a silo can
    depart mid-round-window, and the mask keeps the mix from averaging
    in its stale parameters during the one-round lag before the
    controller swaps membership and the loop rebuilds the mesh.
    """
    loss_fn = make_loss_fn(cfg)
    n_silos = cfg.n_silos
    if consensus_arg and fed.gossip_impl not in ("einsum", "none"):
        raise ValueError(
            "consensus_arg=True lowers gossip as a traced einsum; "
            f"gossip_impl={fed.gossip_impl!r} bakes the plan into the "
            "step and cannot follow a per-round matrix"
        )

    def step_fn(state, batch, consensus=None, active_mask=None):
        params, opt_state, step = state["params"], state["opt_state"], state["step"]
        if n_silos == 1:
            params, opt_state, step, loss = local_sgd_steps(
                loss_fn, optimizer, params, opt_state, batch, step,
                accum_steps=fed.accum_steps, grad_pspecs=grad_pspecs,
            )
        else:
            # vmap over the silo dimension: independent local training.
            def per_silo(p, o, b):
                p2, o2, _, l = local_sgd_steps(loss_fn, optimizer, p, o, b, step,
                                               accum_steps=fed.accum_steps,
                                               grad_pspecs=grad_pspecs)
                return p2, o2, l

            vm = (jax.vmap(per_silo, spmd_axis_name=fed.silo_axis)
                  if fed.silo_axis else jax.vmap(per_silo))
            params, opt_state, losses = vm(params, opt_state, batch)
            loss = losses.mean()
            # consensus mix (the paper's technique)
            if consensus_arg and fed.gossip_impl != "none":
                A = jnp.asarray(consensus)
                if active_mask is not None:
                    A = masked_consensus(A, active_mask)
                params = gossip_einsum(params, A)
            elif fed.gossip_impl == "einsum":
                params = gossip_einsum(params, jnp.asarray(plan.matrix))
            elif fed.gossip_impl in ("ppermute", "pallas"):
                assert mesh is not None and fed.silo_axis is not None
                params = gossip_shard_map(
                    params, plan, mesh, fed.silo_axis,
                    use_pallas=(fed.gossip_impl == "pallas"),
                )
            elif fed.gossip_impl == "none":
                pass
            else:
                raise KeyError(fed.gossip_impl)
            step = step + fed.local_steps
        return {"params": params, "opt_state": opt_state, "step": step}, {
            "loss": loss
        }

    return step_fn


def init_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array,
               dtype=jnp.float32):
    """Initialize training state for :func:`make_train_step`.

    Returns ``{"params", "opt_state", "step"}``; with ``cfg.n_silos > 1``
    every params/opt-state leaf gains a leading ``[n_silos]`` dimension
    (one independently-seeded model per silo) meant to be sharded over
    the silo mesh axis."""
    from repro.models import init_params
    from repro.models.transformer import model_specs

    specs = model_specs(cfg)
    if cfg.n_silos == 1:
        params = init_params(key, specs, dtype)
    else:
        keys = jax.random.split(key, cfg.n_silos)
        params = jax.vmap(lambda k: init_params(k, specs, dtype))(keys)
    opt_state = (
        optimizer.init(params)
        if cfg.n_silos == 1
        else jax.vmap(optimizer.init)(params)
    )
    return {"params": params, "opt_state": opt_state, "step": jnp.zeros((), jnp.int32)}
