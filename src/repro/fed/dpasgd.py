"""DPASGD (Eq. 2) — decentralized periodic averaging SGD.

Each silo performs ``s`` local mini-batch steps, then mixes its model with
its overlay in-neighbours through the consensus matrix A:

    w_i(k+1) = sum_{j in N_i^+ u {i}} A_ij w_j(k)        (mix rounds)
    w_i(k+1) = w_i(k) - alpha * grad f_i(w_i(k))          (local rounds)

Federation axes (see DESIGN.md §3):
* ``n_silos == 1``      — degenerate: centralized data-parallel training
                          (the STAR-inside-one-pod baseline).
* ``n_silos == |axis|`` — every index of the silo mesh axis ("data" on a
                          single pod, "pod" across pods) hosts one silo;
                          params carry a leading silo dim sharded over that
                          axis and the gossip runs as ppermute schedules.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import ModelConfig
from repro.models import transformer as T
from repro.optim import Optimizer
from .gossip import GossipPlan, gossip_einsum, gossip_shard_map


@dataclass(frozen=True)
class DPASGDConfig:
    """Federation knobs of the DPASGD train step.

    ``local_steps`` is the paper's s (local mini-batch steps between
    mixes); ``gossip_impl`` picks the consensus lowering (see module
    docstring); ``silo_axis`` names the mesh axis hosting one silo per
    index; ``mix_every``/``accum_steps`` are runtime extensions (gossip
    every k-th step, gradient accumulation within a local step).
    """

    local_steps: int = 1            # s
    gossip_impl: str = "ppermute"   # "einsum" | "ppermute" | "pallas" | "none"
    silo_axis: Optional[str] = None  # mesh axis hosting silo replicas
    mix_every: int = 1              # gossip every k-th call (paper: 1)
    accum_steps: int = 1            # gradient-accumulation chunks per local step


def make_loss_fn(cfg: ModelConfig):
    def loss(params, batch):
        return T.loss_fn(params, cfg, batch)

    return loss


def local_sgd_steps(
    loss_fn,
    optimizer: Optimizer,
    params,
    opt_state,
    microbatches,  # pytree with leading dim s (+ optional accum dim)
    step,
    accum_steps: int = 1,
    grad_pspecs=None,
):
    """Run s local optimizer steps via lax.scan over microbatches.

    With ``accum_steps > 1`` each local step's batch carries an extra
    leading accumulation dim [s, A, B_micro, ...]: gradients are averaged
    over the A chunks before the (single) optimizer update — numerically
    identical to one step on the full local batch, but with peak
    activation memory divided by A.
    """

    def _constrain_grads(g):
        # Keep the fp32 accumulators sharded exactly like the params —
        # without this, GSPMD keeps them only model-sharded (fp32 full-
        # FSDP-axis replicas: +7.5 GB/device on qwen3-30B).
        if grad_pspecs is None:
            return g
        from repro.models.act_sharding import constrain

        return jax.tree_util.tree_map(
            lambda x, sp: constrain(x, sp), g, grad_pspecs)

    def one(carry, micro):
        p, o, st = carry
        if accum_steps > 1:
            def acc_fn(g_acc_loss, chunk):
                g_acc, l_acc = g_acc_loss
                l, g = jax.value_and_grad(loss_fn)(p, chunk)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (_constrain_grads(g_acc), l_acc + l), None

            g0 = _constrain_grads(jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p))
            (g, l), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            g = jax.tree_util.tree_map(lambda x: x / accum_steps, g)
            l = l / accum_steps
        else:
            l, g = jax.value_and_grad(loss_fn)(p, micro)
        p, o = optimizer.update(g, o, p, st)
        return (p, o, st + 1), l

    (params, opt_state, step), losses = jax.lax.scan(
        one, (params, opt_state, step), microbatches
    )
    return params, opt_state, step, losses.mean()


def make_train_step(
    cfg: ModelConfig,
    fed: DPASGDConfig,
    optimizer: Optimizer,
    plan: Optional[GossipPlan],
    mesh: Optional[jax.sharding.Mesh] = None,
    grad_pspecs=None,
    *,
    consensus_arg: bool = False,
) -> Callable:
    """Build the jittable DPASGD train step.

    state  = {"params", "opt_state", "step"}; when n_silos > 1 every leaf
    has a leading silo dimension.
    batch  = {"tokens": [n_silos?, s, B, S], "labels": ...}

    With ``consensus_arg=True`` the step takes the consensus matrix as a
    *traced* third argument — ``step_fn(state, batch, A)`` — and mixes
    via :func:`gossip_einsum`.  That is the lowering for randomized
    schedules (:class:`~repro.fed.gossip.ScheduleSlot`): the sampled
    topology changes every round, so it must be data, not a baked
    constant, or every round would recompile.  ``plan`` is ignored then.
    """
    loss_fn = make_loss_fn(cfg)
    n_silos = cfg.n_silos
    if consensus_arg and fed.gossip_impl not in ("einsum", "none"):
        raise ValueError(
            "consensus_arg=True lowers gossip as a traced einsum; "
            f"gossip_impl={fed.gossip_impl!r} bakes the plan into the "
            "step and cannot follow a per-round matrix"
        )

    def step_fn(state, batch, consensus=None):
        params, opt_state, step = state["params"], state["opt_state"], state["step"]
        if n_silos == 1:
            params, opt_state, step, loss = local_sgd_steps(
                loss_fn, optimizer, params, opt_state, batch, step,
                accum_steps=fed.accum_steps, grad_pspecs=grad_pspecs,
            )
        else:
            # vmap over the silo dimension: independent local training.
            def per_silo(p, o, b):
                p2, o2, _, l = local_sgd_steps(loss_fn, optimizer, p, o, b, step,
                                               accum_steps=fed.accum_steps,
                                               grad_pspecs=grad_pspecs)
                return p2, o2, l

            vm = (jax.vmap(per_silo, spmd_axis_name=fed.silo_axis)
                  if fed.silo_axis else jax.vmap(per_silo))
            params, opt_state, losses = vm(params, opt_state, batch)
            loss = losses.mean()
            # consensus mix (the paper's technique)
            if consensus_arg and fed.gossip_impl != "none":
                params = gossip_einsum(params, jnp.asarray(consensus))
            elif fed.gossip_impl == "einsum":
                params = gossip_einsum(params, jnp.asarray(plan.matrix))
            elif fed.gossip_impl in ("ppermute", "pallas"):
                assert mesh is not None and fed.silo_axis is not None
                params = gossip_shard_map(
                    params, plan, mesh, fed.silo_axis,
                    use_pallas=(fed.gossip_impl == "pallas"),
                )
            elif fed.gossip_impl == "none":
                pass
            else:
                raise KeyError(fed.gossip_impl)
            step = step + fed.local_steps
        return {"params": params, "opt_state": opt_state, "step": step}, {
            "loss": loss
        }

    return step_fn


def init_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array,
               dtype=jnp.float32):
    """Initialize training state for :func:`make_train_step`.

    Returns ``{"params", "opt_state", "step"}``; with ``cfg.n_silos > 1``
    every params/opt-state leaf gains a leading ``[n_silos]`` dimension
    (one independently-seeded model per silo) meant to be sharded over
    the silo mesh axis."""
    from repro.models import init_params
    from repro.models.transformer import model_specs

    specs = model_specs(cfg)
    if cfg.n_silos == 1:
        params = init_params(key, specs, dtype)
    else:
        keys = jax.random.split(key, cfg.n_silos)
        params = jax.vmap(lambda k: init_params(k, specs, dtype))(keys)
    opt_state = (
        optimizer.init(params)
        if cfg.n_silos == 1
        else jax.vmap(optimizer.init)(params)
    )
    return {"params": params, "opt_state": opt_state, "step": jnp.zeros((), jnp.int32)}
