"""Underlay network model (Sect. 2.2, Appendix F/G).

The underlay G_u = (V ∪ V', E_u) connects access routers (V') with core
links; each silo i ∈ V attaches to one router i' via a symmetric access
link.  From the underlay we derive the *connectivity graph* G_c over the
silos with, per ordered pair (i, j):

* end-to-end latency l(i,j) = sum of link latencies along the shortest
  (distance-routed) path, with per-link latency
  ``0.0085 * distance_km + 4`` ms (Appendix F, [32]);
* available bandwidth A(i',j') = min core-link capacity along the path
  (the simulator ignores background traffic; cf. footnote 3 of the paper).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .delays import ConnectivityGraph, SiloParams

LatLon = Tuple[float, float]

EARTH_RADIUS_KM = 6371.0


def haversine_km(a: LatLon, b: LatLon) -> float:
    """Great-circle distance in km between two (lat, lon) points."""
    (lat1, lon1), (lat2, lon2) = a, b
    p1, p2 = math.radians(lat1), math.radians(lat2)
    dp = math.radians(lat2 - lat1)
    dl = math.radians(lon2 - lon1)
    h = math.sin(dp / 2) ** 2 + math.cos(p1) * math.cos(p2) * math.sin(dl / 2) ** 2
    return 2 * EARTH_RADIUS_KM * math.asin(math.sqrt(h))


def link_latency_ms(distance_km: float) -> float:
    """Per-link latency model of Appendix F: 0.0085 ms/km + 4 ms."""
    return 0.0085 * distance_km + 4.0


@dataclass(frozen=True)
class Underlay:
    """Router-level network: nodes are access routers, one silo per router."""

    name: str
    coords: Tuple[LatLon, ...]  # router i' position; silo i sits next to it
    core_edges: Tuple[Tuple[int, int], ...]  # undirected router pairs
    core_capacity_gbps: float = 1.0
    access_capacity_gbps: float = 10.0
    access_distance_km: float = 10.0

    @property
    def num_silos(self) -> int:
        return len(self.coords)

    @property
    def num_core_links(self) -> int:
        return len(self.core_edges)

    def core_adjacency(self) -> Dict[int, List[Tuple[int, float]]]:
        adj: Dict[int, List[Tuple[int, float]]] = {i: [] for i in range(self.num_silos)}
        for (u, v) in self.core_edges:
            d = haversine_km(self.coords[u], self.coords[v])
            adj[u].append((v, d))
            adj[v].append((u, d))
        return adj

    def shortest_paths(self) -> Dict[int, Tuple[List[float], List[Optional[int]]]]:
        """All-pairs distance-weighted Dijkstra over the core graph.

        Returns per-source (dist_km per node, predecessor per node).
        """
        adj = self.core_adjacency()
        out: Dict[int, Tuple[List[float], List[Optional[int]]]] = {}
        n = self.num_silos
        for s in range(n):
            dist = [math.inf] * n
            pred: List[Optional[int]] = [None] * n
            dist[s] = 0.0
            pq: List[Tuple[float, int]] = [(0.0, s)]
            while pq:
                d, u = heapq.heappop(pq)
                if d > dist[u]:
                    continue
                for (v, w) in adj[u]:
                    nd = d + w
                    if nd < dist[v] - 1e-12:
                        dist[v] = nd
                        pred[v] = u
                        heapq.heappush(pq, (nd, v))
            out[s] = (dist, pred)
        return out

    def path_nodes(self, pred: List[Optional[int]], src: int, dst: int) -> List[int]:
        path = [dst]
        while path[-1] != src:
            p = pred[path[-1]]
            if p is None:
                raise ValueError(f"{self.name}: no path {src}->{dst} (disconnected underlay)")
            path.append(p)
        path.reverse()
        return path

    def pair_metrics(
        self,
        *,
        core_capacity_gbps: Optional[Mapping[Tuple[int, int], float]] = None,
        silos: Optional[Sequence[int]] = None,
        skip_unreachable: bool = False,
    ) -> Tuple[Dict[Tuple[int, int], float], Dict[Tuple[int, int], float]]:
        """(latency_ms, available_bw_gbps) of every routed ordered silo pair.

        The single place the Sect. 2.2 path pricing lives: end-to-end
        latency = 2 access links + per-hop core latencies along the
        distance-routed shortest path; available bandwidth = min core-link
        capacity on that path.  ``core_capacity_gbps`` overrides per-link
        capacities (keyed by the sorted router pair — used by the dynamics
        layer for degraded links); ``silos`` restricts the pair set;
        ``skip_unreachable`` drops partitioned pairs instead of raising.
        """
        sp = self.shortest_paths()
        access_lat = link_latency_ms(self.access_distance_km)
        nodes = range(self.num_silos) if silos is None else sorted(silos)
        latency: Dict[Tuple[int, int], float] = {}
        avail: Dict[Tuple[int, int], float] = {}
        for i in nodes:
            dist, pred = sp[i]
            for j in nodes:
                if i == j:
                    continue
                if math.isinf(dist[j]):
                    if skip_unreachable:
                        continue
                    raise ValueError(
                        f"{self.name}: no path {i}->{j} (disconnected underlay)"
                    )
                path = self.path_nodes(pred, i, j)
                lat = 2 * access_lat
                bw = math.inf
                for (u, v) in zip(path[:-1], path[1:]):
                    lat += link_latency_ms(haversine_km(self.coords[u], self.coords[v]))
                    if core_capacity_gbps is None:
                        bw = min(bw, self.core_capacity_gbps)
                    else:
                        key = (u, v) if u <= v else (v, u)
                        bw = min(
                            bw,
                            core_capacity_gbps.get(key, self.core_capacity_gbps),
                        )
                latency[(i, j)] = lat
                avail[(i, j)] = bw
        return latency, avail

    def connectivity_graph(
        self,
        comp_time_ms: float,
        *,
        access_capacity_gbps: Optional[float] = None,
        per_silo_access_gbps: Optional[Mapping[int, float]] = None,
        per_silo_comp_ms: Optional[Mapping[int, float]] = None,
    ) -> ConnectivityGraph:
        """Derive the full-mesh connectivity graph over the silos."""
        access = access_capacity_gbps if access_capacity_gbps is not None else self.access_capacity_gbps
        n = self.num_silos
        latency, avail = self.pair_metrics()
        params: Dict[int, SiloParams] = {}
        for i in range(n):
            cap = access if per_silo_access_gbps is None else per_silo_access_gbps.get(i, access)
            ct = comp_time_ms if per_silo_comp_ms is None else per_silo_comp_ms.get(i, comp_time_ms)
            params[i] = SiloParams(comp_time_ms=ct, uplink_gbps=cap, downlink_gbps=cap)
        return ConnectivityGraph(
            silos=tuple(range(n)),
            latency_ms=latency,
            available_bw_gbps=avail,
            silo_params=params,
        )

    def load_centrality_center(self) -> int:
        """Node with the highest shortest-path load (betweenness-like)
        centrality — the paper places the STAR orchestrator there [11]."""
        n = self.num_silos
        sp = self.shortest_paths()
        load = [0.0] * n
        for s in range(n):
            _, pred = sp[s]
            for t in range(n):
                if t == s:
                    continue
                for v in self.path_nodes(pred, s, t):
                    load[v] += 1.0
        return max(range(n), key=lambda v: load[v])
