"""Max-plus linear system analysis for synchronous decentralized training.

The paper (Sect. 2.3) models the start times ``t_i(k)`` of each silo's k-th
computation phase as a linear system in the max-plus algebra:

    t_i(k+1) = max_{j in N_i^+ ∪ {i}} ( t_j(k) + d_o(j, i) )        (Eq. 4)

The asymptotic *cycle time* tau = lim t_i(k)/k equals the **maximum cycle
mean** of the weighted delay digraph (Eq. 5, [Baccelli et al., Thm 3.23]):

    tau(G_o) = max_gamma  d_o(gamma) / |gamma|

over all circuits gamma. We compute it with Karp's algorithm [Karp 1978],
which is exact and O(|V||E|). Throughput = 1 / tau.

This module is the stable, node-labelled front end.  Since the vectorized
engine landed, the heavy lifting (Karp, the timing recursion, strong
connectivity) is delegated to :mod:`repro.core.maxplus_vec`, which runs
the same DP as dense array sweeps and can score whole batches of
candidate overlays at once.  The original pure-Python implementations are
kept as ``*_legacy`` — they are the reference oracle for the old-vs-new
equivalence property tests and the baseline for
``benchmarks/maxplus_bench.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Hashable, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from . import maxplus_vec as _vec
from .maxplus_vec import NEG_INF, missing_mask

Node = Hashable
Edge = Tuple[Node, Node]


@dataclass(frozen=True)
class DelayDigraph:
    """A weighted digraph of inter-silo delays (the overlay + self loops).

    ``delays[(i, j)]`` is the total delay between the *start* of a
    computation at ``i`` and the moment ``j`` has received ``i``'s model
    (Eq. 3).  Self-delays ``delays[(i, i)] = s * T_c(i)`` model the local
    computation phase (the paper defines d_o(i, i) this way).
    """

    nodes: Tuple[Node, ...]
    delays: Mapping[Edge, float]

    @staticmethod
    def from_edges(delays: Mapping[Edge, float]) -> "DelayDigraph":
        nodes: List[Node] = []
        seen = set()
        for (i, j) in delays:
            for v in (i, j):
                if v not in seen:
                    seen.add(v)
                    nodes.append(v)
        return DelayDigraph(tuple(nodes), dict(delays))

    def successors(self, i: Node) -> List[Node]:
        return [j for (a, j) in self.delays if a == i]

    def predecessors(self, j: Node) -> List[Node]:
        return [i for (i, b) in self.delays if b == j]

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_edges(self) -> int:
        return len(self.delays)

    def to_matrix(self):
        """Dense ``[N, N]`` weight matrix (``-inf`` holes) + node order."""
        return _vec.graph_to_matrix(self)


def max_cycle_mean(graph: DelayDigraph) -> float:
    """Maximum cycle mean of a digraph (Karp; -inf for acyclic graphs).

    Delegates to the vectorized engine; ``max_cycle_mean_legacy`` is the
    original dict-based implementation, kept as the equivalence oracle.
    """
    W, _ = _vec.graph_to_matrix(graph)
    return _vec.cycle_time_dense(W)


def max_cycle_mean_legacy(graph: DelayDigraph) -> float:
    """Original pure-Python Karp-per-SCC (reference / benchmark baseline)."""
    comp_means = [
        _karp_scc(graph, scc) for scc in strongly_connected_components(graph)
    ]
    return max(comp_means, default=NEG_INF)


def _karp_scc(graph: DelayDigraph, scc: Sequence[Node]) -> float:
    nodes = list(scc)
    index = {v: k for k, v in enumerate(nodes)}
    n = len(nodes)
    if n == 0:
        return NEG_INF
    # Collect intra-SCC edges (including self loops).
    edges = [
        (index[i], index[j], w)
        for (i, j), w in graph.delays.items()
        if i in index and j in index
    ]
    if not edges:
        return NEG_INF
    # D[k][v] = max weight of a walk with exactly k edges from source to v.
    src = 0
    D = [[NEG_INF] * n for _ in range(n + 1)]
    D[0][src] = 0.0
    for k in range(1, n + 1):
        row_prev, row = D[k - 1], D[k]
        for (u, v, w) in edges:
            if row_prev[u] != NEG_INF:
                cand = row_prev[u] + w
                if cand > row[v]:
                    row[v] = cand
    best = NEG_INF
    for v in range(n):
        if D[n][v] == NEG_INF:
            continue
        # min over k of (D_n - D_k) / (n - k)
        worst = math.inf
        for k in range(n):
            if D[k][v] == NEG_INF:
                continue
            worst = min(worst, (D[n][v] - D[k][v]) / (n - k))
        if worst != math.inf:
            best = max(best, worst)
    return best


def strongly_connected_components(graph: DelayDigraph) -> List[List[Node]]:
    """Tarjan's algorithm (iterative)."""
    adj: Dict[Node, List[Node]] = {v: [] for v in graph.nodes}
    for (i, j) in graph.delays:
        if i != j:
            adj[i].append(j)
    index_counter = [0]
    stack: List[Node] = []
    lowlink: Dict[Node, int] = {}
    index: Dict[Node, int] = {}
    on_stack: Dict[Node, bool] = {}
    result: List[List[Node]] = []

    for root in graph.nodes:
        if root in index:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = index_counter[0]
                lowlink[v] = index_counter[0]
                index_counter[0] += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            succ = adj[v]
            for i in range(pi, len(succ)):
                w = succ[i]
                if w not in index:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    recurse = True
                    break
                elif on_stack.get(w, False):
                    lowlink[v] = min(lowlink[v], index[w])
            if recurse:
                continue
            if lowlink[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp.append(w)
                    if w == v:
                        break
                result.append(comp)
            work.pop()
            if work:
                parent = work[-1][0]
                lowlink[parent] = min(lowlink[parent], lowlink[v])
    return result


def is_strongly_connected(graph: DelayDigraph) -> bool:
    """True iff every silo can reach every other over the overlay arcs
    (self-loops ignored) — the precondition for a finite cycle time."""
    W, _ = _vec.graph_to_matrix(graph)
    return bool(_vec.batched_is_strongly_connected(W))


def cycle_time(graph: DelayDigraph) -> float:
    """Cycle time tau(G_o) of the overlay delay digraph (Eq. 5)."""
    return max_cycle_mean(graph)


def throughput(graph: DelayDigraph) -> float:
    """Communication rounds per time unit = 1 / tau."""
    tau = cycle_time(graph)
    if tau <= 0 or missing_mask(tau):
        return math.inf
    return 1.0 / tau


def timing_recursion(
    graph: DelayDigraph, num_rounds: int, t0: Optional[Mapping[Node, float]] = None
) -> Dict[Node, List[float]]:
    """Evolve the max-plus recursion (Eq. 4) for ``num_rounds`` rounds.

    Returns ``{i: [t_i(0), ..., t_i(num_rounds)]}``.  The key theoretical
    property (tested): ``t_i(k) / k -> tau`` for every silo i.

    Runs as a dense ``[N]``-state vector recursion; the dict-of-lists
    return shape is preserved for callers.
    """
    W, nodes = _vec.graph_to_matrix(graph)
    init = None
    if t0 is not None:
        init = np.array([float(t0.get(v, 0.0)) for v in nodes])
    series = _vec.timing_recursion_dense(W, num_rounds, init)
    return {v: series[:, k].tolist() for k, v in enumerate(nodes)}


def timing_recursion_legacy(
    graph: DelayDigraph, num_rounds: int, t0: Optional[Mapping[Node, float]] = None
) -> Dict[Node, List[float]]:
    """Original dict-based Eq. 4 recursion (reference / benchmark baseline)."""
    preds: Dict[Node, List[Node]] = {v: [] for v in graph.nodes}
    for (i, j) in graph.delays:
        if i != j:
            preds[j].append(i)
    t: Dict[Node, List[float]] = {
        v: [0.0 if t0 is None else float(t0.get(v, 0.0))] for v in graph.nodes
    }
    for k in range(num_rounds):
        cur = {v: t[v][k] for v in graph.nodes}
        for v in graph.nodes:
            self_d = graph.delays.get((v, v), 0.0)
            best = cur[v] + self_d
            for p in preds[v]:
                best = max(best, cur[p] + graph.delays[(p, v)])
            t[v].append(best)
    return t


def empirical_cycle_time(graph: DelayDigraph, num_rounds: int = 200) -> float:
    """Estimate tau by running the recursion; converges to Karp's value."""
    t = timing_recursion(graph, num_rounds)
    # Discard a warmup prefix: slope of the tail is within O(1/k) of tau.
    warmup = num_rounds // 2
    est = max(
        (series[num_rounds] - series[warmup]) / (num_rounds - warmup)
        for series in t.values()
    )
    return est


def critical_circuit(graph: DelayDigraph) -> Tuple[float, List[Node]]:
    """Return (tau, circuit) where circuit attains the max cycle mean.

    Delegates to :func:`repro.core.maxplus_vec.critical_circuit_dense`
    (array-sweep potentials + boolean-closure cycle location); the
    original per-edge Bellman-Ford implementation is kept below as
    ``critical_circuit_legacy``, the equivalence oracle.
    """
    W, nodes = _vec.graph_to_matrix(graph)
    tau, circuit = _vec.critical_circuit_dense(W)
    if circuit:
        return tau, [nodes[c] for c in circuit]
    if missing_mask(tau):
        return tau, []
    return critical_circuit_legacy(graph)  # numerically degenerate fallback


def critical_circuit_legacy(graph: DelayDigraph) -> Tuple[float, List[Node]]:
    """Original per-edge Bellman-Ford critical-circuit recovery
    (reference oracle for :func:`critical_circuit`).

    Uses the standard reduction: run Karp for tau, relax longest-path
    potentials under the reduced weights (w - tau), then search the tight
    subgraph for a zero-reduced-mean cycle.
    """
    tau = max_cycle_mean(graph)
    if tau == NEG_INF:
        return tau, []
    nodes = list(graph.nodes)
    idx = {v: k for k, v in enumerate(nodes)}
    n = len(nodes)
    eps = 1e-9 * max(1.0, abs(tau))
    edges = [(idx[i], idx[j], w - tau) for (i, j), w in graph.delays.items()]
    # With reduced weights w - tau every circuit has mean <= 0 and critical
    # circuits have mean exactly 0.  Longest-path potentials converge; the
    # "tight" edges (dist[v] == dist[u] + w') contain a zero-mean cycle.
    dist = [0.0] * n
    for _ in range(n):
        changed = False
        for (u, v, w) in edges:
            if dist[u] + w > dist[v] + eps:
                dist[v] = dist[u] + w
                changed = True
        if not changed:
            break
    tight: Dict[int, List[int]] = {v: [] for v in range(n)}
    for (u, v, w) in edges:
        if abs(dist[u] + w - dist[v]) <= 10 * eps:
            tight[u].append(v)
    # find a cycle in the tight subgraph (iterative DFS with colors)
    color = [0] * n  # 0 unvisited, 1 on stack, 2 done
    parent: Dict[int, int] = {}
    for root in range(n):
        if color[root]:
            continue
        stack = [(root, iter(tight[root]))]
        color[root] = 1
        while stack:
            v, it = stack[-1]
            advanced = False
            for u in it:
                if color[u] == 1:
                    # found cycle u -> ... -> v -> u
                    cyc = [v]
                    w_ = v
                    while w_ != u:
                        w_ = parent[w_]
                        cyc.append(w_)
                    cyc.reverse()
                    cyc.append(cyc[0])
                    return tau, [nodes[c] for c in cyc]
                if color[u] == 0:
                    color[u] = 1
                    parent[u] = v
                    stack.append((u, iter(tight[u])))
                    advanced = True
                    break
            if not advanced:
                color[v] = 2
                stack.pop()
    return tau, []
