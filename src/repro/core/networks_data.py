"""The five underlays of the paper (Table 3).

* **Gaia** (11 silos, 55 links): full mesh over the AWS regions used by
  Gaia [38] — four continents.
* **AWS North America** (22 silos, 231 links): full mesh over 22 AWS
  North-American locations [96].
* **Géant / Exodus / Ebone**: the paper reads GML files from Topology Zoo /
  Rocketfuel which are not available offline.  We build deterministic
  stand-ins with the *exact* node and link counts of Table 3
  (40/61, 79/147, 87/161) over the right geographic boxes: a distance-MST
  backbone plus the shortest remaining pairs, which yields ISP-like sparse
  graphs.  See DESIGN.md §5 for the fidelity discussion.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

from .underlay import Underlay, haversine_km

LatLon = Tuple[float, float]

# ---------------------------------------------------------------------------
# Gaia: 11 AWS regions spanning four continents [38].
GAIA_SITES: Tuple[Tuple[str, LatLon], ...] = (
    ("virginia", (38.95, -77.45)),
    ("oregon", (45.84, -119.70)),
    ("california", (37.35, -121.96)),
    ("saopaulo", (-23.55, -46.63)),
    ("ireland", (53.35, -6.26)),
    ("frankfurt", (50.11, 8.68)),
    ("tokyo", (35.68, 139.69)),
    ("seoul", (37.57, 126.98)),
    ("singapore", (1.35, 103.82)),
    ("sydney", (-33.87, 151.21)),
    ("mumbai", (19.08, 72.88)),
)

# AWS North America: 22 locations (regions + local zones) [96].
AWS_NA_SITES: Tuple[Tuple[str, LatLon], ...] = (
    ("ashburn", (39.04, -77.49)),
    ("columbus", (39.96, -83.00)),
    ("sanfrancisco", (37.77, -122.42)),
    ("portland", (45.52, -122.68)),
    ("montreal", (45.50, -73.57)),
    ("toronto", (43.65, -79.38)),
    ("calgary", (51.05, -114.07)),
    ("mexicocity", (19.43, -99.13)),
    ("atlanta", (33.75, -84.39)),
    ("boston", (42.36, -71.06)),
    ("chicago", (41.88, -87.63)),
    ("dallas", (32.78, -96.80)),
    ("denver", (39.74, -104.99)),
    ("houston", (29.76, -95.37)),
    ("kansascity", (39.10, -94.58)),
    ("lasvegas", (36.17, -115.14)),
    ("losangeles", (34.05, -118.24)),
    ("miami", (25.76, -80.19)),
    ("minneapolis", (44.98, -93.27)),
    ("newyork", (40.71, -74.01)),
    ("phoenix", (33.45, -112.07)),
    ("seattle", (47.61, -122.33)),
)


def _full_mesh(n: int) -> Tuple[Tuple[int, int], ...]:
    return tuple((i, j) for i in range(n) for j in range(i + 1, n))


def _lcg(seed: int):
    """Tiny deterministic PRNG (no numpy dependency at import time)."""
    state = seed & 0xFFFFFFFF

    def rnd() -> float:
        nonlocal state
        state = (1103515245 * state + 12345) & 0x7FFFFFFF
        return state / 0x7FFFFFFF

    return rnd


def _synthetic_coords(
    n: int, lat_range: Tuple[float, float], lon_range: Tuple[float, float], seed: int
) -> Tuple[LatLon, ...]:
    rnd = _lcg(seed)
    out: List[LatLon] = []
    for _ in range(n):
        lat = lat_range[0] + (lat_range[1] - lat_range[0]) * rnd()
        lon = lon_range[0] + (lon_range[1] - lon_range[0]) * rnd()
        out.append((round(lat, 4), round(lon, 4)))
    return tuple(out)


def _mst_plus_shortest(coords: Sequence[LatLon], n_edges: int) -> Tuple[Tuple[int, int], ...]:
    """Distance MST (Prim) + shortest remaining pairs up to ``n_edges``."""
    n = len(coords)
    assert n_edges >= n - 1, "need at least a spanning tree"
    dist = [[haversine_km(coords[i], coords[j]) for j in range(n)] for i in range(n)]
    in_tree = [False] * n
    best = [math.inf] * n
    best_to = [-1] * n
    in_tree[0] = True
    for j in range(1, n):
        best[j] = dist[0][j]
        best_to[j] = 0
    edges: List[Tuple[int, int]] = []
    for _ in range(n - 1):
        v = min((j for j in range(n) if not in_tree[j]), key=lambda j: best[j])
        edges.append((min(v, best_to[v]), max(v, best_to[v])))
        in_tree[v] = True
        for j in range(n):
            if not in_tree[j] and dist[v][j] < best[j]:
                best[j] = dist[v][j]
                best_to[j] = v
    chosen = set(edges)
    rest = sorted(
        ((i, j) for i in range(n) for j in range(i + 1, n) if (i, j) not in chosen),
        key=lambda e: dist[e[0]][e[1]],
    )
    for e in rest:
        if len(edges) >= n_edges:
            break
        edges.append(e)
    return tuple(edges)


def make_underlay(
    name: str,
    *,
    core_capacity_gbps: float = 1.0,
    access_capacity_gbps: float = 10.0,
) -> Underlay:
    """Factory for the paper's five networks."""
    key = name.lower().replace("-", "_").replace(" ", "_")
    if key == "gaia":
        coords = tuple(c for _, c in GAIA_SITES)
        edges = _full_mesh(len(coords))  # 55
    elif key in ("aws_na", "aws_north_america", "awsna"):
        coords = tuple(c for _, c in AWS_NA_SITES)
        edges = _full_mesh(len(coords))  # 231
    elif key == "geant":
        coords = _synthetic_coords(40, (36.0, 60.0), (-9.0, 26.0), seed=0x6EA7)
        edges = _mst_plus_shortest(coords, 61)
    elif key == "exodus":
        coords = _synthetic_coords(79, (30.0, 48.0), (-122.0, -71.0), seed=0xE50D)
        edges = _mst_plus_shortest(coords, 147)
    elif key == "ebone":
        coords = _synthetic_coords(87, (36.0, 60.0), (-9.0, 30.0), seed=0xEB0E)
        edges = _mst_plus_shortest(coords, 161)
    else:
        raise KeyError(f"unknown underlay {name!r}")
    return Underlay(
        name=key,
        coords=coords,
        core_edges=edges,
        core_capacity_gbps=core_capacity_gbps,
        access_capacity_gbps=access_capacity_gbps,
    )


NETWORK_NAMES: Tuple[str, ...] = ("gaia", "aws_na", "geant", "exodus", "ebone")

# (silos, links) from Table 3 — asserted in tests.
EXPECTED_SIZES: Dict[str, Tuple[int, int]] = {
    "gaia": (11, 55),
    "aws_na": (22, 231),
    "geant": (40, 61),
    "exodus": (79, 147),
    "ebone": (87, 161),
}

# ---------------------------------------------------------------------------
# Workloads of Table 2: (model size Mbits, computation time ms on P100).
WORKLOADS: Dict[str, Tuple[float, float]] = {
    "shakespeare": (3.23, 389.6),
    "femnist": (4.62, 4.6),
    "sent140": (18.38, 9.8),
    "inaturalist": (42.88, 25.4),
    "full_inaturalist": (161.06, 946.7),  # Appendix H.4 (ResNet-50)
}
