"""Birkhoff-von Neumann decomposition of doubly stochastic matrices.

A doubly stochastic consensus matrix A decomposes as

    A = sum_k  lambda_k  P_k,     lambda_k > 0, sum lambda_k = 1,

with P_k permutation matrices.  This is the bridge from the paper's
topology design to a TPU collective schedule: every permutation P_k maps
to exactly one ``jax.lax.ppermute`` over the silo axis, so the gossip step

    w_i  <-  sum_j A_ij w_j

compiles to ``sum_k lambda_k * ppermute(w, perm=P_k)`` — a number of
sequential transfers equal to the number of non-identity permutations,
mirroring the degree term of the paper's delay model (Eq. 3).
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def _perfect_matching(support: np.ndarray) -> List[int]:
    """Perfect matching on the bipartite support graph (rows -> cols) via
    Hopcroft-Karp-style augmenting paths (Hungarian augmentation)."""
    n = support.shape[0]
    match_col = [-1] * n  # col -> row
    match_row = [-1] * n  # row -> col

    def try_assign(r: int, seen: List[bool]) -> bool:
        # Prefer the diagonal: extracting the identity permutation first
        # (A_ii is usually the largest entry) saves one ppermute round.
        cols = [r] + [c for c in range(n) if c != r]
        for c in cols:
            if support[r, c] and not seen[c]:
                seen[c] = True
                if match_col[c] == -1 or try_assign(match_col[c], seen):
                    match_col[c] = r
                    match_row[r] = c
                    return True
        return False

    for r in range(n):
        if not try_assign(r, [False] * n):
            raise ValueError("no perfect matching: matrix is not doubly stochastic")
    return match_row


def birkhoff_decomposition(
    A: np.ndarray, tol: float = 1e-9, max_terms: int = 10_000
) -> List[Tuple[float, np.ndarray]]:
    """Decompose doubly stochastic ``A`` into [(coeff, perm)], where
    ``perm[i]`` is the source index feeding row i (i.e. P[i, perm[i]] = 1,
    so (P w)[i] = w[perm[i]]).

    Greedy Birkhoff: repeatedly extract the matching on the support and
    subtract ``min_entry * P``.  Terminates in at most (n-1)^2 + 1 terms;
    for a degree-d gossip matrix it produces <= d + 1 terms.
    """
    A = np.array(A, dtype=np.float64, copy=True)
    n = A.shape[0]
    if A.shape != (n, n):
        raise ValueError("square matrix required")
    if not np.allclose(A.sum(0), 1.0, atol=1e-6) or not np.allclose(A.sum(1), 1.0, atol=1e-6):
        raise ValueError("matrix is not doubly stochastic")
    terms: List[Tuple[float, np.ndarray]] = []
    remaining = 1.0
    for _ in range(max_terms):
        if remaining <= tol:
            break
        support = A > tol
        match_row = _perfect_matching(support)
        coeff = min(A[r, match_row[r]] for r in range(n))
        perm = np.array(match_row, dtype=np.int64)
        terms.append((float(coeff), perm))
        for r in range(n):
            A[r, perm[r]] -= coeff
        remaining -= coeff
    # normalize tiny numeric drift
    total = sum(c for c, _ in terms)
    terms = [(c / total, p) for (c, p) in terms]
    return terms


def reconstruct(terms: List[Tuple[float, np.ndarray]], n: int) -> np.ndarray:
    """Rebuild the ``[n, n]`` doubly-stochastic matrix from Birkhoff
    ``(coeff, perm)`` terms (inverse of :func:`birkhoff_decomposition`;
    used by round-trip tests)."""
    A = np.zeros((n, n))
    for (c, perm) in terms:
        for r in range(n):
            A[r, perm[r]] += c
    return A


def schedule_cost(terms: List[Tuple[float, np.ndarray]]) -> int:
    """Number of non-identity permutations = number of ppermute rounds."""
    cost = 0
    for (_, perm) in terms:
        if not np.array_equal(perm, np.arange(len(perm))):
            cost += 1
    return cost
