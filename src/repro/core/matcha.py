"""MATCHA / MATCHA+ baseline [104] (Sect. 4, Appendix G.3).

MATCHA decomposes a base topology into matchings (via edge coloring) and
activates each matching independently with probability p ~= C_b at every
communication round.  MATCHA starts from the connectivity graph; MATCHA+
starts from the underlay graph.

The paper computes MATCHA's *average cycle time* by simulation (footnote
6); we do the same: sample per-round topologies, run the max-plus timing
recursion with time-varying delays, and report the average round duration.
Per Appendix G.3 we resample whenever no matching is selected, so every
round has at least one active matching.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Set, Tuple

from .delays import ConnectivityGraph, TrainingParams, edge_delay_ms

Node = Hashable
Pair = Tuple[Node, Node]


def greedy_edge_coloring(edges: Sequence[Pair]) -> List[List[Pair]]:
    """Greedy edge coloring -> matchings.  Uses at most 2*Delta - 1 colors;
    on the sparse ISP graphs considered it lands near the Vizing bound
    Delta + 1 used by MATCHA's Misra-Gries step."""
    colors: List[List[Pair]] = []
    used: Dict[Node, Set[int]] = {}
    # Sort: high-degree-incident edges first improves the bound in practice.
    deg: Dict[Node, int] = {}
    for (u, v) in edges:
        deg[u] = deg.get(u, 0) + 1
        deg[v] = deg.get(v, 0) + 1
    for (u, v) in sorted(edges, key=lambda e: -(deg[e[0]] + deg[e[1]])):
        taken = used.setdefault(u, set()) | used.setdefault(v, set())
        c = 0
        while c in taken:
            c += 1
        while c >= len(colors):
            colors.append([])
        colors[c].append((u, v))
        used[u].add(c)
        used[v].add(c)
    return colors


@dataclass
class Matcha:
    """Sampler of per-round MATCHA topologies."""

    matchings: List[List[Pair]]
    budget: float  # C_b

    def __post_init__(self):
        # budget <= 0 never activates a matching, so the Appendix G.3
        # resample-until-nonempty loop in sample_round would spin forever;
        # budget > 1 is not a probability.  Fail at construction instead.
        if not (0.0 < self.budget <= 1.0):
            raise ValueError(
                f"MATCHA budget C_b must be in (0, 1], got {self.budget!r}"
            )

    @staticmethod
    def from_base_graph(pairs: Sequence[Pair], budget: float = 0.5) -> "Matcha":
        return Matcha(matchings=greedy_edge_coloring(list(pairs)), budget=budget)

    def sample_round(self, rng: random.Random) -> List[Pair]:
        """Independently activate each matching w.p. C_b; resample until at
        least one matching is active (Appendix G.3)."""
        while True:
            active: List[Pair] = []
            for m in self.matchings:
                if rng.random() < self.budget:
                    active.extend(m)
            if active:
                return active

    def average_cycle_time(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        *,
        rounds: int = 300,
        seed: int = 0,
    ) -> float:
        """Average round duration via the time-varying max-plus recursion."""
        rng = random.Random(seed)
        t: Dict[Node, float] = {v: 0.0 for v in gc.silos}
        for _ in range(rounds):
            active = self.sample_round(rng)
            # per-round degrees (undirected matchings -> degree = #matchings
            # covering the node; communication is bidirectional)
            deg: Dict[Node, int] = {v: 0 for v in gc.silos}
            for (u, v) in active:
                deg[u] += 1
                deg[v] += 1
            nxt: Dict[Node, float] = {}
            for v in gc.silos:
                start = t[v] + tp.local_steps * gc.silo_params[v].comp_time_ms
                nxt[v] = start
            for (u, v) in active:
                for (a, b) in ((u, v), (v, u)):
                    d = edge_delay_ms(gc, tp, a, b, max(deg[a], 1), max(deg[b], 1))
                    nxt[b] = max(nxt[b], t[a] + d)
            t = nxt
        return max(t.values()) / rounds

    @property
    def num_matchings(self) -> int:
        return len(self.matchings)


def matcha_from_connectivity(gc: ConnectivityGraph, budget: float = 0.5) -> Matcha:
    """MATCHA over the symmetric pairs of a connectivity graph.

    Greedy-colors the undirected pair graph into matchings and allocates
    activation probabilities so the expected number of active matchings
    per round is ``budget * num_matchings``.  Returns a :class:`Matcha`
    sampler of per-round overlays."""
    pairs: List[Pair] = []
    seen: Set[frozenset] = set()
    for (i, j) in gc.latency_ms:
        k = frozenset((i, j))
        if i != j and k not in seen and gc.has_edge(j, i):
            seen.add(k)
            pairs.append((i, j))
    return Matcha.from_base_graph(pairs, budget)


def matcha_plus_from_underlay(underlay, budget: float = 0.5) -> Matcha:
    """MATCHA+: matchings computed on the *underlay* core graph."""
    return Matcha.from_base_graph(list(underlay.core_edges), budget)
