"""Vectorized, batched max-plus engine.

The legacy layer (:mod:`repro.core.maxplus`) models a delay digraph as a
``Dict[Edge, float]`` and runs Karp's algorithm in nested Python loops —
fine for one overlay, hopeless for a topology *search* that must score
thousands of candidates.  This module represents a delay digraph as a
dense ``[N, N]`` float matrix ``W`` with ``W[i, j] = d_o(i, j)`` and
``-inf`` where there is no edge, and evaluates whole batches ``[B, N, N]``
at once:

* ``batched_cycle_time``     — Karp's maximum cycle mean for every graph
                               in the batch, one ``np.max`` sweep per DP
                               level instead of a Python edge loop;
* ``batched_cycle_time_jax`` — the same DP as a jittable JAX function
                               (``lax.scan`` over DP levels) so candidate
                               scoring fuses into one XLA computation;
* ``reachability_closure`` / ``batched_is_strongly_connected`` —
                               boolean matrix-power transitive closure
                               (log₂N squarings);
* ``scc_labels``             — strongly-connected components via mutual
                               reachability for small N, iterative Tarjan
                               fallback for large N;
* ``timing_recursion_dense`` — the Eq. 4 max-plus recursion as an
                               ``[N]``-state vector update.

Karp on a batch
---------------

Karp's algorithm needs every vertex reachable from the source.  Rather
than decomposing into SCCs (data-dependent control flow — unbatchable),
we run the *multi-source* variant: ``D_0(v) = 0`` for every vertex, and
``D_k(v)`` is the max weight of a walk of exactly k arcs ending at v
from any start.  This is the classic super-source construction (a
virtual source with 0-weight arcs into every vertex, no incoming arcs —
creating no new circuit and making every circuit reachable) with the
source level peeled off, so the formula

    mu* = max_v min_{0<=k<N} ( D_N(v) - D_k(v) ) / (N - k)

is exact on the original N vertices.  Acyclic graphs yield
``D_N = -inf`` everywhere (an N-arc walk must repeat a vertex) and the
result is ``-inf``, matching the legacy convention.

The DP is one broadcast ``np.max`` sweep per level; batches are chunked
so a chunk's DP table stays cache-resident (~4x over the naive
whole-batch sweep at N=64, B=1024).
"""

from __future__ import annotations

import math
from typing import Dict, Hashable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..analysis.contracts import contract
# Host-level spans only: the obs-purity lint rule forbids obs use inside
# traced bodies (span clocks are host syncs); the disabled path is one
# module-global flag read per call.
from ..obs.spans import span_fn

Node = Hashable
Edge = Tuple[Node, Node]

NEG_INF = float("-inf")


@contract()
def missing_mask(x) -> np.ndarray:
    """Boolean mask of *absent* arcs: True where ``x`` carries the
    ``NEG_INF`` sentinel.

    The one sanctioned way to test for the sentinel.  Raw ``== NEG_INF``
    comparisons are flagged by ``scripts/lint_repro.py``: they read as a
    value test, and an f32 pipeline can *manufacture* -inf by overflow,
    at which point equality silently reclassifies a real arc as padding.
    Works on scalars and arrays alike (``np.isneginf``).
    """
    return np.isneginf(x)

# Above this vertex count the boolean matrix-power closure (O(N^3 log N)
# bits) loses to iterative Tarjan (O(N + E)).
_DENSE_SCC_THRESHOLD = 512

# Default cap on the D_k storage of one batched Karp chunk (float64).
_DEFAULT_DP_BYTES = 256 << 20

# Per-level working set (chunk * N * N * 8 bytes) targeted at L2/L3
# residency; measured optimum on CPU at N=64 is a 32-64 graph chunk.
_DP_CACHE_BYTES = 2 << 20


# ---------------------------------------------------------------------------
# Graph <-> matrix conversion


@contract(None, "#N", ret="[N,N]")
def edges_to_matrix(
    delays: Mapping[Edge, float], nodes: Sequence[Node]
) -> np.ndarray:
    """Dense ``[N, N]`` weight matrix with ``-inf`` holes from an edge dict."""
    index = {v: k for k, v in enumerate(nodes)}
    W = np.full((len(nodes), len(nodes)), NEG_INF, dtype=np.float64)
    for (i, j), w in delays.items():
        W[index[i], index[j]] = w
    return W


@contract()
def graph_to_matrix(graph) -> Tuple[np.ndarray, Tuple[Node, ...]]:
    """Convert a :class:`repro.core.maxplus.DelayDigraph` to (W, nodes)."""
    return edges_to_matrix(graph.delays, graph.nodes), tuple(graph.nodes)


# ---------------------------------------------------------------------------
# Batched Karp


@span_fn("engine.karp_dense")
@contract("[B,N,N]|[N,N]", ret="[B]|[]")
def batched_cycle_time(
    weights: np.ndarray,
    *,
    max_dp_bytes: int = _DEFAULT_DP_BYTES,
    chunk_graphs: Optional[int] = None,
    dtype: np.dtype = np.float64,
) -> np.ndarray:
    """Maximum cycle mean of every graph in a batch.

    Parameters
    ----------
    weights:
        ``[B, N, N]`` (or a single ``[N, N]``) array; ``weights[b, i, j]``
        is the arc weight i->j of graph b, ``-inf`` where there is no arc.
    max_dp_bytes:
        Hard cap on one chunk's DP storage (Karp's formula needs all
        levels ``D_0..D_N``).
    chunk_graphs:
        Explicit graphs-per-chunk override; by default sized so a level's
        working set stays cache-resident.
    dtype:
        ``np.float64`` (default) reproduces the legacy Python floats
        exactly; ``np.float32`` halves memory traffic — plenty for
        ranking candidate overlays whose delays are ms-scale
        measurements.

    Returns
    -------
    ``[B]`` array of max cycle means (``-inf`` for acyclic graphs); a
    scalar if the input was a single matrix.
    """
    dtype = np.dtype(dtype)
    W = np.asarray(weights, dtype=dtype)
    single = W.ndim == 2
    if single:
        W = W[None]
    if W.ndim != 3 or W.shape[-1] != W.shape[-2]:
        raise ValueError(f"expected [B, N, N] weights, got shape {W.shape}")
    B, N, _ = W.shape
    if N == 0:
        out = np.full(B, NEG_INF, dtype=dtype)
        return out[0] if single else out
    itemsize = dtype.itemsize
    if chunk_graphs is None:
        per_level = N * N * itemsize
        per_graph_dp = (N + 1) * N * itemsize
        chunk_graphs = min(
            max(1, _DP_CACHE_BYTES // max(per_level, 1)),
            max(1, max_dp_bytes // max(per_graph_dp, 1)),
        )
    chunk = max(1, min(B, chunk_graphs))
    out = np.empty(B, dtype=dtype)
    for lo in range(0, B, chunk):
        out[lo : lo + chunk] = _karp_chunk(W[lo : lo + chunk])
    return out[0] if single else out


def _karp_chunk(W: np.ndarray) -> np.ndarray:
    B, N, _ = W.shape
    # Multi-source DP: D[k][b, v] = max weight of a walk of exactly k
    # arcs ending at v (from any start vertex).
    D = np.empty((N + 1, B, N), dtype=W.dtype)
    D[0] = 0.0
    cur = D[0]
    for k in range(1, N + 1):
        # D_k[v] = max_u D_{k-1}[u] + W[u, v]  — one broadcast sweep.
        cur = np.max(cur[:, :, None] + W, axis=1)
        D[k] = cur
    return karp_from_levels(D)


@contract("[N+1,B,N]", ret="[B]")
def karp_from_levels(D: np.ndarray) -> np.ndarray:
    """Karp's formula from a precomputed multi-source DP table.

    ``D`` is ``[N+1, B, N]`` with ``D[k, b, v]`` the max weight of a walk
    of exactly k arcs ending at v in graph b (``D[0] == 0``).  Returns the
    ``[B]`` max cycle means.  Shared by the dense sweep above and the
    edge-list DP of :mod:`repro.core.maxplus_sparse` — the engines differ
    only in how they produce the levels.
    """
    Np1, B, N = D.shape
    assert Np1 == N + 1, f"expected [N+1, B, N] levels, got {D.shape}"
    Dn = D[N]  # [B, N]
    denom = (N - np.arange(N)).astype(D.dtype)  # [N]
    with np.errstate(invalid="ignore"):
        ratios = (Dn[None, :, :] - D[:N]) / denom[:, None, None]
    # D_k = -inf, D_N finite  -> ratio +inf (never the min): already so.
    # D_k = D_N = -inf        -> nan: neutralize to +inf.
    np.nan_to_num(ratios, copy=False, nan=np.inf)
    mins = np.min(ratios, axis=0)  # [B, N]
    # Vertices with no N-arc walk do not certify any cycle.
    mins = np.where(missing_mask(Dn), NEG_INF, mins)
    return np.max(mins, axis=1)


@contract("[N,N]")
def cycle_time_dense(W: np.ndarray) -> float:
    """Max cycle mean of a single dense weight matrix."""
    return float(batched_cycle_time(np.asarray(W, dtype=np.float64)))


@contract("[B,N,N]|[N,N]", ret="[_]")
def batched_throughput(weights: np.ndarray) -> np.ndarray:
    """1 / tau per graph (inf where tau <= 0 or the graph is acyclic)."""
    tau = np.atleast_1d(batched_cycle_time(weights))
    out = np.full_like(tau, np.inf)
    pos = tau > 0
    out[pos] = 1.0 / tau[pos]
    return out


# ---------------------------------------------------------------------------
# JAX variant


@contract("[B,N,N]", ret="[B]")
def batched_cycle_time_jax(weights):
    """Jittable JAX version of :func:`batched_cycle_time`.

    ``weights`` is ``[B, N, N]`` with ``-inf`` holes.  The DP levels run
    under ``lax.scan`` so a whole candidate batch lowers to one XLA
    computation (CPU/TPU).  Wrap in ``jax.jit`` at the call site to cache
    the compilation per (B, N).
    """
    import jax
    import jax.numpy as jnp

    W = jnp.asarray(weights)
    B, N, _ = W.shape
    neg = jnp.array(NEG_INF, dtype=W.dtype)
    D0 = jnp.zeros((B, N), dtype=W.dtype)  # multi-source level 0

    def step(cur, _):
        nxt = jnp.max(cur[:, :, None] + W, axis=1)
        return nxt, nxt

    _, levels = jax.lax.scan(step, D0, None, length=N)  # D_1..D_N
    Dn = levels[-1]
    allk = jnp.concatenate([D0[None], levels[:-1]], axis=0)  # D_0..D_{N-1}
    denom = (N - jnp.arange(N)).astype(W.dtype)
    ratios = (Dn[None, :, :] - allk) / denom[:, None, None]
    ratios = jnp.where(jnp.isnan(ratios), jnp.inf, ratios)
    mins = jnp.min(ratios, axis=0)
    mins = jnp.where(jnp.isneginf(Dn), neg, mins)
    return jnp.max(mins, axis=1)


# ---------------------------------------------------------------------------
# Reachability / SCC


@contract("[...,N,N]", ret="[...,N,N]")
def reachability_closure(adj: np.ndarray) -> np.ndarray:
    """Reflexive-transitive closure of boolean adjacency ``[..., N, N]``.

    Repeated boolean squaring: log2(N) matrix products instead of a
    per-vertex graph traversal, so it batches over leading dimensions.
    """
    A = np.asarray(adj, dtype=bool)
    N = A.shape[-1]
    R = A | np.eye(N, dtype=bool)
    hops = 1
    while hops < N:
        # R ∘ R in the boolean semiring.
        R = np.matmul(R, R)
        hops *= 2
    return R


@contract("[B,N,N]|[N,N]", ret="[B]|[]")
def batched_is_strongly_connected(weights: np.ndarray) -> np.ndarray:
    """``[B]`` bool: is each graph (arcs where weight > -inf) strong?

    Self-loops are ignored, matching the legacy Tarjan-based check.
    """
    W = np.asarray(weights)
    single = W.ndim == 2
    if single:
        W = W[None]
    adj = W > NEG_INF
    idx = np.arange(adj.shape[-1])
    adj = adj.copy()
    adj[:, idx, idx] = False
    R = reachability_closure(adj)
    ok = np.all(R & np.swapaxes(R, -1, -2), axis=(-1, -2))
    return ok[0] if single else ok


@contract("[N,N]", ret="[N]")
def scc_labels(adj: np.ndarray, *, dense_threshold: int = _DENSE_SCC_THRESHOLD) -> np.ndarray:
    """Component label per vertex (vertices share a label iff mutually
    reachable).  Matrix-power closure for small N, Tarjan for large N."""
    A = np.asarray(adj, dtype=bool)
    n = A.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if n <= dense_threshold:
        R = reachability_closure(A)
        mutual = R & R.T
        # Label = smallest mutually-reachable vertex index: identical for
        # every member of the SCC (mutual reachability is an equivalence).
        return np.argmax(mutual, axis=1).astype(np.int64)
    return _tarjan_labels(A)


def _tarjan_labels(A: np.ndarray) -> np.ndarray:
    n = A.shape[0]
    succ = [np.nonzero(A[v])[0] for v in range(n)]
    index = np.full(n, -1, dtype=np.int64)
    lowlink = np.zeros(n, dtype=np.int64)
    on_stack = np.zeros(n, dtype=bool)
    labels = np.full(n, -1, dtype=np.int64)
    stack: List[int] = []
    counter = 0
    ncomp = 0
    for root in range(n):
        if index[root] != -1:
            continue
        work: List[Tuple[int, int]] = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = lowlink[v] = counter
                counter += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            sv = succ[v]
            for i in range(pi, len(sv)):
                w = int(sv[i])
                if index[w] == -1:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    recurse = True
                    break
                if on_stack[w]:
                    lowlink[v] = min(lowlink[v], index[w])
            if recurse:
                continue
            if lowlink[v] == index[v]:
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    labels[w] = ncomp
                    if w == v:
                        break
                ncomp += 1
            work.pop()
            if work:
                parent = work[-1][0]
                lowlink[parent] = min(lowlink[parent], lowlink[v])
    return labels


# ---------------------------------------------------------------------------
# Timing recursion (Eq. 4) on dense state


@contract("[N,N]", "R", "*[N]", ret="[R+1,N]")
def timing_recursion_dense(
    W: np.ndarray, num_rounds: int, t0: Optional[np.ndarray] = None
) -> np.ndarray:
    """Evolve ``t(k+1) = W^T (x) t(k)`` (max-plus) for ``num_rounds`` rounds.

    ``W`` is ``[N, N]``; a missing self-loop acts as weight 0 (a silo with
    no modeled computation delay still observes its own previous start),
    matching the legacy dict recursion.  Returns ``[num_rounds + 1, N]``.
    """
    out = batched_timing_recursion(
        np.asarray(W, dtype=np.float64)[None],
        num_rounds,
        None if t0 is None else np.asarray(t0, dtype=np.float64)[None],
    )
    return out[0]


@contract("[B,N,N]", "R", "*[B,N]", ret="[B,R+1,N]")
def batched_timing_recursion(
    W: np.ndarray, num_rounds: int, t0: Optional[np.ndarray] = None
) -> np.ndarray:
    """Batched Eq. 4 recursion: ``[B, N, N]`` weights -> ``[B, R+1, N]``."""
    W = np.asarray(W, dtype=np.float64)
    B, N, _ = W.shape
    Weff = W.copy()
    idx = np.arange(N)
    diag = Weff[:, idx, idx]
    Weff[:, idx, idx] = np.where(missing_mask(diag), 0.0, diag)
    t = (np.zeros((B, N), dtype=np.float64) if t0 is None
         else np.asarray(t0, dtype=np.float64).copy())
    out = np.empty((B, num_rounds + 1, N), dtype=np.float64)
    out[:, 0] = t
    for k in range(num_rounds):
        # t_j(k+1) = max_i t_i(k) + W[i, j]
        t = np.max(t[:, :, None] + Weff, axis=1)
        out[:, k + 1] = t
    return out


@contract("[N,N]", "R")
def empirical_cycle_time_dense(W: np.ndarray, num_rounds: int = 200) -> float:
    """Estimate tau from the slope of the dense recursion tail."""
    t = timing_recursion_dense(W, num_rounds)
    warmup = num_rounds // 2
    return float(np.max((t[num_rounds] - t[warmup]) / (num_rounds - warmup)))


# ---------------------------------------------------------------------------
# Time-varying (piecewise-constant) timing recursion


def _epoch_of(starts: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Epoch index per entry of ``t``: the last epoch whose start <= t.

    ``starts`` is ``[E]`` (or ``[B, E]`` matching a leading batch dim of
    ``t``) of nondecreasing epoch start times with ``starts[..., 0]``
    covering t=0.
    """
    if starts.ndim == 1:
        e = np.searchsorted(starts, t, side="right") - 1
    else:
        # batched: one boolean reduction instead of a per-row searchsorted
        e = np.sum(starts[:, None, :] <= t[:, :, None], axis=-1) - 1
    return np.clip(e, 0, starts.shape[-1] - 1)


@contract("[E,N,N]", "[E]", "R", "*[N]", ret="[R+1,N]")
def timing_recursion_piecewise(
    Ws: np.ndarray,
    epoch_starts_ms: np.ndarray,
    num_rounds: int,
    t0: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Eq. 4 recursion under a piecewise-constant time-varying network.

    ``Ws`` is ``[E, N, N]``: one Eq. 3 delay matrix per network epoch,
    ``epoch_starts_ms`` the ``[E]`` nondecreasing epoch start instants
    (``epoch_starts_ms[0] <= 0``).  At round k, silo i transmits with the
    delays of the epoch containing its *start* time ``t_i(k)`` — rows of
    the effective delay matrix are gathered per silo, so silos straddling
    an event boundary see different network states within one round
    (exactly the straggler/failure transient the static recursion cannot
    express).  With a single epoch this reduces to
    :func:`timing_recursion_dense` bit-for-bit.

    Returns ``[num_rounds + 1, N]`` start times.
    """
    out = batched_timing_recursion_piecewise(
        np.asarray(Ws, dtype=np.float64)[None],
        np.asarray(epoch_starts_ms, dtype=np.float64)[None],
        num_rounds,
        None if t0 is None else np.asarray(t0, dtype=np.float64)[None],
    )
    return out[0]


@span_fn("engine.timing_piecewise")
@contract("[B,E,N,N]", "[B,E]", "R", "*[B,N]", ret="[B,R+1,N]")
def batched_timing_recursion_piecewise(
    Ws: np.ndarray,
    epoch_starts_ms: np.ndarray,
    num_rounds: int,
    t0: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Batched scenario form: ``[B, E, N, N]`` epochs -> ``[B, R+1, N]``.

    Each scenario b carries its own epoch matrices ``Ws[b]`` and epoch
    grid ``epoch_starts_ms[b]`` (``[B, E]``); scenarios advance in
    lockstep over rounds, which is what lets a whole sweep of candidate
    futures share one vectorized recursion.
    """
    Ws = np.asarray(Ws, dtype=np.float64)
    if Ws.ndim != 4 or Ws.shape[-1] != Ws.shape[-2]:
        raise ValueError(f"expected [B, E, N, N] epoch weights, got {Ws.shape}")
    B, E, N, _ = Ws.shape
    starts = np.asarray(epoch_starts_ms, dtype=np.float64)
    if starts.shape != (B, E):
        raise ValueError(f"epoch_starts_ms shape {starts.shape} != {(B, E)}")
    Weff = Ws.copy()
    idx = np.arange(N)
    diag = Weff[:, :, idx, idx]
    Weff[:, :, idx, idx] = np.where(missing_mask(diag), 0.0, diag)
    t = (np.zeros((B, N), dtype=np.float64) if t0 is None
         else np.asarray(t0, dtype=np.float64).copy())
    out = np.empty((B, num_rounds + 1, N), dtype=np.float64)
    out[:, 0] = t
    b_idx = np.arange(B)[:, None]
    for k in range(num_rounds):
        e = _epoch_of(starts, t)  # [B, N] epoch per *sender*
        Wk = Weff[b_idx, e, idx[None, :], :]  # gather rows -> [B, N, N]
        t = np.max(t[:, :, None] + Wk, axis=1)
        out[:, k + 1] = t
    return out


# ---------------------------------------------------------------------------
# Critical circuit (vectorized tight-subgraph extraction)


@contract("[N,N]")
def critical_circuit_dense(
    W: np.ndarray, *, tau: Optional[float] = None
) -> Tuple[float, List[int]]:
    """(tau, circuit) attaining the max cycle mean of a dense ``[N, N]``
    weight matrix; the circuit is a closed vertex-index walk
    ``[v0, ..., v0]`` (empty for acyclic graphs).

    Fully array-sweep based, replacing the legacy per-edge Bellman-Ford:
    longest-path potentials under the reduced weights ``W - tau`` converge
    in <= N max-plus matvec sweeps (every circuit has mean <= 0 after the
    reduction), the *tight* arcs ``pot[u] + w'(u,v) == pot[v]`` form one
    boolean matrix, and a vertex on a critical circuit is any diagonal hit
    of ``tight @ closure(tight)`` (a path of >= 1 tight arc back to
    itself).  Only the final circuit walk — output-sized — runs in Python.
    """
    W = np.asarray(W, dtype=np.float64)
    N = W.shape[0]
    if tau is None:
        tau = float(batched_cycle_time(W))
    if missing_mask(tau) or N == 0:
        return NEG_INF, []
    finite = W > NEG_INF
    with np.errstate(invalid="ignore"):
        Wr = np.where(finite, W - tau, NEG_INF)
    eps = 1e-9 * max(1.0, abs(tau))
    # Longest-path potentials from the all-zeros super-source.
    pot = np.zeros(N, dtype=np.float64)
    for _ in range(N):
        nxt = np.maximum(pot, np.max(pot[:, None] + Wr, axis=0))
        if np.all(nxt <= pot + eps):
            pot = nxt
            break
        pot = nxt
    tight = finite & (pot[:, None] + Wr >= pot[None, :] - 10 * eps)
    # Vertex on a critical circuit: closed tight walk of length >= 1.
    closure = reachability_closure(tight)
    on_cycle = np.diag(tight @ closure)
    hits = np.nonzero(on_cycle)[0]
    if hits.size == 0:  # numerically degenerate; caller falls back
        return tau, []
    v0 = int(hits[0])
    # Deterministic walk over tight arcs restricted to vertices that can
    # reach v0 tightly: every visited vertex has such a successor, so the
    # walk must revisit some vertex within N steps — and any closed tight
    # walk has reduced mean exactly 0, i.e. original mean exactly tau.
    back = closure[:, v0]
    pos = {v0: 0}
    walk = [v0]
    cur = v0
    while True:
        succ = np.nonzero(tight[cur] & back)[0]
        assert succ.size, "tight subgraph lost the certified circuit"
        cur = int(succ[0])
        if cur in pos:
            return tau, walk[pos[cur] :] + [cur]
        pos[cur] = len(walk)
        walk.append(cur)
