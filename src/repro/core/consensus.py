"""Consensus matrices for DPASGD (Eq. 2, Appendix G.3).

The *local-degree rule* ([62], Eq. 22-23 of the paper):

    A_ij = 1 / (1 + max(|N_i^-|, |N_j^-|))   for (i,j) in E_o
    A_ii = 1 - sum_j A_ij

which is symmetric doubly stochastic on undirected overlays.  For the
directed RING the optimal consensus matrix has all non-zero entries equal
to 1/2 (Appendix H.4): A = (I + P)/2 with P the ring permutation.
"""

from __future__ import annotations

from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np

Node = Hashable
Edge = Tuple[Node, Node]


def _degrees(n: int, edges: Sequence[Tuple[int, int]]) -> np.ndarray:
    deg = np.zeros(n, dtype=np.int64)
    for (i, j) in edges:
        if i != j:
            deg[j] += 1  # in-degree |N_j^+| == |N_j^-| on undirected overlays
    return deg


def local_degree_matrix(n: int, edges: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Consensus matrix from the local-degree rule.

    ``edges`` are directed (i, j) pairs meaning i sends to j; for an
    undirected overlay both directions must be present.
    """
    deg = _degrees(n, edges)
    A = np.zeros((n, n), dtype=np.float64)
    for (i, j) in edges:
        if i == j:
            continue
        A[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    for i in range(n):
        A[i, i] = 1.0 - A[i].sum()
    return A


def ring_matrix(n: int, tour: Sequence[int]) -> np.ndarray:
    """A = (I + P)/2 for the directed ring defined by ``tour``."""
    A = 0.5 * np.eye(n)
    for k in range(n):
        i, j = tour[k], tour[(k + 1) % n]
        A[j, i] += 0.5
    return A


def metropolis_matrix(n: int, edges: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Metropolis-Hastings weights (alternative to local-degree)."""
    deg = _degrees(n, edges)
    A = np.zeros((n, n), dtype=np.float64)
    for (i, j) in edges:
        if i == j:
            continue
        A[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    A = np.maximum(A, A.T)  # symmetrize support
    for i in range(n):
        A[i, i] = 1.0 - A[i].sum()
    return A


def is_doubly_stochastic(A: np.ndarray, tol: float = 1e-9) -> bool:
    """True iff ``A`` ([n, n]) is nonnegative with unit row and column
    sums — the precondition for the Birkhoff decomposition."""
    return (
        bool((A >= -tol).all())
        and bool(np.allclose(A.sum(axis=0), 1.0, atol=1e-8))
        and bool(np.allclose(A.sum(axis=1), 1.0, atol=1e-8))
    )


def spectral_gap(A: np.ndarray) -> float:
    """1 - second largest singular value of A - (1/n) 11^T — governs the
    per-round consensus contraction (classic worst-case bound)."""
    n = A.shape[0]
    M = A - np.full((n, n), 1.0 / n)
    s = np.linalg.svd(M, compute_uv=False)
    return float(1.0 - s[0])


def star_matrix(n: int, center: int) -> np.ndarray:
    """FedAvg-style star: one round of leaf->center averaging followed by
    broadcast equals the rank-one averaging matrix."""
    return np.full((n, n), 1.0 / n)
