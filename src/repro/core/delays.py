"""Delay model of the paper (Eq. 3).

For an overlay edge (i, j):

    d_o(i,j) = s*T_c(i) + l(i,j) + M / min( C_UP(i)/|N_i^-|,
                                            C_DN(j)/|N_j^+|,
                                            A(i',j') )

and d_o(i,i) = s*T_c(i).  All times in milliseconds, capacities in
megabits/ms (== Gbit/s), model size M in megabits.

A network is *edge-capacitated* when access-link sharing can be neglected
(the min is attained by A(i',j')); otherwise *node-capacitated*.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..analysis.contracts import contract
from ..obs.spans import span_fn
from .maxplus import DelayDigraph
from .maxplus_vec import NEG_INF

Node = Hashable
Edge = Tuple[Node, Node]


@dataclass(frozen=True)
class SiloParams:
    """Per-silo measurable characteristics."""

    comp_time_ms: float  # T_c(i): one local update
    uplink_gbps: float  # C_UP(i)
    downlink_gbps: float  # C_DN(i)


@dataclass(frozen=True)
class ConnectivityGraph:
    """The connectivity graph G_c with measurable per-pair characteristics.

    ``latency_ms[(i,j)]`` is the end-to-end delay l(i,j) and
    ``available_bw_gbps[(i,j)]`` the available bandwidth A(i',j') of the
    underlay path between the access routers of i and j.
    """

    silos: Tuple[Node, ...]
    latency_ms: Mapping[Edge, float]
    available_bw_gbps: Mapping[Edge, float]
    silo_params: Mapping[Node, SiloParams]

    def edges(self):
        return list(self.latency_ms.keys())

    @property
    def num_silos(self) -> int:
        return len(self.silos)

    def has_edge(self, i: Node, j: Node) -> bool:
        return (i, j) in self.latency_ms

    def is_symmetric(self) -> bool:
        return all((j, i) in self.latency_ms for (i, j) in self.latency_ms)


@dataclass(frozen=True)
class TrainingParams:
    """Workload parameters entering the delay model."""

    model_size_mbits: float  # M
    local_steps: int = 1  # s


@contract()
def effective_rate_gbps(
    gc: ConnectivityGraph,
    i: Node,
    j: Node,
    out_degree_i: int,
    in_degree_j: int,
) -> float:
    """min(C_UP(i)/|N_i^-|, C_DN(j)/|N_j^+|, A(i',j'))."""
    up = gc.silo_params[i].uplink_gbps / max(out_degree_i, 1)
    dn = gc.silo_params[j].downlink_gbps / max(in_degree_j, 1)
    return min(up, dn, gc.available_bw_gbps[(i, j)])


@contract()
def edge_delay_ms(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    i: Node,
    j: Node,
    out_degree_i: int,
    in_degree_j: int,
) -> float:
    """d_o(i, j) per Eq. 3 (times in ms; 1 Gbps == 1 Mbit/ms)."""
    rate = effective_rate_gbps(gc, i, j, out_degree_i, in_degree_j)
    return (
        tp.local_steps * gc.silo_params[i].comp_time_ms
        + gc.latency_ms[(i, j)]
        + tp.model_size_mbits / rate
    )


@contract()
def connectivity_delay_ms(gc: ConnectivityGraph, tp: TrainingParams, i: Node, j: Node) -> float:
    """d_c(i,j) = s*T_c(i) + l(i,j) + M/A(i',j') — the *edge-capacitated*
    delay used to weigh the connectivity graph for topology design."""
    return (
        tp.local_steps * gc.silo_params[i].comp_time_ms
        + gc.latency_ms[(i, j)]
        + tp.model_size_mbits / gc.available_bw_gbps[(i, j)]
    )


@contract()
def symmetrized_delay_ms(gc: ConnectivityGraph, tp: TrainingParams, i: Node, j: Node) -> float:
    """d_c^(u)(i,j) = (d_c(i,j) + d_c(j,i)) / 2 (Prop. 3.1)."""
    return 0.5 * (connectivity_delay_ms(gc, tp, i, j) + connectivity_delay_ms(gc, tp, j, i))


@contract()
def node_capacitated_sym_delay_ms(
    gc: ConnectivityGraph, tp: TrainingParams, i: Node, j: Node
) -> float:
    """The symmetric weight used by Algorithm 1 (lines 1-3):

    [ s*(T_c(i)+T_c(j)) + l(i,j) + l(j,i) + M/C_UP(i) + M/C_UP(j) ] / 2
    """
    pi, pj = gc.silo_params[i], gc.silo_params[j]
    return 0.5 * (
        tp.local_steps * (pi.comp_time_ms + pj.comp_time_ms)
        + gc.latency_ms[(i, j)]
        + gc.latency_ms[(j, i)]
        + tp.model_size_mbits / pi.uplink_gbps
        + tp.model_size_mbits / pj.uplink_gbps
    )


@contract()
def overlay_delay_digraph(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlay_edges,
) -> DelayDigraph:
    """Build the full delay digraph of an overlay (directed edge list),
    applying the degree-dependent access-link sharing of Eq. 3 and adding
    the self-loop computation delays d_o(i,i) = s*T_c(i)."""
    overlay_edges = list(overlay_edges)
    out_deg: Dict[Node, int] = {v: 0 for v in gc.silos}
    in_deg: Dict[Node, int] = {v: 0 for v in gc.silos}
    for (i, j) in overlay_edges:
        if i == j:
            continue
        out_deg[i] += 1
        in_deg[j] += 1
    delays: Dict[Edge, float] = {}
    for (i, j) in overlay_edges:
        if i == j:
            continue
        if not gc.has_edge(i, j):
            raise ValueError(f"overlay edge {(i, j)} not in connectivity graph")
        delays[(i, j)] = edge_delay_ms(gc, tp, i, j, out_deg[i], in_deg[j])
    for v in gc.silos:
        delays[(v, v)] = tp.local_steps * gc.silo_params[v].comp_time_ms
    return DelayDigraph(tuple(gc.silos), delays)


@contract(ret="[N,N]")
def overlay_delay_matrix(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlay_edges,
) -> np.ndarray:
    """Dense ``[N, N]`` Eq. 3 delay matrix of one overlay (``-inf`` holes).

    Row/column order follows ``gc.silos``; diagonal carries the self-loop
    computation delays ``d_o(i, i) = s * T_c(i)``.  This is the matrix
    form consumed by :mod:`repro.core.maxplus_vec`.
    """
    arcs = [e for e in overlay_edges if e[0] != e[1]]
    for (i, j) in arcs:
        if not gc.has_edge(i, j):
            raise ValueError(f"overlay edge {(i, j)} not in connectivity graph")
    masks = np.ones((1, len(arcs)), dtype=bool)
    return batched_overlay_delay_matrices(gc, tp, arcs, masks)[0]


@span_fn("engine.price_matrices")
@contract(None, None, "#E", "[B,E]", ret="[B,N,N]")
def batched_overlay_delay_matrices(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    arcs: Sequence[Edge],
    masks: np.ndarray,
) -> np.ndarray:
    """Eq. 3 delay matrices for a batch of candidate overlays at once.

    ``arcs`` is the pool of distinct directed silo pairs and ``masks`` a
    ``[B, E]`` boolean selection (candidate b uses arc e iff
    ``masks[b, e]``).  Degrees — and therefore the access-link sharing
    term of Eq. 3 — are recomputed per candidate, fully vectorized.
    Returns ``[B, N, N]`` with ``-inf`` holes and self-loop diagonals.
    """
    n = gc.num_silos
    index = {v: k for k, v in enumerate(gc.silos)}
    masks = np.asarray(masks, dtype=bool)
    B, E = masks.shape
    if E != len(arcs):
        raise ValueError(f"masks last dim {E} != number of arcs {len(arcs)}")
    comp = np.array(
        [tp.local_steps * gc.silo_params[v].comp_time_ms for v in gc.silos]
    )
    W = np.full((B, n, n), NEG_INF, dtype=np.float64)
    idx = np.arange(n)
    W[:, idx, idx] = comp[None, :]
    if E == 0:
        return W
    src = np.array([index[i] for (i, _) in arcs])
    dst = np.array([index[j] for (_, j) in arcs])
    if np.any(src == dst):
        raise ValueError("arc pool must not contain self-loops")
    lat = np.array([gc.latency_ms[(i, j)] for (i, j) in arcs])
    bwa = np.array([gc.available_bw_gbps[(i, j)] for (i, j) in arcs])
    up = np.array([gc.silo_params[v].uplink_gbps for v in gc.silos])
    dn = np.array([gc.silo_params[v].downlink_gbps for v in gc.silos])
    # Per-candidate degrees: one matmul against arc-endpoint one-hots
    # (cast first: numpy's bool-times-float matmul path is far slower).
    eye = np.eye(n)
    maskf = masks.astype(np.float64)
    out_deg = maskf @ eye[src]  # [B, N]
    in_deg = maskf @ eye[dst]
    rate = np.minimum(
        up[src][None, :] / np.maximum(out_deg[:, src], 1.0),
        dn[dst][None, :] / np.maximum(in_deg[:, dst], 1.0),
    )
    rate = np.minimum(rate, bwa[None, :])
    delay = comp[src][None, :] + lat[None, :] + tp.model_size_mbits / rate
    W[:, src, dst] = np.where(masks, delay, NEG_INF)
    return W


@contract()
def is_edge_capacitated(gc: ConnectivityGraph) -> bool:
    """Sufficient condition from Sect. 3.1:
    min(C_UP(i), C_DN(j)) / N >= A(i',j') for every connectivity edge."""
    n = gc.num_silos
    for (i, j) in gc.latency_ms:
        if i == j:
            continue
        up = gc.silo_params[i].uplink_gbps
        dn = gc.silo_params[j].downlink_gbps
        if min(up, dn) / n < gc.available_bw_gbps[(i, j)]:
            return False
    return True
