"""Topology design algorithms for the Minimal Cycle Time (MCT) problem.

Implements every overlay of Table 1 / Table 3:

* ``star_overlay``        — server-client baseline (orchestrator at the
                            load-centrality center);
* ``mst_overlay``         — Prim MST on the symmetrized connectivity graph:
                            *optimal* for edge-capacitated undirected
                            overlays (Prop. 3.1);
* ``ring_overlay``        — directed ring from Christofides' TSP algorithm:
                            3N-approximation on Euclidean graphs
                            (Prop. 3.3 / 3.6);
* ``delta_prim``          — degree-bounded Prim (Algorithm 2, [2]);
* ``delta_mbst_overlay``  — Algorithm 1 (Appendix D): 2-MBST via MST-cube
                            Hamiltonian path + δ-PRIM sweep, picking the
                            candidate with minimal cycle time:
                            6-approximation on node-capacitated Euclidean
                            graphs (Prop. 3.5);
* ``brute_force_mct``     — exact solver (exponential; used by tests to
                            certify optimality/approximation claims on
                            small instances).

Beyond the paper, ``search_overlays_jit`` runs a batched rewire hill
climb *on device*: candidates are generated as local arc edits of an
incumbent overlay and scored by the sparse jitted max-plus engine
(:mod:`repro.core.maxplus_sparse`) inside one ``lax.fori_loop`` — the
search path that scales past the dense engine's N~1k wall.

An *overlay* is returned as a list of **directed** edges; undirected
topologies contain both directions of every link.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, Hashable, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .delays import (
    ConnectivityGraph,
    TrainingParams,
    batched_overlay_delay_matrices,
    node_capacitated_sym_delay_ms,
    overlay_delay_matrix,
    symmetrized_delay_ms,
)
from .maxplus_vec import (
    batched_cycle_time,
    batched_is_strongly_connected,
    cycle_time_dense,
)
from .maxplus_sparse import (
    batched_cycle_time_sparse,
    batched_is_strongly_connected_sparse,
    batched_overlay_delay_edges,
)
from ..obs.spans import span_fn

Node = Hashable
Edge = Tuple[Node, Node]


@dataclass(frozen=True)
class Overlay:
    """A designed overlay with its realized cycle time."""

    name: str
    edges: Tuple[Edge, ...]  # directed
    cycle_time_ms: float

    @property
    def undirected_edges(self) -> Set[FrozenSet[Node]]:
        return {frozenset(e) for e in self.edges}

    def out_degree(self, v: Node) -> int:
        return sum(1 for (i, _) in self.edges if i == v)

    def in_degree(self, v: Node) -> int:
        return sum(1 for (_, j) in self.edges if j == v)


def evaluate_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, edges: Sequence[Edge], name: str = "custom"
) -> Overlay:
    """Price a directed edge list with Eq. 3 and return it as an
    :class:`Overlay` with its exact (f64 dense-engine) cycle time.
    Raises ``ValueError`` if the edges do not form a strongly-connected
    digraph over ``gc.silos``."""
    W = overlay_delay_matrix(gc, tp, edges)
    if not batched_is_strongly_connected(W):
        raise ValueError(f"overlay {name!r} is not strongly connected")
    return Overlay(name=name, edges=tuple(edges), cycle_time_ms=cycle_time_dense(W))


def _sym_edges(gc: ConnectivityGraph) -> List[Tuple[Node, Node]]:
    """Unordered silo pairs present in both directions (G_c^(u))."""
    out = []
    seen = set()
    for (i, j) in gc.latency_ms:
        key = frozenset((i, j))
        if key in seen or i == j:
            continue
        if gc.has_edge(j, i):
            seen.add(key)
            out.append((i, j))
    return out


def _bidir(edges: Sequence[Tuple[Node, Node]]) -> List[Edge]:
    out: List[Edge] = []
    for (i, j) in edges:
        out.append((i, j))
        out.append((j, i))
    return out


# ---------------------------------------------------------------------------
# STAR (server-client baseline)


def star_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, center: Optional[Node] = None
) -> Overlay:
    """Server-client (FedAvg) baseline.

    One communication round is *two-phase* (Appendix B): every silo uploads
    to the orchestrator, which aggregates and pushes the refined model back.
    The orchestrator performs no local training (its loss is constant), so

        tau_STAR = max_l [ s*T_c(l) + l(l,c) + M/min(C_UP(l), C_DN(c)/N, A) ]
                 + max_l [           l(c,l) + M/min(C_UP(c)/N, C_DN(l), A) ]

    which recovers Appendix B's 2N*M/C in the slow-homogeneous-access-link
    regime.  (The generic max-plus circuit mean would halve this because a
    FedAvg round spans two ticks of the recursion.)
    """
    if center is None:
        # Highest-closeness silo in latency space when no underlay info.
        def closeness(v: Node) -> float:
            return sum(gc.latency_ms[(v, u)] for u in gc.silos if u != v)

        center = min(gc.silos, key=closeness)
    leaves = [v for v in gc.silos if v != center]
    n = len(leaves)
    cp = gc.silo_params[center]
    up_phase = 0.0
    dn_phase = 0.0
    for l in leaves:
        lp = gc.silo_params[l]
        up_rate = min(lp.uplink_gbps, cp.downlink_gbps / n, gc.available_bw_gbps[(l, center)])
        dn_rate = min(cp.uplink_gbps / n, lp.downlink_gbps, gc.available_bw_gbps[(center, l)])
        up_phase = max(
            up_phase,
            tp.local_steps * lp.comp_time_ms
            + gc.latency_ms[(l, center)]
            + tp.model_size_mbits / up_rate,
        )
        dn_phase = max(
            dn_phase, gc.latency_ms[(center, l)] + tp.model_size_mbits / dn_rate
        )
    edges = []
    for v in leaves:
        edges.append((center, v))
        edges.append((v, center))
    return Overlay(name="star", edges=tuple(edges), cycle_time_ms=up_phase + dn_phase)


# ---------------------------------------------------------------------------
# MST (Prop. 3.1) — Prim's algorithm on the symmetrized delays


def mst_edges(
    gc: ConnectivityGraph,
    weight: Callable[[Node, Node], float],
) -> List[Tuple[Node, Node]]:
    """Prim MST over G_c^(u) with the given symmetric weight."""
    pairs = _sym_edges(gc)
    adj: Dict[Node, List[Tuple[Node, float]]] = {v: [] for v in gc.silos}
    for (i, j) in pairs:
        w = weight(i, j)
        adj[i].append((j, w))
        adj[j].append((i, w))
    import heapq

    start = gc.silos[0]
    visited = {start}
    pq: List[Tuple[float, int, Node, Node]] = []
    tiebreak = itertools.count()
    for (v, w) in adj[start]:
        heapq.heappush(pq, (w, next(tiebreak), start, v))
    tree: List[Tuple[Node, Node]] = []
    while pq and len(visited) < len(gc.silos):
        w, _, u, v = heapq.heappop(pq)
        if v in visited:
            continue
        visited.add(v)
        tree.append((u, v))
        for (x, wx) in adj[v]:
            if x not in visited:
                heapq.heappush(pq, (wx, next(tiebreak), v, x))
    if len(visited) != len(gc.silos):
        raise ValueError("connectivity graph (symmetrized) is not connected")
    return tree


def mst_overlay(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """MST on the symmetrized connectivity delays, both arc directions
    kept — optimal among undirected overlays on edge-capacitated
    networks (Prop. 3.1)."""
    tree = mst_edges(gc, lambda i, j: symmetrized_delay_ms(gc, tp, i, j))
    ov = evaluate_overlay(gc, tp, _bidir(tree), name="mst")
    return ov


# ---------------------------------------------------------------------------
# RING via Christofides (Prop. 3.3 / 3.6)


def christofides_tour(nodes: Sequence[Node], weight: Callable[[Node, Node], float]) -> List[Node]:
    """Christofides' 1.5-approximation for metric TSP.

    MST + minimum-weight perfect matching on odd-degree vertices (greedy
    matching — keeps the classical guarantee structure; exact blossom is
    overkill at N<=100 and greedy is the standard engineering choice) +
    Eulerian circuit + shortcutting.
    """
    nodes = list(nodes)
    n = len(nodes)
    if n == 1:
        return nodes
    if n == 2:
        return nodes
    # MST (Prim, dense)
    in_tree = [False] * n
    best = [math.inf] * n
    best_to = [-1] * n
    in_tree[0] = True
    for j in range(1, n):
        best[j] = weight(nodes[0], nodes[j])
        best_to[j] = 0
    mst_adj: Dict[int, List[int]] = {i: [] for i in range(n)}
    for _ in range(n - 1):
        v = min((j for j in range(n) if not in_tree[j]), key=lambda j: best[j])
        mst_adj[v].append(best_to[v])
        mst_adj[best_to[v]].append(v)
        in_tree[v] = True
        for j in range(n):
            if not in_tree[j]:
                w = weight(nodes[v], nodes[j])
                if w < best[j]:
                    best[j] = w
                    best_to[j] = v
    # Odd-degree vertices -> greedy min-weight perfect matching
    odd = [v for v in range(n) if len(mst_adj[v]) % 2 == 1]
    pairs = sorted(
        ((weight(nodes[a], nodes[b]), a, b) for k, a in enumerate(odd) for b in odd[k + 1 :]),
    )
    matched: Set[int] = set()
    for (_, a, b) in pairs:
        if a not in matched and b not in matched:
            matched.add(a)
            matched.add(b)
            mst_adj[a].append(b)
            mst_adj[b].append(a)
    # Eulerian circuit (Hierholzer) on the multigraph
    adj_copy: Dict[int, List[int]] = {v: list(ns) for v, ns in mst_adj.items()}
    stack = [0]
    circuit: List[int] = []
    while stack:
        v = stack[-1]
        if adj_copy[v]:
            u = adj_copy[v].pop()
            adj_copy[u].remove(v)
            stack.append(u)
        else:
            circuit.append(stack.pop())
    # Shortcut repeated vertices
    seen: Set[int] = set()
    tour: List[int] = []
    for v in circuit:
        if v not in seen:
            seen.add(v)
            tour.append(v)
    return [nodes[v] for v in tour]


def ring_overlay(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """Directed ring from Christofides on the symmetrized connectivity
    delays (the paper's RING, Prop. 3.3/3.6)."""
    tour = christofides_tour(
        list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    )
    edges = [(tour[k], tour[(k + 1) % len(tour)]) for k in range(len(tour))]
    return evaluate_overlay(gc, tp, edges, name="ring")


def two_opt_ring_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, max_rounds: int = 20
) -> Overlay:
    """Beyond-paper: Christofides tour refined with 2-opt on symmetrized
    delays, then evaluated with the true (node-capacitated) cycle time."""
    tour = christofides_tour(
        list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    )
    w = lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    n = len(tour)
    improved = True
    rounds = 0
    while improved and rounds < max_rounds:
        improved = False
        rounds += 1
        for a in range(n - 1):
            for b in range(a + 2, n - (1 if a == 0 else 0)):
                i, inext = tour[a], tour[a + 1]
                j, jnext = tour[b], tour[(b + 1) % n]
                delta = (w(i, j) + w(inext, jnext)) - (w(i, inext) + w(j, jnext))
                if delta < -1e-9:
                    tour[a + 1 : b + 1] = reversed(tour[a + 1 : b + 1])
                    improved = True
    edges = [(tour[k], tour[(k + 1) % n]) for k in range(n)]
    return evaluate_overlay(gc, tp, edges, name="ring_2opt")


# ---------------------------------------------------------------------------
# δ-PRIM (Algorithm 2) and Algorithm 1 (δ-MBST, Prop. 3.5)


def delta_prim(
    gc: ConnectivityGraph,
    weight: Callable[[Node, Node], float],
    delta: int,
) -> List[Tuple[Node, Node]]:
    """Degree-bounded Prim: grow a tree always picking the smallest-weight
    edge whose tree endpoint has degree < delta (Algorithm 2, [2])."""
    nodes = list(gc.silos)
    pairs = _sym_edges(gc)
    wmap: Dict[FrozenSet[Node], float] = {frozenset(p): weight(*p) for p in pairs}
    in_tree: Set[Node] = {nodes[0]}
    degree: Dict[Node, int] = {v: 0 for v in nodes}
    tree: List[Tuple[Node, Node]] = []
    while len(in_tree) < len(nodes):
        cand: Optional[Tuple[float, Node, Node]] = None
        for u in in_tree:
            if degree[u] >= delta:
                continue
            for v in nodes:
                if v in in_tree:
                    continue
                key = frozenset((u, v))
                if key not in wmap:
                    continue
                w = wmap[key]
                if cand is None or w < cand[0]:
                    cand = (w, u, v)
        if cand is None:
            raise ValueError(f"delta-PRIM stuck: no degree-<{delta} expansion edge")
        _, u, v = cand
        tree.append((u, v))
        degree[u] += 1
        degree[v] += 1
        in_tree.add(v)
    return tree


def _cube_hamiltonian_path(tree_adj: Dict[Node, List[Node]], root: Node) -> List[Node]:
    """Hamiltonian path in the cube of a tree via a pre-order DFS walk.

    A DFS pre-order of a tree visits consecutive vertices at tree distance
    <= 3 when children subtrees are walked contiguously — the classical
    construction behind Karaganis' theorem [43] used by [3, Sect. 3.2.1].
    """
    order: List[Node] = []
    stack: List[Node] = [root]
    seen: Set[Node] = set()
    while stack:
        v = stack.pop()
        if v in seen:
            continue
        seen.add(v)
        order.append(v)
        for u in reversed(tree_adj[v]):
            if u not in seen:
                stack.append(u)
    return order


def algorithm1_mbst(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """Algorithm 1 (Appendix D): candidates = {Hamiltonian path in MST^3}
    ∪ {δ-PRIM trees, δ=3..N}; return the candidate with the smallest
    *true* cycle time (node-capacitated Eq. 3 evaluation)."""
    weight = lambda i, j: node_capacitated_sym_delay_ms(gc, tp, i, j)
    candidates: List[Tuple[str, List[Tuple[Node, Node]]]] = []
    # 2-MBST approximation: Hamiltonian path in the cube of the MST.
    mst = mst_edges(gc, weight)
    adj: Dict[Node, List[Node]] = {v: [] for v in gc.silos}
    for (u, v) in mst:
        adj[u].append(v)
        adj[v].append(u)
    ham = _cube_hamiltonian_path(adj, gc.silos[0])
    path_edges = list(zip(ham[:-1], ham[1:]))
    # The cube path may use pairs missing from G_c^(u) if it is not complete;
    # only keep the candidate if all pairs exist.
    if all(gc.has_edge(i, j) and gc.has_edge(j, i) for (i, j) in path_edges):
        candidates.append(("2mbst_path", path_edges))
    for delta in range(3, gc.num_silos):
        try:
            candidates.append((f"{delta}-prim", delta_prim(gc, weight, delta)))
        except ValueError:
            continue
    # Score every candidate in one batched engine call.
    cand_edges = [_bidir(tree) for (_, tree) in candidates]
    W = np.stack([overlay_delay_matrix(gc, tp, e) for e in cand_edges])
    strong = batched_is_strongly_connected(W)
    taus = np.where(strong, batched_cycle_time(W), np.inf)
    k = int(np.argmin(taus))
    if not np.isfinite(taus[k]):
        raise ValueError("no strongly-connected delta-MBST candidate")
    return Overlay(
        name="delta_mbst", edges=tuple(cand_edges[k]), cycle_time_ms=float(taus[k])
    )


# ---------------------------------------------------------------------------
# Exact solver (for tests on small instances)


def _best_masked_candidate(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    arcs: List[Edge],
    subsets: Iterable[Tuple[int, ...]],
    best_tau: float,
    best_rows: Optional[List[int]],
    *,
    batch: int = 4096,
) -> Tuple[float, Optional[List[int]]]:
    """Scan candidate arc-index subsets in batched engine calls.

    Returns the best (cycle time, arc-index list) seen, seeded with the
    incoming incumbent.  Non-strongly-connected candidates are skipped.
    """
    E = len(arcs)
    buf: List[Tuple[int, ...]] = []

    def flush() -> Tuple[float, Optional[List[int]]]:
        nonlocal best_tau, best_rows
        masks = np.zeros((len(buf), E), dtype=bool)
        for k, subset in enumerate(buf):
            masks[k, list(subset)] = True
        W = batched_overlay_delay_matrices(gc, tp, arcs, masks)
        strong = np.nonzero(batched_is_strongly_connected(W))[0]
        if strong.size:
            taus = batched_cycle_time(W[strong])
            k = int(np.argmin(taus))
            if taus[k] < best_tau:
                best_tau = float(taus[k])
                best_rows = list(buf[int(strong[k])])
        buf.clear()
        return best_tau, best_rows

    for subset in subsets:
        buf.append(subset)
        if len(buf) >= batch:
            best_tau, best_rows = flush()
    if buf:
        best_tau, best_rows = flush()
    return best_tau, best_rows


def brute_force_mct(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    undirected: bool = False,
    max_nodes: int = 7,
    exhaustive: bool = True,
    batch: int = 4096,
) -> Overlay:
    """Exact MCT solver by enumeration (exponential — tests/small N only).

    Candidates are scored through the batched max-plus engine, thousands
    of overlays per call.  With ``exhaustive=True`` (default) every arc
    count is enumerated, which is required for a *certificate* of
    optimality: minimally strong digraphs can have up to 2(N-1) arcs
    (e.g. bidirected trees), so the legacy heuristic cut at ``r >= N + 2``
    arcs could return a suboptimal overlay.  Pass ``exhaustive=False`` to
    re-enable that cut as a cheap heuristic.
    """
    n = gc.num_silos
    if n > max_nodes:
        raise ValueError("brute force limited to tiny instances")
    best_tau = math.inf
    best_rows: Optional[List[int]] = None
    if undirected:
        pairs = _sym_edges(gc)
        arcs = _bidir(pairs)  # pair p -> arc rows 2p, 2p+1
        for r in range(n - 1, len(pairs) + 1):
            subsets = (
                tuple(a for p in combo for a in (2 * p, 2 * p + 1))
                for combo in itertools.combinations(range(len(pairs)), r)
            )
            best_tau, best_rows = _best_masked_candidate(
                gc, tp, arcs, subsets, best_tau, best_rows, batch=batch
            )
        assert best_rows is not None
        edges = tuple(arcs[a] for a in best_rows)
        return Overlay(name="bf", edges=edges, cycle_time_ms=best_tau)
    arcs = [e for e in gc.edges() if e[0] != e[1]]
    # Prune: a strong digraph needs >= n arcs.
    for r in range(n, len(arcs) + 1):
        best_tau, best_rows = _best_masked_candidate(
            gc,
            tp,
            arcs,
            itertools.combinations(range(len(arcs)), r),
            best_tau,
            best_rows,
            batch=batch,
        )
        if not exhaustive and best_rows is not None and r >= n + 2:
            break  # heuristic cut: may miss optima that need many arcs
    assert best_rows is not None
    edges = tuple(arcs[a] for a in best_rows)
    return Overlay(name="bf", edges=edges, cycle_time_ms=best_tau)


# ---------------------------------------------------------------------------
# Device-resident topology search (sparse engine + jitted rewire hill climb)

# Lazily-built jitted climb, cached per process; jax recompiles per
# distinct (B, S, N, n_steps, delta_max) shape tuple and caches after.
_REWIRE_JIT: Dict[str, object] = {}


def _build_rewire_climb():
    import jax
    import jax.numpy as jnp

    from .maxplus_sparse import batched_cycle_time_sparse_jax

    INF = jnp.inf

    def climb(lat, bw, allowed, comp, up, dn, model_mbits,
              asrc, adst, aact, key, n_steps, delta_max):
        """Batched hill climb over arc-slot states.

        ``asrc/adst/aact`` are ``[B, S]`` arc slots per restart; each step
        proposes one local move (endpoint swap / arc add / arc drop) per
        restart, scores the proposal with the sparse jitted Karp, and
        accepts improvements.  Entirely device-side: one XLA computation
        for the whole search.
        """
        B, S = asrc.shape
        n = lat.shape[0]
        boff = jnp.arange(B, dtype=jnp.int32)[:, None] * n
        rows = jnp.arange(B)
        sl = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        comp_sl = jnp.broadcast_to(comp, (B, n))
        slot_ids = jnp.arange(S, dtype=jnp.int32)

        def reach_all(take_idx, seg_src, present):
            # frontier propagation from vertex 0 along present arcs
            r0 = jnp.zeros((B, n), dtype=lat.dtype).at[:, 0].set(1.0)

            def body(_, r):
                vals = jnp.take_along_axis(r, take_idx, axis=1) * present
                hop = jax.ops.segment_max(
                    vals.ravel(), seg_src, num_segments=B * n
                ).reshape(B, n)
                return jnp.maximum(r, hop)

            return jax.lax.fori_loop(0, max(n - 1, 0), body, r0)

        def score(a_src, a_dst, a_act):
            present = a_act & allowed[a_src, a_dst] & (a_src != a_dst)
            pf = present.astype(lat.dtype)
            seg_dst = (boff + a_dst).ravel()
            seg_src = (boff + a_src).ravel()
            out_deg = jax.ops.segment_sum(
                pf.ravel(), seg_src, num_segments=B * n
            ).reshape(B, n)
            in_deg = jax.ops.segment_sum(
                pf.ravel(), seg_dst, num_segments=B * n
            ).reshape(B, n)
            od = jnp.take_along_axis(out_deg, a_src, axis=1)
            idg = jnp.take_along_axis(in_deg, a_dst, axis=1)
            rate = jnp.minimum(
                jnp.minimum(
                    up[a_src] / jnp.maximum(od, 1.0),
                    dn[a_dst] / jnp.maximum(idg, 1.0),
                ),
                bw[a_src, a_dst],
            )
            warc = comp[a_src] + lat[a_src, a_dst] + model_mbits / rate
            warc = jnp.where(present, warc, -INF)
            src_all = jnp.concatenate([a_src, sl], axis=1)
            dst_all = jnp.concatenate([a_dst, sl], axis=1)
            w_all = jnp.concatenate([warc, comp_sl], axis=1)
            tau = batched_cycle_time_sparse_jax(src_all, dst_all, w_all, n)
            fwd = reach_all(a_src, (boff + a_dst).ravel(), pf)
            bwd = reach_all(a_dst, (boff + a_src).ravel(), pf)
            strong = jnp.all((fwd > 0) & (bwd > 0), axis=1)
            deg_ok = jnp.all(out_deg <= delta_max, axis=1) & jnp.all(
                in_deg <= delta_max, axis=1
            )
            return jnp.where(strong & deg_ok, tau, INF)

        def step(_, carry):
            a_src, a_dst, a_act, tau, k = carry
            k, k1, k2, k3, k4, k5 = jax.random.split(k, 6)
            mtype = jax.random.randint(k1, (B,), 0, 3)
            is_add = mtype == 1
            is_drop = mtype == 2
            act_logits = jnp.where(a_act, 0.0, -INF)
            inact_logits = jnp.where(a_act, -INF, 0.0)
            slot_act = jax.random.categorical(k2, act_logits, axis=1)
            slot_inact = jax.random.categorical(k3, inact_logits, axis=1)
            slot = jnp.where(is_add, slot_inact, slot_act).astype(jnp.int32)
            rand_i = jax.random.randint(k4, (B,), 0, n, dtype=jnp.int32)
            rand_j = jax.random.randint(k5, (B,), 0, n, dtype=jnp.int32)
            cur_src = a_src[rows, slot]
            cur_dst = a_dst[rows, slot]
            cur_act = a_act[rows, slot]
            new_src = jnp.where(is_add, rand_i, cur_src)
            new_dst = jnp.where(is_drop, cur_dst, rand_j)
            new_act = ~is_drop
            # Slot sanity (categorical over all -inf logits is garbage),
            # connectivity-graph membership, and arc uniqueness.
            slot_ok = jnp.where(is_add, ~cur_act, cur_act)
            arc_ok = (new_src != new_dst) & allowed[new_src, new_dst]
            dup = jnp.any(
                a_act
                & (a_src == new_src[:, None])
                & (a_dst == new_dst[:, None])
                & (slot_ids[None, :] != slot[:, None]),
                axis=1,
            )
            ok = slot_ok & (is_drop | (arc_ok & ~dup))
            p_src = a_src.at[rows, slot].set(new_src)
            p_dst = a_dst.at[rows, slot].set(new_dst)
            p_act = a_act.at[rows, slot].set(new_act)
            ptau = jnp.where(ok, score(p_src, p_dst, p_act), INF)
            better = ptau < tau
            bet = better[:, None]
            return (
                jnp.where(bet, p_src, a_src),
                jnp.where(bet, p_dst, a_dst),
                jnp.where(bet, p_act, a_act),
                jnp.where(better, ptau, tau),
                k,
            )

        tau0 = score(asrc, adst, aact)
        a_src, a_dst, a_act, tau, _ = jax.lax.fori_loop(
            0, n_steps, step, (asrc, adst, aact, tau0, key)
        )
        return a_src, a_dst, a_act, tau

    return jax.jit(climb, static_argnums=(11, 12))


def _degrees_ok(arcs: Sequence[Tuple[int, int]], n: int, delta: int) -> bool:
    out = np.zeros(n, dtype=np.int64)
    inn = np.zeros(n, dtype=np.int64)
    for (i, j) in arcs:
        out[i] += 1
        inn[j] += 1
    return bool(out.max(initial=0) <= delta and inn.max(initial=0) <= delta)


def _seed_states(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    index: Dict[Node, int],
    n_restarts: int,
    slots: int,
    delta_max: int,
    rng: np.random.Generator,
    incumbent: Optional[Overlay],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, List[List[Tuple[int, int]]]]:
    """Initial ``[B, S]`` arc-slot states for the rewire climb, plus the
    list of structured seed arc lists (for exact f64 re-pricing).

    Restart seeds: the incumbent overlay (if given), the Christofides
    ring, the bidirected MST, then random Hamiltonian rings.  Seeds
    violating the ``delta_max`` degree bound are skipped — they would
    score ``+inf`` forever and burn their restart's whole move budget.
    On a non-complete connectivity graph random rings routinely hit
    unrouted pairs (instant ``+inf``), so the remaining restarts cycle
    over the feasible seeds instead.
    """
    n = gc.num_silos
    seeds: List[List[Tuple[int, int]]] = []
    if incumbent is not None and all(
        i in index and j in index and gc.has_edge(i, j)
        for (i, j) in incumbent.edges
        if i != j
    ):  # churn / link failure can invalidate the incumbent's silos or arcs
        edges = sorted(
            {(index[i], index[j]) for (i, j) in incumbent.edges if i != j}
        )
        if 0 < len(edges) <= slots and _degrees_ok(edges, n, delta_max):
            seeds.append(edges)
    try:  # Christofides ring: the strongest cheap designer (Prop. 3.3)
        tour = christofides_tour(
            list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
        )
        ring_arcs = [
            (index[tour[k]], index[tour[(k + 1) % len(tour)]])
            for k in range(len(tour))
        ]
        if all(
            gc.has_edge(gc.silos[a], gc.silos[b]) for (a, b) in ring_arcs
        ):
            seeds.append(ring_arcs)
    except (ValueError, KeyError):
        pass
    try:
        tree = mst_edges(gc, lambda i, j: symmetrized_delay_ms(gc, tp, i, j))
        mst_arcs = [(index[i], index[j]) for (i, j) in _bidir(tree)]
        if len(mst_arcs) <= slots and _degrees_ok(mst_arcs, n, delta_max):
            seeds.append(mst_arcs)
    except ValueError:
        pass
    full_mesh = len([1 for (i, j) in gc.latency_ms if i != j]) == n * (n - 1)
    asrc = np.zeros((n_restarts, slots), dtype=np.int32)
    adst = np.zeros((n_restarts, slots), dtype=np.int32)
    aact = np.zeros((n_restarts, slots), dtype=bool)
    for b in range(n_restarts):
        if b < len(seeds):
            arcs = seeds[b]
        elif full_mesh or not seeds:
            perm = rng.permutation(n)
            arcs = [
                (int(perm[k]), int(perm[(k + 1) % n])) for k in range(n)
            ]
        else:
            arcs = seeds[b % len(seeds)]
        m = len(arcs)
        asrc[b, :m] = [a for (a, _) in arcs]
        adst[b, :m] = [a for (_, a) in arcs]
        aact[b, :m] = True
    return asrc, adst, aact, seeds


@span_fn("designer.search_jit")
def search_overlays_jit(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_restarts: int = 16,
    n_steps: int = 96,
    delta_max: int = 8,
    max_arcs: Optional[int] = None,
    seed: int = 0,
    incumbent: Optional[Overlay] = None,
) -> Overlay:
    """Device-side topology search: batched rewire hill climb with random
    restarts, scored by the sparse jitted max-plus engine.

    Unlike the designer heuristics (host-side graph algorithms) and the
    batched ring search (host-side candidate generation, batched
    scoring), here *candidate generation itself* runs on the device: each
    of ``n_restarts`` parallel states proposes one local move per step —
    rewire an arc endpoint, add an arc, or drop one — under per-silo
    degree (``delta_max``, in- and out-degree of the overlay, Sect. 3.2's
    node-capacitated motivation) and connectivity-graph membership
    constraints, scores all proposals with
    :func:`repro.core.maxplus_sparse.batched_cycle_time_sparse_jax`
    (Eq. 3 arc weights, including the degree-dependent access-link
    sharing, are rebuilt on device per proposal), and accepts
    improvements.  The whole search lowers to one ``lax.fori_loop`` XLA
    computation of O(B·n_steps·N·E) work — no host round trips, so it
    scales to thousands of silos where dense ``[B, N, N]`` scoring hits
    the memory wall.

    Parameters
    ----------
    gc, tp:
        Connectivity measurements and workload, as for every designer.
    n_restarts:
        Parallel hill-climb states.  Seeds, in order: the ``incumbent``
        (if any), the Christofides ring, the bidirected MST, then random
        Hamiltonian rings.  The ring seed is load-bearing: with it in
        the restart pool (and the exact f64 re-pricing below) the result
        is guaranteed never worse than the paper's RING designer.
    n_steps:
        Rewire moves proposed per restart (static: changing it triggers
        one recompile).
    delta_max:
        Max in-degree and out-degree per silo.
    max_arcs:
        Arc-slot capacity S (default ``2 N``): add moves beyond it are
        rejected, which also caps device memory at O(B·S).
    seed:
        Seeds both the restart rings and the device move stream.
    incumbent:
        Optional overlay to seed restart 0 from — the controller passes
        its active overlay so the search explores *local* repairs first.

    Returns
    -------
    The best of {climb result, structured seeds}, re-priced exactly (f64,
    sparse engine) so the result is never worse than a feasible seed
    (``name="sparse_rewire"``).  Raises ``ValueError`` if neither the
    climb nor any seed reaches a strongly-connected, degree-feasible
    state.
    """
    n = gc.num_silos
    if n < 2:
        raise ValueError("sparse-rewire search needs at least 2 silos")
    index = {v: k for k, v in enumerate(gc.silos)}
    slots = max(max_arcs if max_arcs is not None else 2 * n, n)
    if incumbent is not None:
        slots = max(slots, len({e for e in incumbent.edges if e[0] != e[1]}))
    lat = np.ones((n, n), dtype=np.float32)
    bw = np.ones((n, n), dtype=np.float32)
    allowed = np.zeros((n, n), dtype=bool)
    for (i, j), l in gc.latency_ms.items():
        if i == j:
            continue
        a, b = index[i], index[j]
        lat[a, b] = l
        bw[a, b] = gc.available_bw_gbps[(i, j)]
        allowed[a, b] = True
    comp = np.array(
        [tp.local_steps * gc.silo_params[v].comp_time_ms for v in gc.silos],
        dtype=np.float32,
    )
    up = np.array(
        [gc.silo_params[v].uplink_gbps for v in gc.silos], dtype=np.float32
    )
    dn = np.array(
        [gc.silo_params[v].downlink_gbps for v in gc.silos], dtype=np.float32
    )
    rng = np.random.default_rng(seed)
    asrc, adst, aact, seed_arcs = _seed_states(
        gc, tp, index, n_restarts, slots, delta_max, rng, incumbent
    )
    if "climb" not in _REWIRE_JIT:
        _REWIRE_JIT["climb"] = _build_rewire_climb()
    import jax

    a_src, a_dst, a_act, tau = _REWIRE_JIT["climb"](
        lat, bw, allowed, comp, up, dn, np.float32(tp.model_size_mbits),
        asrc, adst, aact, jax.random.PRNGKey(seed),
        int(n_steps), int(delta_max),
    )
    # Exact f64 re-pricing of the climb's best restart AND the structured
    # seeds, all through the sparse engine (no dense N^2 blowup).  The
    # climb accepts moves by f32 score, so comparing the final candidates
    # in f64 is what makes the "never worse than the seeds" guarantee
    # exact rather than f32-approximate.
    # One batched device->host transfer instead of four implicit syncs.
    a_src, a_dst, a_act, tau = jax.device_get((a_src, a_dst, a_act, tau))
    best = int(np.argmin(tau))
    candidates: List[List[Tuple[int, int]]] = []
    if np.isfinite(tau[best]):
        b_src = a_src[best]
        b_dst = a_dst[best]
        keep = a_act[best] & (b_src != b_dst) & allowed[b_src, b_dst]
        candidates.append(
            [(int(i), int(j)) for (i, j) in zip(b_src[keep], b_dst[keep])]
        )
    candidates.extend(seed_arcs)
    if not candidates:
        raise ValueError(
            "sparse-rewire search found no strongly-connected candidate"
        )
    pool = sorted({a for arcs in candidates for a in arcs})
    pool_index = {a: k for k, a in enumerate(pool)}
    masks = np.zeros((len(candidates), len(pool)), dtype=bool)
    for c, arcs in enumerate(candidates):
        masks[c, [pool_index[a] for a in arcs]] = True
    pool_lbl = [(gc.silos[i], gc.silos[j]) for (i, j) in pool]
    eb = batched_overlay_delay_edges(gc, tp, pool_lbl, masks)
    strong = batched_is_strongly_connected_sparse(eb)
    taus = np.where(strong, batched_cycle_time_sparse(eb), np.inf)
    k = int(np.argmin(taus))
    if not np.isfinite(taus[k]):
        raise ValueError(
            "sparse-rewire search found no strongly-connected candidate"
        )
    edges = tuple(pool_lbl[e] for e in np.nonzero(masks[k])[0])
    return Overlay(
        name="sparse_rewire", edges=edges, cycle_time_ms=float(taus[k])
    )


# ---------------------------------------------------------------------------
# Registry used by benchmarks / launcher


@span_fn("designer.design_overlay")
def design_overlay(
    kind: str,
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    center: Optional[Node] = None,
) -> Overlay:
    """Run one named designer on (``gc``, ``tp``) and return its
    :class:`Overlay`.

    ``kind`` is one of :data:`OVERLAY_KINDS`: ``star``, ``mst``,
    ``ring``, ``ring_2opt``, ``delta_mbst`` (Algorithm 1), or
    ``sparse_rewire`` (the device-side jitted search); ``center`` pins
    the STAR orchestrator.  The registry the benchmarks, launcher, and
    controller all design through."""
    kind = kind.lower()
    if kind == "star":
        return star_overlay(gc, tp, center=center)
    if kind == "mst":
        return mst_overlay(gc, tp)
    if kind == "ring":
        return ring_overlay(gc, tp)
    if kind == "ring_2opt":
        return two_opt_ring_overlay(gc, tp)
    if kind in ("delta_mbst", "dmbst"):
        return algorithm1_mbst(gc, tp)
    if kind in ("sparse_rewire", "sparse-rewire"):
        return search_overlays_jit(gc, tp)
    raise KeyError(f"unknown overlay kind {kind!r}")


OVERLAY_KINDS = (
    "star", "mst", "delta_mbst", "ring", "ring_2opt", "sparse_rewire",
)


def design_schedule(
    kind: str,
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    center: Optional[Node] = None,
    budgets: Optional[Sequence[float]] = None,
    rounds: int = 150,
    seeds: Sequence[int] = (0, 1, 2),
    sample_seed: int = 0,
    objective: str = "tau",
    mixing_rounds: int = 128,
):
    """Run one named designer and return a :class:`repro.core.schedule.Schedule`.

    The schedule-valued superset of :func:`design_overlay`: every
    :data:`OVERLAY_KINDS` designer is wrapped in a
    :class:`~repro.core.schedule.FixedSchedule`, and ``kind="matcha"``
    runs the randomized designer — a budget sweep
    (:func:`~repro.core.schedule.design_matcha_schedule`) that prices
    every budget × seed Monte-Carlo chain through the batched sparse
    engine in one call and returns the budget minimizing ``objective``
    (``"tau"``: mean τ̄; ``"time_to_eps"``: the composite
    ``τ̄ / −log(ρ)`` with ρ the expected contraction over
    ``mixing_rounds`` sampled rounds — see :mod:`repro.core.mixing`).
    ``budgets``/``rounds``/``seeds``/``sample_seed``/``objective``
    parameterize the sweep; fixed kinds design by cycle time alone
    (the fixed-vs-randomized arbitration under an objective lives in
    :func:`repro.dynamics.controller.design_best_schedule`).
    """
    from .schedule import (
        DEFAULT_MATCHA_BUDGETS,
        FixedSchedule,
        design_matcha_schedule,
    )

    kind = kind.lower()
    if kind == "matcha":
        schedule, _ = design_matcha_schedule(
            gc,
            tp,
            budgets=DEFAULT_MATCHA_BUDGETS if budgets is None else budgets,
            rounds=rounds,
            seeds=seeds,
            sample_seed=sample_seed,
            objective=objective,
            mixing_rounds=mixing_rounds,
        )
        return schedule
    return FixedSchedule(design_overlay(kind, gc, tp, center=center))


SCHEDULE_KINDS = OVERLAY_KINDS + ("matcha",)
