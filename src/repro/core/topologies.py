"""Topology design algorithms for the Minimal Cycle Time (MCT) problem.

Implements every overlay of Table 1 / Table 3:

* ``star_overlay``        — server-client baseline (orchestrator at the
                            load-centrality center);
* ``mst_overlay``         — Prim MST on the symmetrized connectivity graph:
                            *optimal* for edge-capacitated undirected
                            overlays (Prop. 3.1);
* ``ring_overlay``        — directed ring from Christofides' TSP algorithm:
                            3N-approximation on Euclidean graphs
                            (Prop. 3.3 / 3.6);
* ``delta_prim``          — degree-bounded Prim (Algorithm 2, [2]);
* ``delta_mbst_overlay``  — Algorithm 1 (Appendix D): 2-MBST via MST-cube
                            Hamiltonian path + δ-PRIM sweep, picking the
                            candidate with minimal cycle time:
                            6-approximation on node-capacitated Euclidean
                            graphs (Prop. 3.5);
* ``brute_force_mct``     — exact solver (exponential; used by tests to
                            certify optimality/approximation claims on
                            small instances).

Beyond the paper, three search engines scale the design loop:

* ``search_overlays_jit``         — batched simulated-annealing rewire
  climb *on device*: candidates are local arc edits (swap / add / drop /
  2-opt) of an incumbent overlay, scored by the sparse jitted max-plus
  engine (:mod:`repro.core.maxplus_sparse`) inside one
  ``lax.fori_loop``; above :data:`_DELTA_ENGINE_MIN_N` silos it
  auto-delegates to the delta engine;
* ``search_overlays_delta``       — the same move set priced
  *incrementally* on the host via
  :class:`~repro.core.maxplus_sparse.DeltaPricer` certificates: O(deg)
  per proposal instead of a full Karp pass;
* ``search_overlays_hierarchical`` — cluster the silos by delay, run
  every intra-cluster search batched in one multi-universe climb call,
  compose with an inter-cluster ring, and price the composition exactly.

An *overlay* is returned as a list of **directed** edges; undirected
topologies contain both directions of every link.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, Hashable, Iterable, List, Mapping, Optional, Sequence, Set, Tuple, Union

import numpy as np

from .delays import (
    ConnectivityGraph,
    TrainingParams,
    batched_overlay_delay_matrices,
    node_capacitated_sym_delay_ms,
    overlay_delay_matrix,
    symmetrized_delay_ms,
)
from .maxplus_vec import (
    NEG_INF,
    batched_cycle_time,
    batched_is_strongly_connected,
    cycle_time_dense,
)
from .maxplus_sparse import (
    DeltaPricer,
    batched_cycle_time_auto,
    batched_cycle_time_sparse,
    batched_is_strongly_connected_sparse,
    batched_overlay_delay_edges,
)
from ..obs.spans import span_fn

Node = Hashable
Edge = Tuple[Node, Node]


@dataclass(frozen=True)
class Overlay:
    """A designed overlay with its realized cycle time."""

    name: str
    edges: Tuple[Edge, ...]  # directed
    cycle_time_ms: float

    @property
    def undirected_edges(self) -> Set[FrozenSet[Node]]:
        return {frozenset(e) for e in self.edges}

    def out_degree(self, v: Node) -> int:
        return sum(1 for (i, _) in self.edges if i == v)

    def in_degree(self, v: Node) -> int:
        return sum(1 for (_, j) in self.edges if j == v)


def evaluate_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, edges: Sequence[Edge], name: str = "custom"
) -> Overlay:
    """Price a directed edge list with Eq. 3 and return it as an
    :class:`Overlay` with its exact (f64 dense-engine) cycle time.
    Raises ``ValueError`` if the edges do not form a strongly-connected
    digraph over ``gc.silos``."""
    W = overlay_delay_matrix(gc, tp, edges)
    if not batched_is_strongly_connected(W):
        raise ValueError(f"overlay {name!r} is not strongly connected")
    return Overlay(name=name, edges=tuple(edges), cycle_time_ms=cycle_time_dense(W))


def _sym_edges(gc: ConnectivityGraph) -> List[Tuple[Node, Node]]:
    """Unordered silo pairs present in both directions (G_c^(u))."""
    out = []
    seen = set()
    for (i, j) in gc.latency_ms:
        key = frozenset((i, j))
        if key in seen or i == j:
            continue
        if gc.has_edge(j, i):
            seen.add(key)
            out.append((i, j))
    return out


def _bidir(edges: Sequence[Tuple[Node, Node]]) -> List[Edge]:
    out: List[Edge] = []
    for (i, j) in edges:
        out.append((i, j))
        out.append((j, i))
    return out


# ---------------------------------------------------------------------------
# STAR (server-client baseline)


def star_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, center: Optional[Node] = None
) -> Overlay:
    """Server-client (FedAvg) baseline.

    One communication round is *two-phase* (Appendix B): every silo uploads
    to the orchestrator, which aggregates and pushes the refined model back.
    The orchestrator performs no local training (its loss is constant), so

        tau_STAR = max_l [ s*T_c(l) + l(l,c) + M/min(C_UP(l), C_DN(c)/N, A) ]
                 + max_l [           l(c,l) + M/min(C_UP(c)/N, C_DN(l), A) ]

    which recovers Appendix B's 2N*M/C in the slow-homogeneous-access-link
    regime.  (The generic max-plus circuit mean would halve this because a
    FedAvg round spans two ticks of the recursion.)
    """
    if center is None:
        # Highest-closeness silo in latency space when no underlay info.
        def closeness(v: Node) -> float:
            return sum(gc.latency_ms[(v, u)] for u in gc.silos if u != v)

        center = min(gc.silos, key=closeness)
    leaves = [v for v in gc.silos if v != center]
    n = len(leaves)
    cp = gc.silo_params[center]
    up_phase = 0.0
    dn_phase = 0.0
    for l in leaves:
        lp = gc.silo_params[l]
        up_rate = min(lp.uplink_gbps, cp.downlink_gbps / n, gc.available_bw_gbps[(l, center)])
        dn_rate = min(cp.uplink_gbps / n, lp.downlink_gbps, gc.available_bw_gbps[(center, l)])
        up_phase = max(
            up_phase,
            tp.local_steps * lp.comp_time_ms
            + gc.latency_ms[(l, center)]
            + tp.model_size_mbits / up_rate,
        )
        dn_phase = max(
            dn_phase, gc.latency_ms[(center, l)] + tp.model_size_mbits / dn_rate
        )
    edges = []
    for v in leaves:
        edges.append((center, v))
        edges.append((v, center))
    return Overlay(name="star", edges=tuple(edges), cycle_time_ms=up_phase + dn_phase)


# ---------------------------------------------------------------------------
# MST (Prop. 3.1) — Prim's algorithm on the symmetrized delays


def mst_edges(
    gc: ConnectivityGraph,
    weight: Callable[[Node, Node], float],
) -> List[Tuple[Node, Node]]:
    """Prim MST over G_c^(u) with the given symmetric weight."""
    pairs = _sym_edges(gc)
    adj: Dict[Node, List[Tuple[Node, float]]] = {v: [] for v in gc.silos}
    for (i, j) in pairs:
        w = weight(i, j)
        adj[i].append((j, w))
        adj[j].append((i, w))
    import heapq

    start = gc.silos[0]
    visited = {start}
    pq: List[Tuple[float, int, Node, Node]] = []
    tiebreak = itertools.count()
    for (v, w) in adj[start]:
        heapq.heappush(pq, (w, next(tiebreak), start, v))
    tree: List[Tuple[Node, Node]] = []
    while pq and len(visited) < len(gc.silos):
        w, _, u, v = heapq.heappop(pq)
        if v in visited:
            continue
        visited.add(v)
        tree.append((u, v))
        for (x, wx) in adj[v]:
            if x not in visited:
                heapq.heappush(pq, (wx, next(tiebreak), v, x))
    if len(visited) != len(gc.silos):
        raise ValueError("connectivity graph (symmetrized) is not connected")
    return tree


def mst_overlay(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """MST on the symmetrized connectivity delays, both arc directions
    kept — optimal among undirected overlays on edge-capacitated
    networks (Prop. 3.1)."""
    tree = mst_edges(gc, lambda i, j: symmetrized_delay_ms(gc, tp, i, j))
    ov = evaluate_overlay(gc, tp, _bidir(tree), name="mst")
    return ov


# ---------------------------------------------------------------------------
# RING via Christofides (Prop. 3.3 / 3.6)


def christofides_tour(nodes: Sequence[Node], weight: Callable[[Node, Node], float]) -> List[Node]:
    """Christofides' 1.5-approximation for metric TSP.

    MST + minimum-weight perfect matching on odd-degree vertices (greedy
    matching — keeps the classical guarantee structure; exact blossom is
    overkill at N<=100 and greedy is the standard engineering choice) +
    Eulerian circuit + shortcutting.
    """
    nodes = list(nodes)
    n = len(nodes)
    if n == 1:
        return nodes
    if n == 2:
        return nodes
    # MST (Prim, dense)
    in_tree = [False] * n
    best = [math.inf] * n
    best_to = [-1] * n
    in_tree[0] = True
    for j in range(1, n):
        best[j] = weight(nodes[0], nodes[j])
        best_to[j] = 0
    mst_adj: Dict[int, List[int]] = {i: [] for i in range(n)}
    for _ in range(n - 1):
        v = min((j for j in range(n) if not in_tree[j]), key=lambda j: best[j])
        mst_adj[v].append(best_to[v])
        mst_adj[best_to[v]].append(v)
        in_tree[v] = True
        for j in range(n):
            if not in_tree[j]:
                w = weight(nodes[v], nodes[j])
                if w < best[j]:
                    best[j] = w
                    best_to[j] = v
    # Odd-degree vertices -> greedy min-weight perfect matching
    odd = [v for v in range(n) if len(mst_adj[v]) % 2 == 1]
    pairs = sorted(
        ((weight(nodes[a], nodes[b]), a, b) for k, a in enumerate(odd) for b in odd[k + 1 :]),
    )
    matched: Set[int] = set()
    for (_, a, b) in pairs:
        if a not in matched and b not in matched:
            matched.add(a)
            matched.add(b)
            mst_adj[a].append(b)
            mst_adj[b].append(a)
    # Eulerian circuit (Hierholzer) on the multigraph
    adj_copy: Dict[int, List[int]] = {v: list(ns) for v, ns in mst_adj.items()}
    stack = [0]
    circuit: List[int] = []
    while stack:
        v = stack[-1]
        if adj_copy[v]:
            u = adj_copy[v].pop()
            adj_copy[u].remove(v)
            stack.append(u)
        else:
            circuit.append(stack.pop())
    # Shortcut repeated vertices
    seen: Set[int] = set()
    tour: List[int] = []
    for v in circuit:
        if v not in seen:
            seen.add(v)
            tour.append(v)
    return [nodes[v] for v in tour]


def ring_overlay(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """Directed ring from Christofides on the symmetrized connectivity
    delays (the paper's RING, Prop. 3.3/3.6)."""
    tour = christofides_tour(
        list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    )
    edges = [(tour[k], tour[(k + 1) % len(tour)]) for k in range(len(tour))]
    return evaluate_overlay(gc, tp, edges, name="ring")


def two_opt_ring_overlay(
    gc: ConnectivityGraph, tp: TrainingParams, max_rounds: int = 20
) -> Overlay:
    """Beyond-paper: Christofides tour refined with 2-opt on symmetrized
    delays, then evaluated with the true (node-capacitated) cycle time."""
    tour = christofides_tour(
        list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    )
    w = lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
    n = len(tour)
    improved = True
    rounds = 0
    while improved and rounds < max_rounds:
        improved = False
        rounds += 1
        for a in range(n - 1):
            for b in range(a + 2, n - (1 if a == 0 else 0)):
                i, inext = tour[a], tour[a + 1]
                j, jnext = tour[b], tour[(b + 1) % n]
                delta = (w(i, j) + w(inext, jnext)) - (w(i, inext) + w(j, jnext))
                if delta < -1e-9:
                    tour[a + 1 : b + 1] = reversed(tour[a + 1 : b + 1])
                    improved = True
    edges = [(tour[k], tour[(k + 1) % n]) for k in range(n)]
    return evaluate_overlay(gc, tp, edges, name="ring_2opt")


# ---------------------------------------------------------------------------
# δ-PRIM (Algorithm 2) and Algorithm 1 (δ-MBST, Prop. 3.5)


def delta_prim(
    gc: ConnectivityGraph,
    weight: Callable[[Node, Node], float],
    delta: int,
) -> List[Tuple[Node, Node]]:
    """Degree-bounded Prim: grow a tree always picking the smallest-weight
    edge whose tree endpoint has degree < delta (Algorithm 2, [2])."""
    nodes = list(gc.silos)
    pairs = _sym_edges(gc)
    wmap: Dict[FrozenSet[Node], float] = {frozenset(p): weight(*p) for p in pairs}
    in_tree: Set[Node] = {nodes[0]}
    degree: Dict[Node, int] = {v: 0 for v in nodes}
    tree: List[Tuple[Node, Node]] = []
    while len(in_tree) < len(nodes):
        cand: Optional[Tuple[float, Node, Node]] = None
        for u in in_tree:
            if degree[u] >= delta:
                continue
            for v in nodes:
                if v in in_tree:
                    continue
                key = frozenset((u, v))
                if key not in wmap:
                    continue
                w = wmap[key]
                if cand is None or w < cand[0]:
                    cand = (w, u, v)
        if cand is None:
            raise ValueError(f"delta-PRIM stuck: no degree-<{delta} expansion edge")
        _, u, v = cand
        tree.append((u, v))
        degree[u] += 1
        degree[v] += 1
        in_tree.add(v)
    return tree


def _cube_hamiltonian_path(tree_adj: Dict[Node, List[Node]], root: Node) -> List[Node]:
    """Hamiltonian path in the cube of a tree via a pre-order DFS walk.

    A DFS pre-order of a tree visits consecutive vertices at tree distance
    <= 3 when children subtrees are walked contiguously — the classical
    construction behind Karaganis' theorem [43] used by [3, Sect. 3.2.1].
    """
    order: List[Node] = []
    stack: List[Node] = [root]
    seen: Set[Node] = set()
    while stack:
        v = stack.pop()
        if v in seen:
            continue
        seen.add(v)
        order.append(v)
        for u in reversed(tree_adj[v]):
            if u not in seen:
                stack.append(u)
    return order


def algorithm1_mbst(gc: ConnectivityGraph, tp: TrainingParams) -> Overlay:
    """Algorithm 1 (Appendix D): candidates = {Hamiltonian path in MST^3}
    ∪ {δ-PRIM trees, δ=3..N}; return the candidate with the smallest
    *true* cycle time (node-capacitated Eq. 3 evaluation)."""
    weight = lambda i, j: node_capacitated_sym_delay_ms(gc, tp, i, j)
    candidates: List[Tuple[str, List[Tuple[Node, Node]]]] = []
    # 2-MBST approximation: Hamiltonian path in the cube of the MST.
    mst = mst_edges(gc, weight)
    adj: Dict[Node, List[Node]] = {v: [] for v in gc.silos}
    for (u, v) in mst:
        adj[u].append(v)
        adj[v].append(u)
    ham = _cube_hamiltonian_path(adj, gc.silos[0])
    path_edges = list(zip(ham[:-1], ham[1:]))
    # The cube path may use pairs missing from G_c^(u) if it is not complete;
    # only keep the candidate if all pairs exist.
    if all(gc.has_edge(i, j) and gc.has_edge(j, i) for (i, j) in path_edges):
        candidates.append(("2mbst_path", path_edges))
    for delta in range(3, gc.num_silos):
        try:
            candidates.append((f"{delta}-prim", delta_prim(gc, weight, delta)))
        except ValueError:
            continue
    # Score every candidate in one batched engine call.
    cand_edges = [_bidir(tree) for (_, tree) in candidates]
    W = np.stack([overlay_delay_matrix(gc, tp, e) for e in cand_edges])
    strong = batched_is_strongly_connected(W)
    taus = np.where(strong, batched_cycle_time(W), np.inf)
    k = int(np.argmin(taus))
    if not np.isfinite(taus[k]):
        raise ValueError("no strongly-connected delta-MBST candidate")
    return Overlay(
        name="delta_mbst", edges=tuple(cand_edges[k]), cycle_time_ms=float(taus[k])
    )


# ---------------------------------------------------------------------------
# Exact solver (for tests on small instances)


def _best_masked_candidate(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    arcs: List[Edge],
    subsets: Iterable[Tuple[int, ...]],
    best_tau: float,
    best_rows: Optional[List[int]],
    *,
    batch: int = 4096,
) -> Tuple[float, Optional[List[int]]]:
    """Scan candidate arc-index subsets in batched engine calls.

    Returns the best (cycle time, arc-index list) seen, seeded with the
    incoming incumbent.  Non-strongly-connected candidates are skipped.
    """
    E = len(arcs)
    buf: List[Tuple[int, ...]] = []

    def flush() -> Tuple[float, Optional[List[int]]]:
        nonlocal best_tau, best_rows
        masks = np.zeros((len(buf), E), dtype=bool)
        for k, subset in enumerate(buf):
            masks[k, list(subset)] = True
        W = batched_overlay_delay_matrices(gc, tp, arcs, masks)
        strong = np.nonzero(batched_is_strongly_connected(W))[0]
        if strong.size:
            taus = batched_cycle_time(W[strong])
            k = int(np.argmin(taus))
            if taus[k] < best_tau:
                best_tau = float(taus[k])
                best_rows = list(buf[int(strong[k])])
        buf.clear()
        return best_tau, best_rows

    for subset in subsets:
        buf.append(subset)
        if len(buf) >= batch:
            best_tau, best_rows = flush()
    if buf:
        best_tau, best_rows = flush()
    return best_tau, best_rows


def brute_force_mct(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    undirected: bool = False,
    max_nodes: int = 7,
    exhaustive: bool = True,
    batch: int = 4096,
) -> Overlay:
    """Exact MCT solver by enumeration (exponential — tests/small N only).

    Candidates are scored through the batched max-plus engine, thousands
    of overlays per call.  With ``exhaustive=True`` (default) every arc
    count is enumerated, which is required for a *certificate* of
    optimality: minimally strong digraphs can have up to 2(N-1) arcs
    (e.g. bidirected trees), so the legacy heuristic cut at ``r >= N + 2``
    arcs could return a suboptimal overlay.  Pass ``exhaustive=False`` to
    re-enable that cut as a cheap heuristic.
    """
    n = gc.num_silos
    if n > max_nodes:
        raise ValueError("brute force limited to tiny instances")
    best_tau = math.inf
    best_rows: Optional[List[int]] = None
    if undirected:
        pairs = _sym_edges(gc)
        arcs = _bidir(pairs)  # pair p -> arc rows 2p, 2p+1
        for r in range(n - 1, len(pairs) + 1):
            subsets = (
                tuple(a for p in combo for a in (2 * p, 2 * p + 1))
                for combo in itertools.combinations(range(len(pairs)), r)
            )
            best_tau, best_rows = _best_masked_candidate(
                gc, tp, arcs, subsets, best_tau, best_rows, batch=batch
            )
        assert best_rows is not None
        edges = tuple(arcs[a] for a in best_rows)
        return Overlay(name="bf", edges=edges, cycle_time_ms=best_tau)
    arcs = [e for e in gc.edges() if e[0] != e[1]]
    # Prune: a strong digraph needs >= n arcs.
    for r in range(n, len(arcs) + 1):
        best_tau, best_rows = _best_masked_candidate(
            gc,
            tp,
            arcs,
            itertools.combinations(range(len(arcs)), r),
            best_tau,
            best_rows,
            batch=batch,
        )
        if not exhaustive and best_rows is not None and r >= n + 2:
            break  # heuristic cut: may miss optima that need many arcs
    assert best_rows is not None
    edges = tuple(arcs[a] for a in best_rows)
    return Overlay(name="bf", edges=edges, cycle_time_ms=best_tau)


# ---------------------------------------------------------------------------
# Device-resident topology search (sparse engine + jitted rewire hill climb)

# Lazily-built jitted climb, cached per process; jax recompiles per
# distinct (B, S, N, n_steps, delta_max) shape tuple and caches after.
_REWIRE_JIT: Dict[str, object] = {}


def _build_rewire_climb(multi: bool = False):
    """Build (and jit) the device-side rewire climb.

    ``multi=False``: one connectivity universe shared by all restarts
    (``lat/bw/allowed`` are ``[n, n]``, ``comp/up/dn`` are ``[n]``).

    ``multi=True``: every restart carries its *own* universe
    (``[B, n, n]`` / ``[B, n]``) plus an ``n_active`` vector — the
    hierarchical designer packs one cluster per group of restarts, pads
    them all to the max cluster size, and runs every intra-cluster
    search in this one call.  Padded nodes sit at indices
    ``>= n_active[b]`` with ``allowed`` all-False and ``comp = -inf``
    (their self-loop becomes padding, so they contribute no cycles) and
    are exempted from the strong-connectivity requirement.
    """
    import jax
    import jax.numpy as jnp

    from .maxplus_sparse import batched_cycle_time_sparse_jax

    INF = jnp.inf

    def climb(lat, bw, allowed, comp, up, dn, model_mbits,
              asrc, adst, aact, key, n_steps, delta_max, sa_t0, sa_t1):
        """Batched simulated-annealing rewire climb over arc-slot states.

        ``asrc/adst/aact`` are ``[B, S]`` arc slots per restart; each
        step proposes one local move per restart — endpoint swap, arc
        add, arc drop, or a 2-opt double rewire (two arcs exchange
        destinations; degree-preserving, so it explores where the
        single moves saturate the degree bound) — scores the proposal
        with the sparse jitted Karp, and accepts improvements plus
        Metropolis-accepted uphill moves under a geometric temperature
        schedule ``sa_t0 -> sa_t1`` (relative-tau scale; ``sa_t0 = 0``
        recovers pure hill climbing).  The best feasible state ever
        visited is tracked separately and returned, so annealing can
        only add exploration, never cost.  Entirely device-side: one
        XLA computation for the whole search.
        """
        B, S = asrc.shape
        n = lat.shape[-1]
        boff = jnp.arange(B, dtype=jnp.int32)[:, None] * n
        rows = jnp.arange(B)
        sl = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        slot_ids = jnp.arange(S, dtype=jnp.int32)
        if multi:
            comp_sl = comp
            active = ~jnp.isneginf(comp)  # [B, n]; padded nodes = -inf
            n_active = jnp.sum(active.astype(jnp.int32), axis=1)

            def pick2(M, s, d):  # M[B, n, n] gathered at per-row indices
                b = jnp.arange(B, dtype=jnp.int32).reshape(
                    (B,) + (1,) * (s.ndim - 1))
                return M[b, s, d]

            def pick1(V, s):  # V[B, n]
                b = jnp.arange(B, dtype=jnp.int32).reshape(
                    (B,) + (1,) * (s.ndim - 1))
                return V[b, s]

        else:
            comp_sl = jnp.broadcast_to(comp, (B, n))
            active = None

            def pick2(M, s, d):
                return M[s, d]

            def pick1(V, s):
                return V[s]

        def reach_all(take_idx, seg_src, present):
            # frontier propagation from vertex 0 along present arcs
            r0 = jnp.zeros((B, n), dtype=lat.dtype).at[:, 0].set(1.0)

            def body(_, r):
                vals = jnp.take_along_axis(r, take_idx, axis=1) * present
                hop = jax.ops.segment_max(
                    vals.ravel(), seg_src, num_segments=B * n
                ).reshape(B, n)
                return jnp.maximum(r, hop)

            return jax.lax.fori_loop(0, max(n - 1, 0), body, r0)

        def score(a_src, a_dst, a_act):
            present = a_act & pick2(allowed, a_src, a_dst) & (a_src != a_dst)
            pf = present.astype(lat.dtype)
            seg_dst = (boff + a_dst).ravel()
            seg_src = (boff + a_src).ravel()
            out_deg = jax.ops.segment_sum(
                pf.ravel(), seg_src, num_segments=B * n
            ).reshape(B, n)
            in_deg = jax.ops.segment_sum(
                pf.ravel(), seg_dst, num_segments=B * n
            ).reshape(B, n)
            od = jnp.take_along_axis(out_deg, a_src, axis=1)
            idg = jnp.take_along_axis(in_deg, a_dst, axis=1)
            rate = jnp.minimum(
                jnp.minimum(
                    pick1(up, a_src) / jnp.maximum(od, 1.0),
                    pick1(dn, a_dst) / jnp.maximum(idg, 1.0),
                ),
                pick2(bw, a_src, a_dst),
            )
            warc = pick1(comp, a_src) + pick2(lat, a_src, a_dst) \
                + model_mbits / rate
            warc = jnp.where(present, warc, -INF)
            src_all = jnp.concatenate([a_src, sl], axis=1)
            dst_all = jnp.concatenate([a_dst, sl], axis=1)
            w_all = jnp.concatenate([warc, comp_sl], axis=1)
            # Feasible states bound present in-degree by delta_max (+1
            # self-loop, +1 single-move transient), so the degree-padded
            # kernel path is lossless; infeasible states are masked to
            # +inf below regardless of their tau.
            tau = batched_cycle_time_sparse_jax(
                src_all, dst_all, w_all, n, max_in_degree=delta_max + 2)
            fwd = reach_all(a_src, (boff + a_dst).ravel(), pf)
            bwd = reach_all(a_dst, (boff + a_src).ravel(), pf)
            reached = (fwd > 0) & (bwd > 0)
            if multi:
                strong = jnp.all(reached | ~active, axis=1)
            else:
                strong = jnp.all(reached, axis=1)
            deg_ok = jnp.all(out_deg <= delta_max, axis=1) & jnp.all(
                in_deg <= delta_max, axis=1
            )
            return jnp.where(strong & deg_ok, tau, INF)

        def step(t, carry):
            a_src, a_dst, a_act, tau, b_src, b_dst, b_act, btau, k = carry
            k, k1, k2, k3, k4, k5, k6, k7 = jax.random.split(k, 8)
            mtype = jax.random.randint(k1, (B,), 0, 4)
            is_add = mtype == 1
            is_drop = mtype == 2
            is_two = mtype == 3
            act_logits = jnp.where(a_act, 0.0, -INF)
            inact_logits = jnp.where(a_act, -INF, 0.0)
            slot_act = jax.random.categorical(k2, act_logits, axis=1)
            slot_inact = jax.random.categorical(k3, inact_logits, axis=1)
            slot = jnp.where(is_add, slot_inact, slot_act).astype(jnp.int32)
            rand_i = jax.random.randint(k4, (B,), 0, n, dtype=jnp.int32)
            rand_j = jax.random.randint(k5, (B,), 0, n, dtype=jnp.int32)
            if multi:  # sample endpoints among each universe's live nodes
                rand_i = rand_i % jnp.maximum(n_active, 1)
                rand_j = rand_j % jnp.maximum(n_active, 1)
            cur_src = a_src[rows, slot]
            cur_dst = a_dst[rows, slot]
            cur_act = a_act[rows, slot]
            new_src = jnp.where(is_add, rand_i, cur_src)
            new_dst = jnp.where(is_drop, cur_dst, rand_j)
            new_act = ~is_drop
            # Slot sanity (categorical over all -inf logits is garbage),
            # connectivity-graph membership, and arc uniqueness.
            slot_ok = jnp.where(is_add, ~cur_act, cur_act)
            arc_ok = (new_src != new_dst) & pick2(allowed, new_src, new_dst)
            dup = jnp.any(
                a_act
                & (a_src == new_src[:, None])
                & (a_dst == new_dst[:, None])
                & (slot_ids[None, :] != slot[:, None]),
                axis=1,
            )
            one_ok = slot_ok & (is_drop | (arc_ok & ~dup))
            p_src = a_src.at[rows, slot].set(new_src)
            p_dst = a_dst.at[rows, slot].set(new_dst)
            p_act = a_act.at[rows, slot].set(new_act)
            # 2-opt double rewire: slots (slot, slot2) holding (a, b) and
            # (c, d) exchange destinations -> (a, d), (c, b).
            slot2 = jax.random.categorical(k6, act_logits, axis=1).astype(
                jnp.int32)
            c_src = a_src[rows, slot2]
            c_dst = a_dst[rows, slot2]
            c_act = a_act[rows, slot2]

            def not_dup(ns, nd):
                return ~jnp.any(
                    a_act
                    & (a_src == ns[:, None])
                    & (a_dst == nd[:, None])
                    & (slot_ids[None, :] != slot[:, None])
                    & (slot_ids[None, :] != slot2[:, None]),
                    axis=1,
                )

            two_ok = (
                cur_act & c_act & (slot != slot2)
                & (cur_src != c_dst) & pick2(allowed, cur_src, c_dst)
                & (c_src != cur_dst) & pick2(allowed, c_src, cur_dst)
                & not_dup(cur_src, c_dst) & not_dup(c_src, cur_dst)
                & ~((cur_src == c_src) & (cur_dst == c_dst))
            )
            q_dst = a_dst.at[rows, slot].set(c_dst).at[rows, slot2].set(
                cur_dst)
            two = is_two[:, None]
            p_src = jnp.where(two, a_src, p_src)
            p_dst = jnp.where(two, q_dst, p_dst)
            p_act = jnp.where(two, a_act, p_act)
            ok = jnp.where(is_two, two_ok, one_ok)
            ptau = jnp.where(ok, score(p_src, p_dst, p_act), INF)
            better = ptau < tau
            # Metropolis acceptance on the relative-tau scale.
            frac = t.astype(lat.dtype) / lat.dtype.type(
                max(n_steps - 1, 1))
            temp = jnp.maximum(sa_t0 * (sa_t1 / sa_t0) ** frac, 1e-12)
            rel = (ptau - tau) / jnp.maximum(jnp.abs(tau), 1.0)
            u = jax.random.uniform(k7, (B,), dtype=lat.dtype)
            sa_ok = (
                (sa_t0 > 0)
                & jnp.isfinite(ptau)
                & jnp.isfinite(tau)
                & (u < jnp.exp(-rel / temp))
            )
            accept = (better | sa_ok)[:, None]
            a_src = jnp.where(accept, p_src, a_src)
            a_dst = jnp.where(accept, p_dst, a_dst)
            a_act = jnp.where(accept, p_act, a_act)
            tau = jnp.where(accept[:, 0], ptau, tau)
            record = ptau < btau
            rec = record[:, None]
            return (
                a_src, a_dst, a_act, tau,
                jnp.where(rec, p_src, b_src),
                jnp.where(rec, p_dst, b_dst),
                jnp.where(rec, p_act, b_act),
                jnp.where(record, ptau, btau),
                k,
            )

        tau0 = score(asrc, adst, aact)
        carry = (asrc, adst, aact, tau0,
                 asrc, adst, aact, tau0, key)
        _, _, _, _, b_src, b_dst, b_act, btau, _ = jax.lax.fori_loop(
            0, n_steps, step, carry
        )
        return b_src, b_dst, b_act, btau

    return jax.jit(climb, static_argnums=(11, 12))


def _rewire_climb_fn(multi: bool = False):
    key = "climb_multi" if multi else "climb"
    if key not in _REWIRE_JIT:
        _REWIRE_JIT[key] = _build_rewire_climb(multi)
    return _REWIRE_JIT[key]


def _degrees_ok(arcs: Sequence[Tuple[int, int]], n: int, delta: int) -> bool:
    out = np.zeros(n, dtype=np.int64)
    inn = np.zeros(n, dtype=np.int64)
    for (i, j) in arcs:
        out[i] += 1
        inn[j] += 1
    return bool(out.max(initial=0) <= delta and inn.max(initial=0) <= delta)


def _seed_states(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    index: Dict[Node, int],
    n_restarts: int,
    slots: int,
    delta_max: int,
    rng: np.random.Generator,
    incumbent: Optional[Overlay],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, List[List[Tuple[int, int]]]]:
    """Initial ``[B, S]`` arc-slot states for the rewire climb, plus the
    list of structured seed arc lists (for exact f64 re-pricing).

    Restart seeds: the incumbent overlay (if given), the Christofides
    ring, the bidirected MST, then random Hamiltonian rings.  Seeds
    violating the ``delta_max`` degree bound are skipped — they would
    score ``+inf`` forever and burn their restart's whole move budget.
    On a non-complete connectivity graph random rings routinely hit
    unrouted pairs (instant ``+inf``), so the remaining restarts cycle
    over the feasible seeds instead.
    """
    n = gc.num_silos
    seeds: List[List[Tuple[int, int]]] = []
    if incumbent is not None and all(
        i in index and j in index and gc.has_edge(i, j)
        for (i, j) in incumbent.edges
        if i != j
    ):  # churn / link failure can invalidate the incumbent's silos or arcs
        edges = sorted(
            {(index[i], index[j]) for (i, j) in incumbent.edges if i != j}
        )
        if 0 < len(edges) <= slots and _degrees_ok(edges, n, delta_max):
            seeds.append(edges)
    try:  # Christofides ring: the strongest cheap designer (Prop. 3.3)
        tour = christofides_tour(
            list(gc.silos), lambda i, j: symmetrized_delay_ms(gc, tp, i, j)
        )
        ring_arcs = [
            (index[tour[k]], index[tour[(k + 1) % len(tour)]])
            for k in range(len(tour))
        ]
        if all(
            gc.has_edge(gc.silos[a], gc.silos[b]) for (a, b) in ring_arcs
        ):
            seeds.append(ring_arcs)
    except (ValueError, KeyError):
        pass
    try:
        tree = mst_edges(gc, lambda i, j: symmetrized_delay_ms(gc, tp, i, j))
        mst_arcs = [(index[i], index[j]) for (i, j) in _bidir(tree)]
        if len(mst_arcs) <= slots and _degrees_ok(mst_arcs, n, delta_max):
            seeds.append(mst_arcs)
    except ValueError:
        pass
    full_mesh = len([1 for (i, j) in gc.latency_ms if i != j]) == n * (n - 1)
    asrc = np.zeros((n_restarts, slots), dtype=np.int32)
    adst = np.zeros((n_restarts, slots), dtype=np.int32)
    aact = np.zeros((n_restarts, slots), dtype=bool)
    for b in range(n_restarts):
        if b < len(seeds):
            arcs = seeds[b]
        elif full_mesh or not seeds:
            perm = rng.permutation(n)
            arcs = [
                (int(perm[k]), int(perm[(k + 1) % n])) for k in range(n)
            ]
        else:
            arcs = seeds[b % len(seeds)]
        m = len(arcs)
        asrc[b, :m] = [a for (a, _) in arcs]
        adst[b, :m] = [a for (_, a) in arcs]
        aact[b, :m] = True
    return asrc, adst, aact, seeds


def _reprice_candidates(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    candidates: List[List[Tuple[int, int]]],
    name: str,
) -> Overlay:
    """Exact f64 re-pricing of index-space candidate arc lists through
    the size-dispatched engine; returns the best strongly-connected one.

    The climbs accept moves by approximate (f32 / delta-certificate)
    score, so comparing the final candidates exactly here is what turns
    "never worse than the seeds" from approximate into exact."""
    if not candidates:
        raise ValueError(
            f"{name} search found no strongly-connected candidate")
    pool = sorted({a for arcs in candidates for a in arcs})
    pool_index = {a: k for k, a in enumerate(pool)}
    masks = np.zeros((len(candidates), len(pool)), dtype=bool)
    for c, arcs in enumerate(candidates):
        masks[c, [pool_index[a] for a in arcs]] = True
    pool_lbl = [(gc.silos[i], gc.silos[j]) for (i, j) in pool]
    eb = batched_overlay_delay_edges(gc, tp, pool_lbl, masks)
    strong = batched_is_strongly_connected_sparse(eb)
    taus = np.where(strong, batched_cycle_time_auto(eb), np.inf)
    k = int(np.argmin(taus))
    if not np.isfinite(taus[k]):
        raise ValueError(
            f"{name} search found no strongly-connected candidate")
    edges = tuple(pool_lbl[e] for e in np.nonzero(masks[k])[0])
    return Overlay(name=name, edges=edges, cycle_time_ms=float(taus[k]))


@span_fn("designer.search_jit")
def search_overlays_jit(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_restarts: int = 16,
    n_steps: int = 96,
    delta_max: int = 8,
    max_arcs: Optional[int] = None,
    seed: int = 0,
    incumbent: Optional[Overlay] = None,
    engine: str = "auto",
    sa_t0: float = 0.05,
    sa_t1: float = 1e-3,
) -> Overlay:
    """Device-side topology search: batched rewire hill climb with random
    restarts, scored by the sparse jitted max-plus engine.

    Unlike the designer heuristics (host-side graph algorithms) and the
    batched ring search (host-side candidate generation, batched
    scoring), here *candidate generation itself* runs on the device: each
    of ``n_restarts`` parallel states proposes one local move per step —
    rewire an arc endpoint, add an arc, or drop one — under per-silo
    degree (``delta_max``, in- and out-degree of the overlay, Sect. 3.2's
    node-capacitated motivation) and connectivity-graph membership
    constraints, scores all proposals with
    :func:`repro.core.maxplus_sparse.batched_cycle_time_sparse_jax`
    (Eq. 3 arc weights, including the degree-dependent access-link
    sharing, are rebuilt on device per proposal), and accepts
    improvements.  The whole search lowers to one ``lax.fori_loop`` XLA
    computation of O(B·n_steps·N·E) work — no host round trips, so it
    scales to thousands of silos where dense ``[B, N, N]`` scoring hits
    the memory wall.

    Parameters
    ----------
    gc, tp:
        Connectivity measurements and workload, as for every designer.
    n_restarts:
        Parallel hill-climb states.  Seeds, in order: the ``incumbent``
        (if any), the Christofides ring, the bidirected MST, then random
        Hamiltonian rings.  The ring seed is load-bearing: with it in
        the restart pool (and the exact f64 re-pricing below) the result
        is guaranteed never worse than the paper's RING designer.
    n_steps:
        Rewire moves proposed per restart (static: changing it triggers
        one recompile).
    delta_max:
        Max in-degree and out-degree per silo.
    max_arcs:
        Arc-slot capacity S (default ``2 N``): add moves beyond it are
        rejected, which also caps device memory at O(B·S).
    seed:
        Seeds both the restart rings and the device move stream.
    incumbent:
        Optional overlay to seed restart 0 from — the controller passes
        its active overlay so the search explores *local* repairs first.
    engine:
        ``"jit"`` runs the device climb below; ``"delta"`` delegates to
        :func:`search_overlays_delta` (host-side incremental pricing —
        no full Karp per proposal, so far more moves per second at
        large N); ``"auto"`` picks ``"jit"`` under
        :data:`_DELTA_ENGINE_MIN_N` silos and ``"delta"`` above, where
        per-proposal Karp is the bottleneck.
    sa_t0, sa_t1:
        Simulated-annealing start/end temperature on the relative-tau
        scale (geometric schedule); ``sa_t0 = 0`` disables annealing.
        The best state ever visited is tracked separately, so annealing
        only adds exploration.

    Returns
    -------
    The best of {climb result, structured seeds}, re-priced exactly (f64,
    size-dispatched engine) so the result is never worse than a feasible
    seed (``name="sparse_rewire"``).  Raises ``ValueError`` if neither
    the climb nor any seed reaches a strongly-connected, degree-feasible
    state.
    """
    n = gc.num_silos
    if n < 2:
        raise ValueError("sparse-rewire search needs at least 2 silos")
    if engine not in ("auto", "jit", "delta"):
        raise ValueError(f"unknown search engine {engine!r}")
    if engine == "delta" or (engine == "auto" and n >= _DELTA_ENGINE_MIN_N):
        import dataclasses

        found = search_overlays_delta(
            gc, tp,
            n_restarts=n_restarts,
            # Delta proposals cost O(deg), not a Karp pass: spend the
            # saved work on a deeper move budget per restart.
            n_steps=max(8 * n_steps, 256),
            delta_max=delta_max, max_arcs=max_arcs, seed=seed,
            incumbent=incumbent, sa_t0=sa_t0, sa_t1=sa_t1,
        )
        return dataclasses.replace(found, name="sparse_rewire")
    index = {v: k for k, v in enumerate(gc.silos)}
    slots = max(max_arcs if max_arcs is not None else 2 * n, n)
    if incumbent is not None:
        slots = max(slots, len({e for e in incumbent.edges if e[0] != e[1]}))
    lat = np.ones((n, n), dtype=np.float32)
    bw = np.ones((n, n), dtype=np.float32)
    allowed = np.zeros((n, n), dtype=bool)
    for (i, j), l in gc.latency_ms.items():
        if i == j:
            continue
        a, b = index[i], index[j]
        lat[a, b] = l
        bw[a, b] = gc.available_bw_gbps[(i, j)]
        allowed[a, b] = True
    comp = np.array(
        [tp.local_steps * gc.silo_params[v].comp_time_ms for v in gc.silos],
        dtype=np.float32,
    )
    up = np.array(
        [gc.silo_params[v].uplink_gbps for v in gc.silos], dtype=np.float32
    )
    dn = np.array(
        [gc.silo_params[v].downlink_gbps for v in gc.silos], dtype=np.float32
    )
    rng = np.random.default_rng(seed)
    asrc, adst, aact, seed_arcs = _seed_states(
        gc, tp, index, n_restarts, slots, delta_max, rng, incumbent
    )
    import jax

    a_src, a_dst, a_act, tau = _rewire_climb_fn()(
        lat, bw, allowed, comp, up, dn, np.float32(tp.model_size_mbits),
        asrc, adst, aact, jax.random.PRNGKey(seed),
        int(n_steps), int(delta_max),
        np.float32(sa_t0), np.float32(sa_t1),
    )
    # One batched device->host transfer instead of four implicit syncs.
    a_src, a_dst, a_act, tau = jax.device_get((a_src, a_dst, a_act, tau))
    best = int(np.argmin(tau))
    candidates: List[List[Tuple[int, int]]] = []
    if np.isfinite(tau[best]):
        b_src = a_src[best]
        b_dst = a_dst[best]
        keep = a_act[best] & (b_src != b_dst) & allowed[b_src, b_dst]
        candidates.append(
            [(int(i), int(j)) for (i, j) in zip(b_src[keep], b_dst[keep])]
        )
    candidates.extend(seed_arcs)
    return _reprice_candidates(gc, tp, candidates, "sparse_rewire")


# ---------------------------------------------------------------------------
# Delta-evaluated host climb (DeltaPricer-backed)

# Below this many silos the fully-jitted device climb is cheaper than
# host-side proposal bookkeeping; above it, per-proposal Karp dominates
# and the O(deg) delta pricer wins by orders of magnitude.
_DELTA_ENGINE_MIN_N = 384


def _strong_arcs(n: int, arcs: Iterable[Tuple[int, int]]) -> bool:
    """Strong connectivity of an index-space arc set (host BFS both ways)."""
    adj: List[List[int]] = [[] for _ in range(n)]
    radj: List[List[int]] = [[] for _ in range(n)]
    for (u, v) in arcs:
        adj[u].append(v)
        radj[v].append(u)

    def full(a: List[List[int]]) -> bool:
        seen = bytearray(n)
        seen[0] = 1
        stack = [0]
        count = 1
        while stack:
            x = stack.pop()
            for y in a[x]:
                if not seen[y]:
                    seen[y] = 1
                    count += 1
                    stack.append(y)
        return count == n

    return full(adj) and full(radj)


@span_fn("designer.search_delta")
def search_overlays_delta(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_restarts: int = 4,
    n_steps: int = 768,
    delta_max: int = 8,
    max_arcs: Optional[int] = None,
    seed: int = 0,
    incumbent: Optional[Overlay] = None,
    pricing: str = "delta",
    reanchor_every: int = 1024,
    sa_t0: float = 0.05,
    sa_t1: float = 1e-3,
    stats_out: Optional[Dict[str, int]] = None,
) -> Overlay:
    """Host-side rewire search with **delta-evaluated** cycle-time
    pricing (:class:`repro.core.maxplus_sparse.DeltaPricer`).

    Same move set as the jitted climb — endpoint swap, arc add, arc
    drop, 2-opt double rewire — and the same simulated-annealing
    acceptance, but each proposal is priced incrementally: the pricer
    keeps per-node longest-path potentials and a critical circuit as a
    certificate of the current tau, so a move that touches O(deg) arcs
    re-prices in O(deg) instead of a full O(N·E) Karp pass.  Weight
    maintenance is incremental too: a move perturbs silo degrees, and
    only the arcs incident to those silos re-derive their Eq. 3 delay
    (the degree-dependent access-link sharing term).  Together this is
    what pushes the feasible search size from ~10^3 to ~10^4 silos.

    ``pricing="full"`` forces the full-Karp oracle on every proposal —
    the benchmark's baseline arm for the >= 5x proposals/s acceptance
    gate.  ``reanchor_every`` bounds certificate drift by rebuilding it
    from scratch every K accepted moves (with the default f64 pricer
    the fast paths are already bit-exact; the knob exists for f32
    pricers and as a belt-and-suspenders invariant).  ``stats_out``
    (optional dict) receives proposal/accept counters and the pricer's
    fast/propagated/reanchor path counts.

    Returns the best of {per-restart best states, structured seeds},
    re-priced exactly like every other search (``name="delta_rewire"``).
    """
    n = gc.num_silos
    if n < 2:
        raise ValueError("delta-rewire search needs at least 2 silos")
    if pricing not in ("delta", "full"):
        raise ValueError(f"unknown pricing mode {pricing!r}")
    index = {v: k for k, v in enumerate(gc.silos)}
    slots = max(max_arcs if max_arcs is not None else 2 * n, n)
    if incumbent is not None:
        slots = max(slots, len({e for e in incumbent.edges if e[0] != e[1]}))
    latd: Dict[Tuple[int, int], Tuple[float, float]] = {}
    nbr: List[List[int]] = [[] for _ in range(n)]
    for (i, j), l in gc.latency_ms.items():
        if i == j:
            continue
        a, b = index[i], index[j]
        # host dict of python floats: nothing here touches a device
        latd[(a, b)] = (float(l), float(gc.available_bw_gbps[(i, j)]))  # repro-lint: ignore[effect-purity]
        nbr[a].append(b)
    nbrs = [
        np.array(v, dtype=np.int64) if v else np.empty(0, dtype=np.int64)
        for v in nbr
    ]
    comp = np.array(
        [tp.local_steps * gc.silo_params[v].comp_time_ms for v in gc.silos],
        dtype=np.float64,
    )
    up = np.array(
        [gc.silo_params[v].uplink_gbps for v in gc.silos], dtype=np.float64
    )
    dn = np.array(
        [gc.silo_params[v].downlink_gbps for v in gc.silos], dtype=np.float64
    )
    mbits = float(tp.model_size_mbits)

    def arc_w(u: int, v: int, od: int, idg: int) -> float:
        # Same expressions in the same order as batched_overlay_delay_edges
        # so search-time weights match the exact re-pricing bit-for-bit.
        l, bwv = latd[(u, v)]
        rate = min(min(up[u] / max(od, 1.0), dn[v] / max(idg, 1.0)), bwv)
        return comp[u] + l + mbits / rate

    rng = np.random.default_rng(seed)
    asrc, adst, aact, seed_arcs = _seed_states(
        gc, tp, index, n_restarts, slots, delta_max, rng, incumbent
    )
    totals = {"proposals": 0, "accepts": 0, "fast": 0, "propagated": 0,
              "reanchor": 0}
    candidates: List[List[Tuple[int, int]]] = []
    for b in range(n_restarts):
        arcs0: List[Tuple[int, int]] = []
        seen: Set[Tuple[int, int]] = set()
        for s, d, a in zip(asrc[b], adst[b], aact[b]):
            arc = (int(s), int(d))
            # Random-ring seeds may propose unrouted pairs on sparse
            # connectivity graphs; the climb starts from the routable
            # subset and reconnects through add moves.
            if a and arc in latd and arc not in seen:
                seen.add(arc)
                arcs0.append(arc)
        best = _delta_climb_one(
            n, slots, arcs0, latd, nbrs, arc_w, comp, delta_max,
            int(n_steps), rng, pricing, int(reanchor_every),
            float(sa_t0), float(sa_t1), totals,  # repro-lint: ignore[effect-purity]
        )
        if best is not None:
            candidates.append(best)
    candidates.extend(seed_arcs)
    if stats_out is not None:
        stats_out.update(totals)
    return _reprice_candidates(gc, tp, candidates, "delta_rewire")


def _delta_climb_one(
    n: int,
    slots: int,
    arcs0: List[Tuple[int, int]],
    latd: Dict[Tuple[int, int], Tuple[float, float]],
    nbrs: List[np.ndarray],
    arc_w: Callable[[int, int, int, int], float],
    comp: np.ndarray,
    delta_max: int,
    n_steps: int,
    rng: np.random.Generator,
    pricing: str,
    reanchor_every: int,
    sa_t0: float,
    sa_t1: float,
    totals: Dict[str, int],
) -> Optional[List[Tuple[int, int]]]:
    """One delta-priced annealing climb; returns the best feasible arc
    list found (index space), or None if no strongly-connected state was
    ever visited."""
    S = slots
    ssrc = np.zeros(S + n, dtype=np.int64)
    sdst = np.zeros(S + n, dtype=np.int64)
    sw = np.full(S + n, NEG_INF, dtype=np.float64)
    # Self-loop slots S..S+n-1 carry the computation delays (Eq. 3's
    # always-present diagonal) and never move.
    ssrc[S:] = np.arange(n)
    sdst[S:] = np.arange(n)
    sw[S:] = comp
    out_deg = np.zeros(n, dtype=np.int64)
    in_deg = np.zeros(n, dtype=np.int64)
    out_slots: List[Set[int]] = [set() for _ in range(n)]
    in_slots: List[Set[int]] = [set() for _ in range(n)]
    arc_slot: Dict[Tuple[int, int], int] = {}
    for s, (u, v) in enumerate(arcs0):
        ssrc[s], sdst[s] = u, v
        out_deg[u] += 1
        in_deg[v] += 1
        out_slots[u].add(s)
        in_slots[v].add(s)
        arc_slot[(u, v)] = s
    for s, (u, v) in enumerate(arcs0):
        sw[s] = arc_w(u, v, int(out_deg[u]), int(in_deg[v]))
    free = list(range(S - 1, len(arcs0) - 1, -1))  # stack of empty slots
    act_list: List[int] = list(range(len(arcs0)))
    act_pos: Dict[int, int] = {s: k for k, s in enumerate(act_list)}

    def act_add(s: int) -> None:
        act_pos[s] = len(act_list)
        act_list.append(s)

    def act_remove(s: int) -> None:
        i = act_pos.pop(s)
        last = act_list.pop()
        if last != s:
            act_list[i] = last
            act_pos[last] = i

    dp = DeltaPricer(ssrc, sdst, sw, n)
    cur_strong = _strong_arcs(n, arc_slot.keys())
    best_arcs = list(arc_slot.keys()) if cur_strong else None
    btau = dp.tau if cur_strong else np.inf
    accepts = 0
    denom = float(max(n_steps - 1, 1))
    force_full = pricing == "full"

    def reweight(upd, dout, din, moved):
        """Re-derive Eq. 3 weights of arcs incident to degree changes."""
        for node, dd in dout.items():
            if dd:
                for s2 in out_slots[node]:
                    if s2 in moved:
                        continue
                    uu, vv = int(ssrc[s2]), int(sdst[s2])
                    upd[s2] = (uu, vv, arc_w(
                        uu, vv,
                        int(out_deg[uu]) + dout.get(uu, 0),
                        int(in_deg[vv]) + din.get(vv, 0)))
        for node, dd in din.items():
            if dd:
                for s2 in in_slots[node]:
                    if s2 in moved:
                        continue
                    uu, vv = int(ssrc[s2]), int(sdst[s2])
                    upd[s2] = (uu, vv, arc_w(
                        uu, vv,
                        int(out_deg[uu]) + dout.get(uu, 0),
                        int(in_deg[vv]) + din.get(vv, 0)))

    for t in range(n_steps):
        totals["proposals"] += 1
        mtype = int(rng.integers(0, 4))
        upd: Dict[int, Tuple[int, int, float]] = {}
        dout: Dict[int, int] = {}
        din: Dict[int, int] = {}
        structural = True  # does the move remove/redirect any arc?
        if mtype == 0:  # endpoint swap: (u, v) -> (u, v2)
            if not act_list:
                continue
            s = act_list[int(rng.integers(len(act_list)))]
            u, v = int(ssrc[s]), int(sdst[s])
            cand = nbrs[u]
            if cand.size == 0:
                continue
            v2 = int(cand[int(rng.integers(cand.size))])
            if v2 == v or v2 == u or (u, v2) in arc_slot:
                continue
            if in_deg[v2] + 1 > delta_max:
                continue
            din[v] = din.get(v, 0) - 1
            din[v2] = din.get(v2, 0) + 1
            reweight(upd, dout, din, {s})
            upd[s] = (u, v2, arc_w(
                u, v2, int(out_deg[u]), int(in_deg[v2]) + 1))
            removed, added = ((u, v),), ((u, v2),)
        elif mtype == 1:  # add
            if not free:
                continue
            u = int(rng.integers(n))
            cand = nbrs[u]
            if cand.size == 0:
                continue
            v = int(cand[int(rng.integers(cand.size))])
            if (u, v) in arc_slot:
                continue
            if out_deg[u] + 1 > delta_max or in_deg[v] + 1 > delta_max:
                continue
            s = free[-1]
            dout[u] = 1
            din[v] = 1
            reweight(upd, dout, din, {s})
            upd[s] = (u, v, arc_w(
                u, v, int(out_deg[u]) + 1, int(in_deg[v]) + 1))
            removed, added = (), ((u, v),)
            structural = False  # adds cannot disconnect
        elif mtype == 2:  # drop
            if len(act_list) <= 1:
                continue
            s = act_list[int(rng.integers(len(act_list)))]
            u, v = int(ssrc[s]), int(sdst[s])
            dout[u] = -1
            din[v] = -1
            reweight(upd, dout, din, {s})
            upd[s] = (u, v, NEG_INF)
            removed, added = ((u, v),), ()
        else:  # 2-opt: (a, b), (c, d) -> (a, d), (c, b); degree-neutral
            if len(act_list) < 2:
                continue
            s1 = act_list[int(rng.integers(len(act_list)))]
            s2 = act_list[int(rng.integers(len(act_list)))]
            if s1 == s2:
                continue
            a, bb = int(ssrc[s1]), int(sdst[s1])
            c, d = int(ssrc[s2]), int(sdst[s2])
            if a == d or c == bb:
                continue
            if (a, d) in arc_slot or (c, bb) in arc_slot:
                continue  # also rejects the degenerate b==d / a==c swaps
            if (a, d) not in latd or (c, bb) not in latd:
                continue
            upd[s1] = (a, d, arc_w(a, d, int(out_deg[a]), int(in_deg[d])))
            upd[s2] = (c, bb, arc_w(c, bb, int(out_deg[c]), int(in_deg[bb])))
            removed, added = ((a, bb), (c, d)), ((a, d), (c, bb))
        slots_arr = np.fromiter(upd.keys(), dtype=np.int64, count=len(upd))
        su = np.fromiter((x[0] for x in upd.values()), dtype=np.int64,
                         count=len(upd))
        du = np.fromiter((x[1] for x in upd.values()), dtype=np.int64,
                         count=len(upd))
        wu = np.fromiter((x[2] for x in upd.values()), dtype=np.float64,
                         count=len(upd))
        pm = dp.price(slots_arr, su, du, wu, force_full=force_full)
        dtau = pm.tau - dp.tau
        accept = dtau < 0
        if not accept and sa_t0 > 0:
            temp = max(sa_t0 * (sa_t1 / sa_t0) ** (t / denom), 1e-12)
            rel = dtau / max(abs(dp.tau), 1.0)
            accept = rng.random() < math.exp(-min(rel / temp, 700.0))
        if not accept:
            continue
        if structural or not cur_strong:
            rm = set(removed)
            new_arcs = [x for x in arc_slot if x not in rm]
            new_arcs.extend(added)
            strong2 = _strong_arcs(n, new_arcs)
            if cur_strong and not strong2:
                continue  # never walk out of the feasible region
            cur_strong = strong2
        dp.commit(pm)
        totals["accepts"] += 1
        accepts += 1
        # apply bookkeeping for the moved slots
        for s, (uu, vv, ww) in upd.items():
            ou, ov = int(ssrc[s]), int(sdst[s])
            was = bool(np.isfinite(sw[s]))
            now = bool(np.isfinite(ww))
            if was and (not now or (ou, ov) != (uu, vv)):
                out_slots[ou].discard(s)
                in_slots[ov].discard(s)
                arc_slot.pop((ou, ov), None)
                if not now:
                    act_remove(s)
                    free.append(s)
            if now and (not was or (ou, ov) != (uu, vv)):
                out_slots[uu].add(s)
                in_slots[vv].add(s)
                arc_slot[(uu, vv)] = s
                if not was:
                    act_add(s)
                    if free and free[-1] == s:
                        free.pop()
            ssrc[s], sdst[s], sw[s] = uu, vv, ww
        for node, dd in dout.items():
            out_deg[node] += dd
        for node, dd in din.items():
            in_deg[node] += dd
        if reanchor_every > 0 and accepts % reanchor_every == 0:
            dp.reanchor()
        if cur_strong and dp.tau < btau:
            btau = dp.tau
            best_arcs = list(arc_slot.keys())
    totals["fast"] += dp.stats["fast"]
    totals["propagated"] += dp.stats["propagated"]
    totals["reanchor"] += dp.stats["reanchor"]
    return best_arcs


# ---------------------------------------------------------------------------
# Hierarchical decomposition (cluster -> intra-cluster searches batched in
# one multi-universe climb -> inter-cluster ring -> exact composition price)


def cluster_silos(
    gc: ConnectivityGraph,
    *,
    n_clusters: Optional[int] = None,
    labels: Optional[Union[Mapping[Node, Hashable], Sequence[Hashable]]] = None,
    seed: int = 0,
) -> List[List[Node]]:
    """Partition the silos into delay clusters.

    With ``labels`` (a mapping silo -> label, or a sequence aligned with
    ``gc.silos`` — e.g. geographic regions), clusters are the label
    groups, ordered by label.  Otherwise clusters come from
    farthest-point medoid seeding on the symmetrized latency (a missing
    pair counts as infinitely far, so disconnected components separate
    first) with nearest-medoid assignment; ``n_clusters`` defaults to
    ``round(sqrt(N))`` — the balance point where both the intra searches
    and the inter-cluster ring stay ~sqrt(N)-sized.  Within each
    cluster, silo order follows ``gc.silos``.
    """
    silos = list(gc.silos)
    n = len(silos)
    if labels is not None:
        if isinstance(labels, Mapping):
            lab = [labels[v] for v in silos]
        else:
            lab = list(labels)
            if len(lab) != n:
                raise ValueError(
                    f"labels: expected {n} entries, got {len(lab)}")
        groups: Dict[Hashable, List[Node]] = {}
        for v, l in zip(silos, lab):
            groups.setdefault(l, []).append(v)
        keys = list(groups)
        try:
            keys.sort()
        except TypeError:  # mixed/incomparable labels
            keys.sort(key=repr)
        return [groups[k] for k in keys]
    k = int(n_clusters) if n_clusters is not None else max(
        1, int(round(math.sqrt(n))))
    k = min(max(k, 1), n)
    if k <= 1:
        return [silos]
    index = {v: i for i, v in enumerate(silos)}
    D = np.full((n, n), np.inf, dtype=np.float64)
    np.fill_diagonal(D, 0.0)
    for (i, j), l in gc.latency_ms.items():
        if i == j:
            continue
        a, b = index[i], index[j]
        D[a, b] = min(D[a, b], float(l))  # repro-lint: ignore[effect-purity]
        D[b, a] = min(D[b, a], float(l))  # repro-lint: ignore[effect-purity]
    rng = np.random.default_rng(seed)
    meds = [int(rng.integers(n))]
    dmin = D[meds[0]].copy()
    for _ in range(k - 1):
        nxt = int(np.argmax(dmin))
        meds.append(nxt)
        dmin = np.minimum(dmin, D[nxt])
    assign = np.argmin(D[:, meds], axis=1)
    out = [[silos[i] for i in range(n) if int(assign[i]) == c]
           for c in range(k)]
    return [c for c in out if c]


def _subgraph(gc: ConnectivityGraph, nodes: Sequence[Node]) -> ConnectivityGraph:
    """Connectivity restricted to ``nodes`` (order preserved)."""
    keep = set(nodes)
    return ConnectivityGraph(
        tuple(nodes),
        {k: v for k, v in gc.latency_ms.items()
         if k[0] in keep and k[1] in keep},
        {k: v for k, v in gc.available_bw_gbps.items()
         if k[0] in keep and k[1] in keep},
        {v: gc.silo_params[v] for v in nodes},
    )


def _cluster_medoid(gc: ConnectivityGraph, members: Sequence[Node]) -> Node:
    """The member minimizing total round-trip latency to the others
    (unrouted pairs count as a large constant, so well-connected silos
    win)."""
    if len(members) == 1:
        return members[0]
    best: Optional[Tuple[float, int]] = None
    for k, a in enumerate(members):
        tot = 0.0
        for b in members:
            if a == b:
                continue
            la = gc.latency_ms.get((a, b))
            lb = gc.latency_ms.get((b, a))
            tot += ((float(la) + float(lb))  # repro-lint: ignore[effect-purity]
                    if la is not None and lb is not None else 1e9)
        if best is None or tot < best[0]:
            best = (tot, k)
    return members[best[1]]


@span_fn("designer.search_hierarchical")
def search_overlays_hierarchical(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    n_clusters: Optional[int] = None,
    labels: Optional[Union[Mapping[Node, Hashable], Sequence[Hashable]]] = None,
    n_restarts: int = 2,
    n_steps: int = 64,
    delta_max: int = 8,
    seed: int = 0,
    incumbent: Optional[Overlay] = None,
    sa_t0: float = 0.05,
    sa_t1: float = 1e-3,
) -> Overlay:
    """Hierarchical topology search: cluster the silos by delay (or by
    the caller's ``labels``), search every cluster's internal overlay,
    compose with an inter-cluster ring, and price the composition
    exactly.

    The intra-cluster searches are *batched*: each cluster's
    connectivity sub-problem is padded to the largest cluster size and
    packed as ``n_restarts`` universes of one multi-universe rewire
    climb (:func:`_build_rewire_climb` with ``multi=True``) — every
    cluster's search runs in a single device call, so the decomposition
    costs one O(B · n_steps · nmax · S) climb instead of one climb per
    cluster.  Cluster work scales with ``nmax ~ N / k`` rather than
    ``N``, which is what makes ~10^4-silo design tractable: with
    ``k ~ sqrt(N)`` clusters the intra climbs cost
    O(n_steps · N^1.5) total.

    Intra-cluster searches run under ``max(2, delta_max - 1)`` so the
    silos chosen as cluster borders keep degree headroom; the
    inter-cluster ring visits clusters in Christofides order over their
    medoids and joins consecutive clusters through their cheapest
    bidirectionally-routed border pair (``ValueError`` if two adjacent
    clusters share no such pair).  The composed overlay is re-priced
    by the exact f64 engine (``name="hierarchical"``), with the
    ``incumbent`` (when still routable) competing as a candidate so a
    controller redesign can never regress below it.
    """
    n = gc.num_silos
    if n < 2:
        raise ValueError("hierarchical search needs at least 2 silos")
    if incumbent is None and n <= 512:
        # At sizes where the O(n^2) Christofides build is cheap, seed
        # the global ring as the incumbent: it competes in the final
        # exact pricing, so the decomposition can never lose to the
        # paper's RING on a small problem (on sparse connectivity the
        # ring may be unroutable — its inf price just loses).
        try:
            incumbent = ring_overlay(gc, tp)
        except (KeyError, ValueError):
            pass
    clusters = cluster_silos(
        gc, n_clusters=n_clusters, labels=labels, seed=seed)
    index = {v: k for k, v in enumerate(gc.silos)}
    if len(clusters) <= 1:
        import dataclasses

        found = search_overlays_jit(
            gc, tp, n_restarts=max(n_restarts, 4), n_steps=n_steps,
            delta_max=delta_max, seed=seed, incumbent=incumbent,
            sa_t0=sa_t0, sa_t1=sa_t1)
        return dataclasses.replace(found, name="hierarchical")
    delta_intra = max(2, delta_max - 1)
    rng = np.random.default_rng(seed)
    multi = [c for c in clusters if len(c) >= 2]
    intra_arcs: List[Tuple[Node, Node]] = []
    if multi:
        nmax = max(len(c) for c in multi)
        slots = 2 * nmax
        U = len(multi) * n_restarts
        latA = np.ones((U, nmax, nmax), dtype=np.float32)
        bwA = np.ones((U, nmax, nmax), dtype=np.float32)
        alA = np.zeros((U, nmax, nmax), dtype=bool)
        compA = np.full((U, nmax), NEG_INF, dtype=np.float32)
        upA = np.ones((U, nmax), dtype=np.float32)
        dnA = np.ones((U, nmax), dtype=np.float32)
        asrcA = np.zeros((U, slots), dtype=np.int32)
        adstA = np.zeros((U, slots), dtype=np.int32)
        aactA = np.zeros((U, slots), dtype=bool)
        subs: List[Tuple[ConnectivityGraph, List[List[Tuple[int, int]]]]] = []
        for ci, members in enumerate(multi):
            sub = _subgraph(gc, members)
            m = sub.num_silos
            sidx = {v: k for k, v in enumerate(sub.silos)}
            u0 = ci * n_restarts
            sl = slice(u0, u0 + n_restarts)
            for (i, j), l in sub.latency_ms.items():
                if i == j:
                    continue
                a, b = sidx[i], sidx[j]
                latA[sl, a, b] = l
                bwA[sl, a, b] = sub.available_bw_gbps[(i, j)]
                alA[sl, a, b] = True
            compA[sl, :m] = [
                tp.local_steps * sub.silo_params[v].comp_time_ms
                for v in sub.silos
            ]
            upA[sl, :m] = [sub.silo_params[v].uplink_gbps for v in sub.silos]
            dnA[sl, :m] = [sub.silo_params[v].downlink_gbps for v in sub.silos]
            inc = None
            if incumbent is not None:
                mem = set(members)
                proj = tuple(
                    (i, j) for (i, j) in incumbent.edges
                    if i in mem and j in mem and i != j
                )
                if proj:
                    inc = Overlay(
                        name="incumbent", edges=proj, cycle_time_ms=np.inf)
            a_s, a_d, a_a, s_arcs = _seed_states(
                sub, tp, sidx, n_restarts, slots, delta_intra, rng, inc)
            asrcA[sl], adstA[sl], aactA[sl] = a_s, a_d, a_a
            subs.append((sub, s_arcs))
        import jax

        res = _rewire_climb_fn(multi=True)(
            latA, bwA, alA, compA, upA, dnA,
            np.float32(tp.model_size_mbits),
            asrcA, adstA, aactA, jax.random.PRNGKey(seed),
            int(n_steps), int(delta_intra),
            np.float32(sa_t0), np.float32(sa_t1),
        )
        b_src, b_dst, b_act, tauU = jax.device_get(res)
        for ci, (sub, s_arcs) in enumerate(subs):
            u0 = ci * n_restarts
            k = u0 + int(np.argmin(tauU[u0:u0 + n_restarts]))
            cands: List[List[Tuple[int, int]]] = []
            if np.isfinite(tauU[k]):
                bs, bd, ba = b_src[k], b_dst[k], b_act[k]
                keep = ba & (bs != bd) & alA[k, bs, bd]
                cands.append(
                    [(int(i), int(j)) for (i, j) in zip(bs[keep], bd[keep])])
            cands.extend(s_arcs)
            best = _reprice_candidates(sub, tp, cands, "hierarchical_intra")
            intra_arcs.extend(best.edges)
    medoids = [_cluster_medoid(gc, c) for c in clusters]
    med_ci = {m: ci for ci, m in enumerate(medoids)}
    try:
        tour = christofides_tour(
            medoids, lambda i, j: symmetrized_delay_ms(gc, tp, i, j))
        order = [med_ci[m] for m in tour]
    except (KeyError, ValueError):
        order = list(range(len(clusters)))  # sparse medoid mesh: keep order
    inter: Set[Tuple[Node, Node]] = set()
    for k in range(len(order)):
        A = clusters[order[k]]
        B = clusters[order[(k + 1) % len(order)]]
        best_pair: Optional[Tuple[float, Node, Node]] = None
        for a in A:
            for b in B:
                if gc.has_edge(a, b) and gc.has_edge(b, a):
                    c = (float(gc.latency_ms[(a, b)])  # repro-lint: ignore[effect-purity]
                         + float(gc.latency_ms[(b, a)]))  # repro-lint: ignore[effect-purity]
                    if best_pair is None or c < best_pair[0]:
                        best_pair = (c, a, b)
        if best_pair is None:
            raise ValueError(
                "hierarchical search: no bidirectionally-routed border "
                f"pair between clusters {order[k]} and "
                f"{order[(k + 1) % len(order)]}")
        inter.add((best_pair[1], best_pair[2]))
        inter.add((best_pair[2], best_pair[1]))
    composed = sorted(
        {(index[i], index[j])
         for (i, j) in itertools.chain(intra_arcs, inter) if i != j})
    candidates = [composed]
    if incumbent is not None and all(
        i in index and j in index and gc.has_edge(i, j)
        for (i, j) in incumbent.edges if i != j
    ):
        candidates.append(sorted(
            {(index[i], index[j]) for (i, j) in incumbent.edges if i != j}))
    return _reprice_candidates(gc, tp, candidates, "hierarchical")


# ---------------------------------------------------------------------------
# Registry used by benchmarks / launcher


@span_fn("designer.design_overlay")
def design_overlay(
    kind: str,
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    center: Optional[Node] = None,
) -> Overlay:
    """Run one named designer on (``gc``, ``tp``) and return its
    :class:`Overlay`.

    ``kind`` is one of :data:`OVERLAY_KINDS`: ``star``, ``mst``,
    ``ring``, ``ring_2opt``, ``delta_mbst`` (Algorithm 1),
    ``sparse_rewire`` (the rewire search behind its size-dispatched
    engine), ``delta_rewire`` (the host delta-priced climb, forced), or
    ``hierarchical`` (cluster / compose); ``center`` pins the STAR
    orchestrator.  The registry the benchmarks, launcher, and
    controller all design through."""
    kind = kind.lower()
    if kind == "star":
        return star_overlay(gc, tp, center=center)
    if kind == "mst":
        return mst_overlay(gc, tp)
    if kind == "ring":
        return ring_overlay(gc, tp)
    if kind == "ring_2opt":
        return two_opt_ring_overlay(gc, tp)
    if kind in ("delta_mbst", "dmbst"):
        return algorithm1_mbst(gc, tp)
    if kind in ("sparse_rewire", "sparse-rewire"):
        return search_overlays_jit(gc, tp)
    if kind in ("delta_rewire", "delta-rewire"):
        return search_overlays_delta(gc, tp)
    if kind == "hierarchical":
        return search_overlays_hierarchical(gc, tp)
    raise KeyError(f"unknown overlay kind {kind!r}")


OVERLAY_KINDS = (
    "star", "mst", "delta_mbst", "ring", "ring_2opt", "sparse_rewire",
    "delta_rewire", "hierarchical",
)


def design_schedule(
    kind: str,
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    center: Optional[Node] = None,
    budgets: Optional[Sequence[float]] = None,
    rounds: int = 150,
    seeds: Sequence[int] = (0, 1, 2),
    sample_seed: int = 0,
    objective: str = "tau",
    mixing_rounds: int = 128,
):
    """Run one named designer and return a :class:`repro.core.schedule.Schedule`.

    The schedule-valued superset of :func:`design_overlay`: every
    :data:`OVERLAY_KINDS` designer is wrapped in a
    :class:`~repro.core.schedule.FixedSchedule`, and ``kind="matcha"``
    runs the randomized designer — a budget sweep
    (:func:`~repro.core.schedule.design_matcha_schedule`) that prices
    every budget × seed Monte-Carlo chain through the batched sparse
    engine in one call and returns the budget minimizing ``objective``
    (``"tau"``: mean τ̄; ``"time_to_eps"``: the composite
    ``τ̄ / −log(ρ)`` with ρ the expected contraction over
    ``mixing_rounds`` sampled rounds — see :mod:`repro.core.mixing`).
    ``budgets``/``rounds``/``seeds``/``sample_seed``/``objective``
    parameterize the sweep; fixed kinds design by cycle time alone
    (the fixed-vs-randomized arbitration under an objective lives in
    :func:`repro.dynamics.controller.design_best_schedule`).
    """
    from .schedule import (
        DEFAULT_MATCHA_BUDGETS,
        FixedSchedule,
        design_matcha_schedule,
    )

    kind = kind.lower()
    if kind == "matcha":
        schedule, _ = design_matcha_schedule(
            gc,
            tp,
            budgets=DEFAULT_MATCHA_BUDGETS if budgets is None else budgets,
            rounds=rounds,
            seeds=seeds,
            sample_seed=sample_seed,
            objective=objective,
            mixing_rounds=mixing_rounds,
        )
        return schedule
    return FixedSchedule(design_overlay(kind, gc, tp, center=center))


SCHEDULE_KINDS = OVERLAY_KINDS + ("matcha",)
