"""Schedules: deterministic overlays and randomized plan distributions
behind one pricing API (Sect. 4 / Appendix G.3).

The paper prices a *fixed* overlay by its max cycle mean and MATCHA — a
*distribution* over per-round topologies — by simulation (footnote 6).
Until now those lived in different worlds: overlays flowed through the
batched engines, dynamics, and the gossip runtime, while MATCHA was a
scalar ``random.Random`` dict loop invisible to all of them.  This
module makes both first-class :class:`Schedule` objects:

* :class:`FixedSchedule`     — a designed :class:`~repro.core.topologies.Overlay`;
  every round uses the same edges, pricing is the exact Karp cycle time.
* :class:`MatchaSchedule`    — MATCHA(+)'s budget-parameterized matching
  activation [104]: each round independently activates every matching
  w.p. ``budget`` (resampling empty rounds, Appendix G.3).  Pricing is
  Monte-Carlo τ̄ with a confidence interval, fully batched: activation
  masks ``[R, M]`` over the matchings, per-round Eq. 3 arc pricing via
  :func:`~repro.core.maxplus_sparse.batched_overlay_delay_edges` (degrees
  — and access-link sharing — recomputed per round), and the
  round-varying edge-list timing recursion
  :func:`~repro.core.maxplus_sparse.timing_recursion_time_varying_sparse`
  — one engine call for a whole budgets × seeds sweep.  Seeded chains
  reproduce the legacy scalar oracle
  :meth:`repro.core.matcha.Matcha.average_cycle_time` exactly (tested at
  rtol 1e-6; the masks consume the same ``random.Random`` stream and the
  weights/recursion are the same f64 operations).

The shared API:

* :meth:`Schedule.price`           — :class:`ScheduleEstimate` (τ̄, CI) on a
  connectivity graph, the number every designer/controller compares;
* :meth:`Schedule.round_edges`     — the directed overlay of round ``k``,
  a pure function of (schedule, k): every silo sampling from a shared
  round counter materializes the same topology with no coordination
  (the contract :class:`repro.fed.gossip.ScheduleSlot` builds on);
* :meth:`Schedule.simulate_rounds` — realized round durations, the
  profile the online controller calibrates its detector against.

A cycle-time caveat: the unified pricing API compares *round rate* only.
On that metric RING tends to dominate MATCHA — the paper's headline
result, which the max-plus steady state explains: a fixed overlay
pipelines, so even a slow link is amortized over the whole critical
circuit, while random per-round re-coupling propagates every stall.
Randomized schedules are chosen for what τ̄ cannot see — mixing per unit
of traffic under a communication budget — so callers pin the family (and
the budget menu) deliberately; the Schedule API's job is to price, adapt,
and actuate that choice under drift, not to second-guess it.
"""

from __future__ import annotations

import abc
import math
import random
from dataclasses import dataclass, replace
from typing import Hashable, List, Sequence, Tuple

import numpy as np

from ..analysis.contracts import contract
from ..obs.spans import span_fn
from .delays import ConnectivityGraph, TrainingParams
from .matcha import Matcha, greedy_edge_coloring
from .maxplus_sparse import (
    batched_overlay_delay_edges,
    timing_recursion_unique_rounds_sparse,
)
from .topologies import Overlay, evaluate_overlay

Node = Hashable
Edge = Tuple[Node, Node]

DEFAULT_MATCHA_BUDGETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0)


class ScheduleInfeasibleError(ValueError):
    """No randomized schedule exists on this connectivity estimate —
    the graph routes no symmetric pairs (or every matching pair has
    vanished), so there is nothing to sample.  Callers that treat a
    schedule as one *candidate* (the online controller's re-design pool)
    catch exactly this and fall back to fixed overlays; any other error
    from the pricing engine propagates."""


@dataclass(frozen=True)
class ScheduleEstimate:
    """Priced (cycle time, mixing rate) of a schedule on one estimate.

    ``tau_ms`` is the mean over Monte-Carlo replicates, ``ci95_ms`` the
    95% normal-approximation half-width over seeds (0.0 when the
    schedule is deterministic or a single seed was used), ``per_seed_ms``
    the raw per-replicate averages.  ``rho`` is the per-round consensus
    contraction factor (second-largest singular value of the deployed
    matrix for fixed schedules, ``sqrt(λ_max(E[WᵀW] − J/n))`` for
    randomized ones — see :mod:`repro.core.mixing`); NaN means mixing
    was not priced (τ-only callers never pay the spectral cost).
    """

    tau_ms: float
    ci95_ms: float
    per_seed_ms: Tuple[float, ...]
    rho: float = float("nan")

    @property
    def time_to_eps_score(self) -> float:
        """``τ / −log(ρ)`` — ms per e-fold of consensus-error decay
        (:func:`repro.core.mixing.wall_clock_to_eps`); NaN when ρ is
        unpriced, +inf when ρ ≥ 1 (no contraction)."""
        from .mixing import wall_clock_to_eps

        return wall_clock_to_eps(self.tau_ms, self.rho)


class Schedule(abc.ABC):
    """A (possibly randomized) per-round communication topology."""

    name: str

    @property
    @abc.abstractmethod
    def is_randomized(self) -> bool:
        """Does :meth:`round_edges` vary with the round counter?"""

    @abc.abstractmethod
    def round_edges(self, round_idx: int) -> Tuple[Edge, ...]:
        """Directed overlay edges of round ``round_idx``.

        Must be a pure function of the schedule's frozen state and the
        round counter — silos sharing the counter sample identical
        topologies without any cross-silo coordination.
        """

    @abc.abstractmethod
    def price(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        *,
        rounds: int = 300,
        seeds: Sequence[int] = (0,),
    ) -> ScheduleEstimate:
        """Average cycle time (Eq. 3 / Eq. 4) on the given measurements."""

    @abc.abstractmethod
    def simulate_rounds(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        num_rounds: int,
        *,
        seed: int = 0,
    ) -> np.ndarray:
        """``[num_rounds]`` simulated round durations (the controller's
        expected-profile input)."""

    def simulate_rounds_batch(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        num_rounds: int,
        seeds: Sequence[int] = (0,),
    ) -> np.ndarray:
        """``[len(seeds), num_rounds]`` duration profiles.  Randomized
        schedules override this to price every seed chain in one engine
        call; the base implementation loops."""
        return np.stack(
            [
                self.simulate_rounds(gc, tp, num_rounds, seed=s)
                for s in seeds
            ]
        )


# ---------------------------------------------------------------------------
# Fixed schedules


@dataclass(frozen=True)
class FixedSchedule(Schedule):
    """A deterministic overlay as a degenerate schedule: every round uses
    the same edges and pricing is the exact (f64 Karp) cycle time."""

    overlay: Overlay

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.overlay.name

    @property
    def is_randomized(self) -> bool:
        return False

    def round_edges(self, round_idx: int) -> Tuple[Edge, ...]:
        return self.overlay.edges

    def price(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        *,
        rounds: int = 300,
        seeds: Sequence[int] = (0,),
    ) -> ScheduleEstimate:
        tau = evaluate_overlay(gc, tp, self.overlay.edges, self.overlay.name).cycle_time_ms
        return ScheduleEstimate(tau_ms=tau, ci95_ms=0.0, per_seed_ms=(tau,))

    def simulate_rounds(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        num_rounds: int,
        *,
        seed: int = 0,
    ) -> np.ndarray:
        arcs = [e for e in self.overlay.edges if e[0] != e[1]]
        if not arcs:
            # Degenerate overlay (e.g. a one-silo estimate after churn):
            # only the computation self-loops tick, every round costs the
            # slowest silo's local steps — the comp-only profile the old
            # dense calibration produced, not an error.
            comp = max(
                tp.local_steps * gc.silo_params[v].comp_time_ms
                for v in gc.silos
            )
            return np.full(num_rounds, comp, dtype=np.float64)
        masks = np.ones((1, num_rounds, len(arcs)), dtype=bool)
        times = _priced_recursion(gc, tp, arcs, masks)
        return np.diff(times[0].max(axis=1))


# ---------------------------------------------------------------------------
# MATCHA as a schedule


@dataclass(frozen=True)
class MatchaSchedule(Schedule):
    """MATCHA(+)'s randomized plan distribution as a first-class schedule.

    ``matchings`` is the edge-coloring decomposition of the base graph
    (unordered silo pairs; communication is bidirectional), ``budget``
    the per-round activation probability C_b, validated to (0, 1] —
    ``budget <= 0`` would make the Appendix G.3 resample-until-nonempty
    loop spin forever.  ``sample_seed`` fixes the *deployment* sampling
    stream consumed by :meth:`round_edges` (counter-based, so round k is
    addressable without generating rounds 0..k-1); pricing uses its own
    per-seed ``random.Random`` streams to stay bit-compatible with the
    legacy scalar oracle.
    """

    matchings: Tuple[Tuple[Edge, ...], ...]
    budget: float
    name: str = "matcha"
    sample_seed: int = 0

    def __post_init__(self):
        if not (0.0 < self.budget <= 1.0):
            raise ValueError(
                f"MATCHA budget C_b must be in (0, 1], got {self.budget!r} "
                "(budget <= 0 never activates a matching and the "
                "resample-until-nonempty rule of Appendix G.3 would loop "
                "forever)"
            )
        if not self.matchings or all(len(m) == 0 for m in self.matchings):
            raise ValueError("MatchaSchedule needs at least one nonempty matching")

    @property
    def num_matchings(self) -> int:
        return len(self.matchings)

    @property
    def is_randomized(self) -> bool:
        return True

    @property
    def pairs(self) -> Tuple[Edge, ...]:
        """All unordered base-graph pairs, concatenated across matchings."""
        return tuple(p for m in self.matchings for p in m)

    # -- sampling -----------------------------------------------------------

    def round_active(self, round_idx: int) -> Tuple[int, ...]:
        """Indices of the matchings active in round ``round_idx``.

        Counter-based: a fresh ``Philox``-backed generator is derived from
        ``(sample_seed, round_idx)``, so the draw is a pure, platform-
        stable function of the pair — the cross-silo determinism contract.
        Resamples until at least one matching is active (Appendix G.3).
        """
        rng = np.random.default_rng(
            np.random.SeedSequence((int(self.sample_seed), int(round_idx)))
        )
        while True:
            active = np.flatnonzero(rng.random(self.num_matchings) < self.budget)
            if active.size:
                return tuple(int(a) for a in active)

    def round_edges(self, round_idx: int) -> Tuple[Edge, ...]:
        out: List[Edge] = []
        for m in self.round_active(round_idx):
            for (i, j) in self.matchings[m]:
                out.append((i, j))
                out.append((j, i))
        return tuple(out)

    def activation_masks(self, rounds: int, seed: int) -> np.ndarray:
        """``[R, M]`` boolean activation masks for one pricing chain.

        Consumes the exact ``random.Random(seed)`` stream of the legacy
        :meth:`repro.core.matcha.Matcha.sample_round` loop (one uniform
        per matching per attempt, rounds resampled until nonempty), which
        is what makes the vectorized τ̄ reproduce the scalar oracle
        bit-for-bit on equal seeds.  Because every attempt consumes
        exactly M draws and a round accepts its *first* nonempty attempt,
        the accepted rows are simply the nonempty attempt rows in stream
        order — so attempts are drawn in bulk and filtered vectorized
        (draws past the last accepted round are discarded, which legacy
        never sees: the generator is private to this call).
        """
        rng = random.Random(seed)
        M = self.num_matchings
        out = np.empty((rounds, M), dtype=bool)
        got = 0
        p_accept = 1.0 - (1.0 - self.budget) ** M
        rnd = rng.random
        while got < rounds:
            need = rounds - got
            n_att = min(int(need / p_accept * 1.2) + 4, 65536)
            draws = np.array(
                [rnd() for _ in range(n_att * M)], dtype=np.float64
            ).reshape(n_att, M)
            rows = draws < self.budget
            acc = rows[rows.any(axis=1)]
            take = min(len(acc), need)
            out[got : got + take] = acc[:take]
            got += take
        return out

    # -- pricing ------------------------------------------------------------

    def _arc_pool(self, gc: ConnectivityGraph) -> Tuple[List[Edge], np.ndarray]:
        """(directed arc pool, [E] matching index per arc), filtered to
        pairs the graph still routes (dynamics: silos leave, links
        partition — a vanished pair simply drops out of the pool)."""
        arcs: List[Edge] = []
        mids: List[int] = []
        present = set(gc.silos)
        for m, matching in enumerate(self.matchings):
            for (i, j) in matching:
                if (
                    i in present
                    and j in present
                    and gc.has_edge(i, j)
                    and gc.has_edge(j, i)
                ):
                    arcs.extend([(i, j), (j, i)])
                    mids.extend([m, m])
        return arcs, np.asarray(mids, dtype=np.int64)

    def price(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        *,
        rounds: int = 300,
        seeds: Sequence[int] = (0,),
    ) -> ScheduleEstimate:
        taus = average_cycle_times_batched(
            (self,), gc, tp, rounds=rounds, seeds=seeds
        )[0]
        return _estimate_from_chains(taus)

    def simulate_rounds(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        num_rounds: int,
        *,
        seed: int = 0,
    ) -> np.ndarray:
        return self.simulate_rounds_batch(gc, tp, num_rounds, (seed,))[0]

    def simulate_rounds_batch(
        self,
        gc: ConnectivityGraph,
        tp: TrainingParams,
        num_rounds: int,
        seeds: Sequence[int] = (0,),
    ) -> np.ndarray:
        arcs, mids = self._arc_pool(gc)
        masks = np.stack(
            [self.activation_masks(num_rounds, s)[:, mids] for s in seeds]
        )
        times = _priced_recursion(gc, tp, arcs, masks)
        return np.diff(times.max(axis=2), axis=1)


def _estimate_from_chains(taus: np.ndarray) -> ScheduleEstimate:
    taus = np.asarray(taus, dtype=np.float64)
    mean = float(taus.mean())
    if taus.size < 2:
        return ScheduleEstimate(mean, 0.0, tuple(float(t) for t in taus))
    half = 1.96 * float(taus.std(ddof=1)) / math.sqrt(taus.size)
    return ScheduleEstimate(mean, half, tuple(float(t) for t in taus))


def _priced_recursion(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    arcs: Sequence[Edge],
    masks: np.ndarray,
) -> np.ndarray:
    """``[C, R+1, N]`` start times of ``[C, R, E]`` per-round arc masks:
    Eq. 3 pricing (per-round degrees) + round-varying Eq. 4 recursion.

    Identical mask rows get identical Eq. 3 weights (degrees are a pure
    function of the row), so only the *distinct* rows are priced and the
    per-round weight stack is a gather — at small budgets most rounds
    repeat a handful of activation subsets.
    """
    C, R, E = masks.shape
    if E == 0:
        raise ScheduleInfeasibleError("schedule has no usable arcs on this graph")
    flat = masks.reshape(C * R, E)
    first, inv = _unique_rows(flat)
    return _recursion_from_unique(gc, tp, arcs, flat[first], inv, C, R)


def _unique_rows(flat: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(first-occurrence indices, inverse) of the rows of a boolean
    ``[B, K]`` matrix.  Rows are identified by their packed bits — for
    K <= 64 that is one ``uint64`` key per row, an order of magnitude
    cheaper than ``np.unique(..., axis=0)`` row sorting."""
    packed = np.ascontiguousarray(np.packbits(flat, axis=1))
    nb = packed.shape[1]
    if nb <= 8:
        keyb = np.zeros((flat.shape[0], 8), dtype=np.uint8)
        keyb[:, :nb] = packed
        key = keyb.view(np.uint64).ravel()
    else:
        key = packed.view([("", packed.dtype)] * nb).ravel()
    _, first, inv = np.unique(key, return_index=True, return_inverse=True)
    return first, inv


def _recursion_from_unique(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    arcs: Sequence[Edge],
    uniq_masks: np.ndarray,
    inv: np.ndarray,
    C: int,
    R: int,
) -> np.ndarray:
    """Price the [U, E] distinct rows and run the unique-rounds recursion
    (the full [C, R, E] weight stack is never materialized)."""
    eb = batched_overlay_delay_edges(gc, tp, list(arcs), uniq_masks)
    # Column-sort by dst at the deduped [U, E] stage so the recursion's
    # per-round segment maxes are plain reduceats with no reorder.
    order = np.argsort(eb.dst[0], kind="stable")
    return timing_recursion_unique_rounds_sparse(
        eb.src[0][order],
        eb.dst[0][order],
        eb.w[:, order],
        inv.reshape(C, R),
        gc.num_silos,
    )


@span_fn("engine.schedule_cycle_times")
@contract("#S", ret="[S,K]", seeds="#K")
def average_cycle_times_batched(
    schedules: Sequence[MatchaSchedule],
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    rounds: int = 300,
    seeds: Sequence[int] = (0,),
) -> np.ndarray:
    """``[len(schedules), len(seeds)]`` seeded τ̄ chains in one engine call.

    All schedules must share the same matchings (they typically differ
    only in budget — the budget-sweep case); each (schedule, seed) chain
    reproduces ``Matcha(matchings, budget).average_cycle_time(gc, tp,
    rounds=rounds, seed=seed)`` exactly.
    """
    if not schedules:
        return np.zeros((0, len(seeds)), dtype=np.float64)
    base = schedules[0].matchings
    if any(s.matchings != base for s in schedules):
        raise ValueError("batched pricing requires a shared matching pool")
    arcs, mids = schedules[0]._arc_pool(gc)
    if not arcs:
        raise ScheduleInfeasibleError("schedule has no usable arcs on this graph")
    C = len(schedules) * len(seeds)
    act = np.empty((C, rounds, schedules[0].num_matchings), dtype=bool)
    c = 0
    for s in schedules:
        for seed in seeds:
            act[c] = s.activation_masks(rounds, seed)
            c += 1
    # Dedup at the matching level (M bits per round, one uint64 key) and
    # only expand the distinct activation subsets to arc masks — at small
    # budgets most rounds repeat a handful of subsets.
    flat = act.reshape(C * rounds, -1)
    first, inv = _unique_rows(flat)
    times = _recursion_from_unique(
        gc, tp, arcs, flat[first][:, mids], inv, C, rounds
    )
    taus = times[:, rounds].max(axis=1) / rounds
    return taus.reshape(len(schedules), len(seeds))


# ---------------------------------------------------------------------------
# Constructors / designer


@contract()
def matcha_schedule_from_connectivity(
    gc: ConnectivityGraph, budget: float = 0.5, *, sample_seed: int = 0
) -> MatchaSchedule:
    """MATCHA over the symmetric pairs of a connectivity graph (the
    schedule twin of :func:`repro.core.matcha.matcha_from_connectivity`)."""
    pairs: List[Edge] = []
    seen = set()
    for (i, j) in gc.latency_ms:
        k = frozenset((i, j))
        if i != j and k not in seen and gc.has_edge(j, i):
            seen.add(k)
            pairs.append((i, j))
    return MatchaSchedule(
        matchings=tuple(tuple(m) for m in greedy_edge_coloring(pairs)),
        budget=budget,
        sample_seed=sample_seed,
    )


@contract()
def matcha_schedule_from_underlay(
    underlay, budget: float = 0.5, *, sample_seed: int = 0
) -> MatchaSchedule:
    """MATCHA+: matchings computed on the underlay core graph."""
    return MatchaSchedule(
        matchings=tuple(
            tuple(m) for m in greedy_edge_coloring(list(underlay.core_edges))
        ),
        budget=budget,
        name="matcha+",
        sample_seed=sample_seed,
    )


@contract()
def schedule_from_matcha(m: Matcha, *, sample_seed: int = 0) -> MatchaSchedule:
    """Lift a legacy :class:`~repro.core.matcha.Matcha` sampler."""
    return MatchaSchedule(
        matchings=tuple(tuple(mm) for mm in m.matchings),
        budget=m.budget,
        sample_seed=sample_seed,
    )


@contract()
def design_matcha_schedule(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    *,
    budgets: Sequence[float] = DEFAULT_MATCHA_BUDGETS,
    rounds: int = 150,
    seeds: Sequence[int] = (0, 1, 2),
    sample_seed: int = 0,
    objective: str = "tau",
    mixing_rounds: int = 128,
) -> Tuple[MatchaSchedule, ScheduleEstimate]:
    """Budget sweep: one batched engine call across budgets × seeds.

    Prices a :class:`MatchaSchedule` at every budget (``len(budgets) *
    len(seeds)`` Monte-Carlo chains in a single
    :func:`average_cycle_times_batched` evaluation) and returns the
    budget minimizing ``objective`` plus its estimate.  Under the
    default ``"tau"`` that is the smallest mean τ̄ — typically the
    smallest budget, since fewer active matchings per round means
    faster rounds *and less mixing*.  ``objective="time_to_eps"``
    additionally prices every budget's expected contraction ρ over
    ``mixing_rounds`` sampled activation rows
    (:func:`repro.core.mixing.schedule_rho`) and minimizes the
    composite ``τ̄ / −log(ρ)``, resolving the throughput/mixing tension
    the τ-only sweep punts to the caller; the returned estimate then
    carries the winning ρ.
    """
    try:
        matchings = matcha_schedule_from_connectivity(gc).matchings
    except ValueError as e:  # no symmetric pairs to color
        raise ScheduleInfeasibleError(str(e)) from e
    cands = [
        MatchaSchedule(matchings=matchings, budget=b, sample_seed=sample_seed)
        for b in budgets
    ]
    taus = average_cycle_times_batched(cands, gc, tp, rounds=rounds, seeds=seeds)
    mean_taus = taus.mean(axis=1)
    if objective == "tau":
        best = int(np.argmin(mean_taus))
        return cands[best], _estimate_from_chains(taus[best])
    # time_to_eps (score_estimate validates the name): lazy import —
    # mixing imports this module at top level, so the cycle breaks here.
    from .mixing import schedule_rho, score_estimate

    rhos = [
        schedule_rho(c, gc, rounds=mixing_rounds, seed=sample_seed)
        for c in cands
    ]
    ests = [
        replace(_estimate_from_chains(taus[k]), rho=rhos[k])
        for k in range(len(cands))
    ]
    best = int(np.argmin([score_estimate(e, objective) for e in ests]))
    return cands[best], ests[best]
